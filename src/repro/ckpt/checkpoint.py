"""Sharded checkpointing with integrity checks, async save and
reshard-on-restore (fault tolerance / elastic scaling substrate).

Format: one ``.npy`` per flattened leaf under ``<dir>/step_<n>/`` plus a
``manifest.json`` holding the treedef, shapes/dtypes, crc32 per leaf, the
data-pipeline state and user metadata.  A ``COMMIT`` marker is written last:
restore ignores uncommitted (crashed mid-save) checkpoints — the classic
atomic-rename protocol.

``restore(..., shardings=...)`` device_puts every leaf with the *target*
sharding, so a checkpoint taken on a 2-pod mesh restores onto 1 pod (or a
different parallelism layout) without conversion — RLAS re-optimisation on
topology change (paper §5.3) pairs with this in launch/elastic.py.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(directory: str, step: int, tree: Any,
         extra: Optional[Dict] = None, keep: int = 3) -> str:
    """Synchronous checkpoint write; returns the checkpoint path."""
    path = os.path.join(directory, f"step_{step:08d}")
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = _flatten(tree)
    manifest = {"step": step, "treedef": str(treedef),
                "n_leaves": len(leaves), "extra": extra or {}, "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        fn = os.path.join(tmp, f"leaf_{i:05d}.npy")
        logical_dtype = str(arr.dtype)
        if arr.dtype.kind == "V" or logical_dtype not in np.sctypeDict:
            # ml_dtypes (bfloat16, float8_*) don't survive np.save/np.load;
            # store raw bits and record the logical dtype in the manifest
            arr = arr.view(
                {1: np.uint8, 2: np.uint16, 4: np.uint32}[arr.dtype.itemsize])
        np.save(fn, arr)
        manifest["leaves"].append({
            "shape": list(arr.shape), "dtype": logical_dtype,
            "crc32": zlib.crc32(arr.tobytes()) & 0xFFFFFFFF})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "COMMIT"), "w") as f:
        f.write("ok")
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)
    _gc(directory, keep)
    return path


class AsyncCheckpointer:
    """Snapshot-then-write in a background thread; join() before exit."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None

    def save(self, directory: str, step: int, tree: Any,
             extra: Optional[Dict] = None, keep: int = 3):
        self.join()
        snapshot = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._thread = threading.Thread(
            target=save, args=(directory, step, snapshot, extra, keep),
            daemon=True)
        self._thread.start()

    def join(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp") and \
                os.path.exists(os.path.join(directory, name, "COMMIT")):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(directory: str, step: int, target_tree: Any,
            shardings: Any = None, strict_crc: bool = True):
    """Restore into the structure of ``target_tree``; optionally device_put
    each leaf with the matching sharding from ``shardings`` (resharding)."""
    path = os.path.join(directory, f"step_{step:08d}")
    assert os.path.exists(os.path.join(path, "COMMIT")), \
        f"uncommitted/missing checkpoint {path}"
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = _flatten(target_tree)
    assert manifest["n_leaves"] == len(leaves), \
        f"leaf count mismatch: ckpt {manifest['n_leaves']} vs {len(leaves)}"
    if shardings is not None:
        shard_leaves = jax.tree_util.tree_flatten(
            shardings, is_leaf=lambda x: x is None)[0]
        assert len(shard_leaves) == len(leaves), \
            "shardings tree must match target (use None leaves to skip)"
    else:
        shard_leaves = [None] * len(leaves)
    out = []
    for i, (leaf, shard) in enumerate(zip(leaves, shard_leaves)):
        arr = np.load(os.path.join(path, f"leaf_{i:05d}.npy"))
        meta = manifest["leaves"][i]
        if strict_crc:
            crc = zlib.crc32(arr.tobytes()) & 0xFFFFFFFF
            assert crc == meta["crc32"], f"leaf {i} corrupt (crc mismatch)"
        if str(arr.dtype) != meta["dtype"]:
            import ml_dtypes
            arr = arr.view(np.dtype(getattr(ml_dtypes, meta["dtype"])))
        assert list(arr.shape) == meta["shape"]
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]


def _gc(directory: str, keep: int):
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(directory)
        if n.startswith("step_") and not n.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)
