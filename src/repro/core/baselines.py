"""Competing execution-plan strategies (paper §6.4, Table 6).

* ``ff_place``  — First-Fit: topological greedy that collocates each unit with
  its producers when resources allow (the traffic-minimising heuristic family
  of T-Storm [52] / Aniello et al. [13]).
* ``rr_place``  — Round-Robin across sockets (R-Storm-style load balancing).
* ``RLAS_fix(L)/(U)`` — the paper's fixed-capability ablations: run the same
  search/scaling as RLAS but assume a constant T^f (worst-case / zero);
  exposed via ``tf_mode`` on :func:`repro.core.scaling.rlas_optimize`.
* ``random_plan`` — Monte-Carlo random replication+placement (Fig. 14).

FF and RR enforce resource constraints as far as possible and, like the paper,
gradually relax them (scaling capacities by 1.25x) when no feasible slot
exists, which typically ends up oversubscribing a few sockets.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from .graph import ExecutionGraph, LogicalGraph
from .perfmodel import UNPLACED, evaluate
from .placement import PlacementResult
from .topology import MachineSpec


def _greedy_fill(graph: ExecutionGraph, machine: MachineSpec,
                 input_rate: Optional[float],
                 socket_order_fn) -> List[int]:
    """Shared FF/RR skeleton: place units one by one under relaxable limits."""
    n = graph.n_units
    placement = [UNPLACED] * n
    relax = 1.0
    for _ in range(32):                          # relaxation ladder
        placement = [UNPLACED] * n
        ok = True
        for v in graph.topo_unit_order():
            placed = False
            for s in socket_order_fn(v, placement, graph, machine):
                placement[v] = s
                ev = evaluate(graph, machine, placement, input_rate)
                within = all(
                    ev.cpu_usage[t] <= machine.cores_per_socket * relax + 1e-9
                    for t in range(machine.n_sockets)) and all(
                    ev.mem_usage[t] <= machine.local_bw * relax * (1 + 1e-9)
                    for t in range(machine.n_sockets))
                chan_ok = np.all(ev.chan_usage <= machine.Q * relax + 1e-6)
                if within and chan_ok:
                    placed = True
                    break
                placement[v] = UNPLACED
            if not placed:
                ok = False
                break
        if ok:
            return placement
        relax *= 1.25
    # last resort: force-place everything ignoring constraints
    for v in graph.topo_unit_order():
        if placement[v] == UNPLACED:
            placement[v] = 0
    return placement


def ff_place(graph: ExecutionGraph, machine: MachineSpec,
             input_rate: Optional[float] = None) -> PlacementResult:
    """First-Fit with producer collocation preference (topo order)."""

    def order(v, placement, g, m):
        prods = [placement[u] for u, _ in g.in_edges[v]
                 if placement[u] != UNPLACED]
        pref = sorted(set(prods), key=prods.count, reverse=True)
        rest = [s for s in range(m.n_sockets) if s not in pref]
        return pref + rest

    placement = _greedy_fill(graph, machine, input_rate, order)
    ev = evaluate(graph, machine, placement, input_rate)
    return PlacementResult(placement, ev, ev.feasible, graph.n_units, True, 0.0)


def rr_place(graph: ExecutionGraph, machine: MachineSpec,
             input_rate: Optional[float] = None) -> PlacementResult:
    """Round-robin across sockets in topological unit order."""
    counter = {"i": 0}

    def order(v, placement, g, m):
        start = counter["i"] % m.n_sockets
        counter["i"] += 1
        return [(start + k) % m.n_sockets for k in range(m.n_sockets)]

    placement = _greedy_fill(graph, machine, input_rate, order)
    ev = evaluate(graph, machine, placement, input_rate)
    return PlacementResult(placement, ev, ev.feasible, graph.n_units, True, 0.0)


def random_plan(logical: LogicalGraph, machine: MachineSpec,
                rng: np.random.Generator,
                input_rate: Optional[float] = None,
                max_threads: Optional[int] = None,
                compress_ratio: int = 1,
                routes=None,
                ) -> Tuple[ExecutionGraph, List[int], "PlanEval"]:
    """One Monte-Carlo sample: random replication until the thread budget is
    hit, then uniform random placement (paper Fig. 14 protocol).  Returns the
    full :class:`PlanEval` (``.R`` is 0-equivalent when infeasible)."""
    if max_threads is None:
        max_threads = machine.total_cores
    names = list(logical.operators)
    parallelism = {name: 1 for name in names}
    while sum(parallelism.values()) < max_threads:
        op = names[rng.integers(len(names))]
        parallelism[op] += 1
        if rng.random() < 0.15:          # random stopping point
            break
    graph = ExecutionGraph(logical, parallelism, compress_ratio,
                           routes=routes)
    placement = [int(rng.integers(machine.n_sockets))
                 for _ in range(graph.n_units)]
    ev = evaluate(graph, machine, placement, input_rate)
    return graph, placement, ev
