"""Topologically-sorted iterative scaling (paper Algorithm 1).

Joint replication + placement optimization: starting from replication level 1
for every operator, repeatedly (1) optimize placement with the B&B, (2) find
the bottleneck (over-supplied) operator scanning from sinks toward the spout
(reverse topological order), (3) raise its replication level by the oversupply
ratio ``ceil(r_i / r_o)``, and re-optimize.  Terminates when placement fails,
no further increase is possible, or the thread budget (total cores by default)
is exhausted.  The best plan seen is returned.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from .graph import ExecutionGraph, LogicalGraph
from .placement import PlacementResult, bnb_place
from .topology import MachineSpec


@dataclasses.dataclass
class ScalingResult:
    parallelism: Dict[str, int]
    placement: PlacementResult
    graph: ExecutionGraph
    history: List[Tuple[Dict[str, int], float]]   # (parallelism, R) per iter
    iterations: int

    @property
    def R(self) -> float:
        return self.placement.R


def rlas_optimize(logical: LogicalGraph, machine: MachineSpec,
                  input_rate: Optional[float] = None,
                  compress_ratio: int = 1,
                  max_threads: Optional[int] = None,
                  bestfit: bool = False,
                  max_nodes: int = 50_000,
                  tf_mode: str = "relative",
                  max_iters: int = 200,
                  initial_parallelism: Optional[Dict[str, int]] = None,
                  bottleneck_rule: str = "reverse_topo",
                  routes=None,
                  ) -> ScalingResult:
    """RLAS: jointly optimize replication and placement (Alg. 1 + Alg. 2).

    ``tf_mode`` selects the capability assumption used *during optimization*
    ("relative" = RLAS, "worst" = RLAS_fix(L), "zero" = RLAS_fix(U)); results
    are always reported under the true relative model.  ``routes`` is the
    compiled routing table forwarded to :class:`ExecutionGraph` so scaling
    decisions see the same edge semantics the runtime executes.
    """
    if max_threads is None:
        max_threads = machine.total_cores
    parallelism = {name: 1 for name in logical.operators}
    if initial_parallelism:
        parallelism.update(initial_parallelism)
    best: Optional[ScalingResult] = None
    history: List[Tuple[Dict[str, int], float]] = []
    rev_topo = list(reversed(logical.topo_order()))

    it = 0
    while it < max_iters:
        it += 1
        graph = ExecutionGraph(logical, parallelism, compress_ratio,
                               routes=routes)
        pres = bnb_place(graph, machine, input_rate, bestfit=bestfit,
                         max_nodes=max_nodes, tf_mode=tf_mode)
        history.append((dict(parallelism), pres.R))
        if pres.feasible and (best is None or pres.R > best.R):
            best = ScalingResult(dict(parallelism), pres, graph, history, it)
        if not pres.feasible:
            break                       # Alg.1 line 9-10: placement failed
        # Identify the bottleneck: the paper scans sinks -> spout (reverse
        # topological order); "max_ratio" grows the most over-supplied
        # operator first, which balances deep chains faster (autoshard).
        bottlenecks = pres.eval.bottlenecks
        grew = False
        if bottleneck_rule == "max_ratio":
            scan = sorted(bottlenecks,
                          key=lambda o: -bottlenecks[o]
                          if math.isfinite(bottlenecks[o]) else -1e30)
        else:
            scan = [op for op in rev_topo if op in bottlenecks]
        for op in scan:
            ratio = bottlenecks[op]
            k = parallelism[op]
            if math.isfinite(ratio):
                new_k = max(k + 1, math.ceil(k * ratio))
            else:                        # unbounded ingress (I = None) spout
                new_k = k * 2
            # geometric growth cap: an extreme oversupply ratio (common for
            # the first stage behind an unbounded feed) must not grab the
            # whole thread budget in one iteration — growth stays balanced
            # across bottlenecks and converges within max_iters
            new_k = min(new_k, k * 2)
            # cap so the total thread count stays within budget
            budget = max_threads - (sum(parallelism.values()) - k)
            new_k = min(new_k, budget)
            if new_k <= k:
                continue                 # cannot grow this op further
            parallelism[op] = new_k
            grew = True
            break
        if not grew:
            break                        # no bottleneck can be scaled
    if best is None:
        graph = ExecutionGraph(logical, parallelism, compress_ratio,
                               routes=routes)
        pres = bnb_place(graph, machine, input_rate, bestfit=bestfit,
                         max_nodes=max_nodes, tf_mode=tf_mode)
        best = ScalingResult(dict(parallelism), pres, graph, history, it)
    best = dataclasses.replace(best, history=history, iterations=it)
    return best
