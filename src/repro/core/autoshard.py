"""RLAS applied to the LM training pipeline (DESIGN.md §2 TPU adaptation).

The layer stack is a streaming pipeline: *operators* are stages (embed,
period-groups of layers, head+loss), *tuples* are microbatches of
activations, *sockets* are pods.  Stage service time T^e comes from the
stage's roofline (FLOPs / chip compute, parameter+activation bytes / HBM bw);
the fetch term T^f is the paper's Formula (2) with the DMA-granule/ICI-DCN
constants from ``topology.tpu_pod_spec``.

RLAS then *jointly* decides replication (how many chips process each stage —
data parallelism) and placement (which pod) under per-pod compute/bandwidth
constraints — exactly the paper's optimization, answering the multi-pod
question "replicate the pipeline per pod (DP over DCN) or split stages across
pods (PP over DCN)?" from the model rather than by convention.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.models.config import ModelConfig
from .graph import LogicalGraph
from .topology import TPU_V5E_PEAK_FLOPS, TPU_V5E_HBM_BW, tpu_pod_spec

MXU_EFFICIENCY = 0.5            # attainable fraction of peak on real kernels


@dataclasses.dataclass
class StagePlan:
    assignment: Dict[str, int]          # stage -> majority pod
    parallelism: Dict[str, int]         # stage -> chips (DP degree)
    dp_degree: int
    throughput: float                   # microbatches/sec (model estimate)
    crosses_pods: bool                  # True = pipeline split across pods
    result: object                      # ScalingResult for inspection
    plan: object = None                 # the api.Plan (estimate/simulate)


def _stage_flops_bytes(cfg: ModelConfig, tokens: int):
    """(flops, param_bytes, act_bytes) per microbatch for one period group."""
    total, active = cfg.param_count()
    per_layer_active = active / max(cfg.n_layers, 1)
    layers_per_stage = len(cfg.period)
    flops = 2 * per_layer_active * layers_per_stage * tokens
    bytes_params = per_layer_active * layers_per_stage * 2          # bf16
    bytes_acts = tokens * cfg.d_model * 2
    return flops, bytes_params, bytes_acts


def build_stage_topology(cfg: ModelConfig, microbatch: int, seq: int,
                         train: bool = True):
    """Declare the layer stack as a planning-only streaming Topology
    (stages have profiled specs but no runtime kernels)."""
    from repro.streaming.api import Topology

    tokens = microbatch * seq
    mult = 3.0 if train else 1.0        # fwd+bwd
    act_bytes = tokens * cfg.d_model * 2
    peak = TPU_V5E_PEAK_FLOPS * MXU_EFFICIENCY

    embed_flops = 2 * cfg.vocab * cfg.d_model * 0 + tokens * cfg.d_model * 2
    # host feed: rate-limited stand-in (1e6 microbatches/s >> any stage),
    # NOT free — a 0-cost spout would saturate the model's bandwidth budget
    topo = (Topology(f"stages[{cfg.name}]")
            .spout("feed", exec_ns=1e3, tuple_bytes=tokens * 4)
            .op("embed", exec_ns=mult * embed_flops / peak * 1e9,
                tuple_bytes=tokens * 4, mem_bytes=act_bytes))
    for i in range(cfg.n_periods):
        flops, pbytes, abytes = _stage_flops_bytes(cfg, tokens)
        topo.op(f"stage{i}", exec_ns=mult * flops / peak * 1e9,
                tuple_bytes=abytes, mem_bytes=pbytes + abytes)
    head_flops = mult * 2 * cfg.vocab * cfg.d_model * tokens
    topo.op("head", exec_ns=head_flops / peak * 1e9,
            tuple_bytes=act_bytes, mem_bytes=act_bytes)
    return topo


def build_stage_graph(cfg: ModelConfig, microbatch: int, seq: int,
                      train: bool = True) -> LogicalGraph:
    return build_stage_topology(cfg, microbatch, seq, train).build_logical()


def plan_stages(cfg: ModelConfig, n_pods: int = 2, chips_per_pod: int = 256,
                microbatch: int = 16, seq: int = 4096,
                compress_ratio: int = 16, train: bool = True) -> StagePlan:
    from repro.streaming.api import Job

    machine = tpu_pod_spec(n_pods=n_pods, chips_per_pod=chips_per_pod)
    plan = Job(build_stage_topology(cfg, microbatch, seq, train)).plan(
        machine, optimizer="rlas", compress_ratio=compress_ratio,
        bestfit=True, max_nodes=20_000, max_iters=400,
        bottleneck_rule="reverse_topo", max_threads=machine.total_cores)
    res = plan.result
    # majority pod per stage (replicas may be spread for DP across pods)
    votes: Dict[str, Dict[int, int]] = {}
    if plan.eval is not None:
        for idx, unit in enumerate(plan.graph.replicas):
            s = plan.placement[idx]
            if s >= 0:
                votes.setdefault(unit.op, {})
                votes[unit.op][int(s)] = votes[unit.op].get(int(s), 0) \
                    + unit.group
    assignment = {op: max(v, key=v.get) for op, v in votes.items()}
    # PP cut = adjacent stages whose majority pods differ
    stage_pods = {v for k, v in assignment.items() if k.startswith("stage")}
    return StagePlan(
        assignment=assignment,
        parallelism=dict(res.parallelism),
        dp_degree=min(res.parallelism.values()) if res.parallelism else 1,
        throughput=res.R,
        crosses_pods=len(stage_pods) > 1,
        result=res, plan=plan)
