"""RLAS: relative-location aware scheduling (the paper's core contribution).

Public API:
  topology.MachineSpec / server_a / server_b / tpu_pod_spec
  graph.LogicalGraph / OperatorSpec / ExecutionGraph
  perfmodel.evaluate / PlanEval
  placement.bnb_place / brute_force_place
  scaling.rlas_optimize
  baselines.ff_place / rr_place / random_plan
"""
from .graph import ExecutionGraph, LogicalGraph, OperatorSpec, Replica
from .perfmodel import UNPLACED, PlanEval, evaluate
from .placement import PlacementResult, bnb_place, brute_force_place
from .scaling import ScalingResult, rlas_optimize
from .topology import MachineSpec, server_a, server_b, subset, tpu_pod_spec
from . import baselines

__all__ = [
    "ExecutionGraph", "LogicalGraph", "OperatorSpec", "Replica",
    "UNPLACED", "PlanEval", "evaluate",
    "PlacementResult", "bnb_place", "brute_force_place",
    "ScalingResult", "rlas_optimize",
    "MachineSpec", "server_a", "server_b", "subset", "tpu_pod_spec",
    "baselines",
]
