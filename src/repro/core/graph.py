"""Operator graphs (paper §2.2).

A :class:`LogicalGraph` is the application DAG: vertices are continuously
running operators, edges are streams.  Replication expands it into an
:class:`ExecutionGraph` whose vertices are *replicas* (the schedulable unit —
"we refer a replica of an operator simply as an operator", §3.1).  Shuffle
partitioning connects every producer replica to every consumer replica with
the producer's output split evenly.

The *compress-graph* heuristic (§4, heuristic 3) groups up to ``ratio``
replicas of one logical operator into a single schedulable unit whose capacity
and resource demand scale with the group size.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class OperatorSpec:
    """Profiled operator specification (paper Table 1, "operator specific").

    ``exec_ns``  — T^e, average execution+emit time per input tuple (ns).
    ``tuple_bytes`` — N, average size of one *input* tuple fetched from the
                   producer (bytes).
    ``mem_bytes``  — M, memory traffic per processed tuple (bytes) charged
                   against the local-bandwidth budget B.
    ``selectivity`` — output tuples emitted per input tuple processed.
    ``state_bytes`` — the share of ``mem_bytes`` attributable to *declared
                   operator state* (``repro.streaming.state.StateSpec``):
                   when an operator declares managed state, its topology
                   derives ``mem_bytes = tuple_bytes + state_bytes`` from
                   the declaration instead of a hand-tuned constant, and
                   the model reports the state share separately
                   (``PlanEval.state_usage``).
    ``state_resident_tuples`` — window-buffer *occupancy* in tuples: how
                   many rows the operator's declared window holds resident
                   at once (event-time windows buffer ``size + lateness``
                   event-time units of stream awaiting watermark passage;
                   count windows hold ``size`` arrivals of history — the
                   degenerate segmented case).  The model multiplies it by
                   the tuple size (shared across an operator's replicas —
                   each shard buffers its slice of the stream) to expose
                   the memory pinned by in-flight pane batches
                   (``PlanEval.state_resident_bytes``).  Occupancy is
                   rate-independent: pricing residency in wall-seconds
                   Little's-law style over-charged event-time operators by
                   orders of magnitude (a 64-tick pane is microseconds of
                   buffering at realistic rates, not 64 seconds).
    """

    name: str
    exec_ns: float
    tuple_bytes: float = 64.0
    mem_bytes: float = 64.0
    selectivity: float = 1.0
    is_spout: bool = False
    state_bytes: float = 0.0
    state_resident_tuples: float = 0.0
    #: True when the occupancy is a property of the *stream* and shards
    #: across the operator's replicas (event-time pane buffers: each keyed
    #: shard holds its slice of the same size+lateness span); False when
    #: every replica holds its own full buffer (count-window history is
    #: per-replica arrival position, so replication multiplies it)
    state_resident_shared: bool = True
    #: device operator: the kernel is a jitted JAX computation dispatched to
    #: an accelerator (or XLA host device).  ``device_ns`` is the per-tuple
    #: device compute time; ``exec_ns`` keeps its meaning as the *host-side*
    #: work (decode/route/emit).  ``dispatch_depth`` is the bounded in-flight
    #: dispatch window the Executor runs with (1 == synchronous).
    device: bool = False
    device_ns: float = 0.0
    dispatch_depth: int = 1

    @property
    def exec_s(self) -> float:
        """Effective per-tuple service time in seconds.

        Host operators: ``exec_ns``.  Device operators at ``dispatch_depth``
        1 pay host + device serially; at depth >= 2 the async dispatch window
        overlaps host ingest with device compute, so the bottleneck is
        ``max(host, device/depth)`` — the planner, placement model, and DES
        all consume this property, so overlap pricing propagates everywhere
        from this one definition.
        """
        if not self.device:
            return self.exec_ns * 1e-9
        if self.dispatch_depth <= 1:
            return (self.exec_ns + self.device_ns) * 1e-9
        return max(self.exec_ns, self.device_ns / self.dispatch_depth) * 1e-9


@dataclasses.dataclass
class LogicalGraph:
    """Application DAG over logical operators.

    ``edge_selectivity`` optionally overrides the producer's default
    selectivity per (producer, consumer) stream — LR's operators emit
    multiple output streams with distinct selectivities (paper Table 8).
    """

    operators: Dict[str, OperatorSpec]
    edges: List[Tuple[str, str]]                 # (producer, consumer)
    edge_selectivity: Dict[Tuple[str, str], float] = dataclasses.field(
        default_factory=dict)

    def __post_init__(self):
        names = set(self.operators)
        for u, v in self.edges:
            assert u in names and v in names, f"unknown edge {u}->{v}"
        self._check_acyclic()

    def sel(self, u: str, v: str) -> float:
        return self.edge_selectivity.get((u, v), self.operators[u].selectivity)

    def _check_acyclic(self) -> None:
        order = self.topo_order()
        assert len(order) == len(self.operators), "graph has a cycle"

    def producers(self, name: str) -> List[str]:
        return [u for u, v in self.edges if v == name]

    def consumers(self, name: str) -> List[str]:
        return [v for u, v in self.edges if u == name]

    def spouts(self) -> List[str]:
        return [n for n, op in self.operators.items() if op.is_spout]

    def sinks(self) -> List[str]:
        cons = {u for u, _ in self.edges}
        return [n for n in self.operators if n not in cons]

    def topo_order(self) -> List[str]:
        indeg = {n: 0 for n in self.operators}
        for _, v in self.edges:
            indeg[v] += 1
        frontier = sorted(n for n, d in indeg.items() if d == 0)
        order: List[str] = []
        while frontier:
            n = frontier.pop(0)
            order.append(n)
            for c in self.consumers(n):
                indeg[c] -= 1
                if indeg[c] == 0:
                    frontier.append(c)
            frontier.sort()
        return order


@dataclasses.dataclass(frozen=True)
class Replica:
    """One schedulable unit: ``group`` replicas of ``op`` scheduled together."""

    op: str                    # logical operator name
    index: int                 # replica-group index within the operator
    group: int                 # number of fused replicas (compression, >=1)
    spec: OperatorSpec

    @property
    def uid(self) -> str:
        return f"{self.op}#{self.index}"


class ExecutionGraph:
    """Replica-level DAG produced from (logical graph, replication levels).

    ``parallelism[name]`` is the replication level of each logical operator.
    ``compress_ratio`` fuses up to that many replicas into one unit
    (heuristic 3); the last unit of an operator may be smaller.

    ``routes`` optionally supplies the compiled routing table
    (:class:`repro.streaming.routing.RoutingTable`, duck-typed here to keep
    the planning core standalone): when given, replica-level edge weights
    come from ``routes.unit_weight`` so the planner models exactly the
    partition strategy and per-stream selectivity the runtime and the DES
    execute.  Without it, edges fall back to the logical graph's
    selectivities under shuffle semantics.
    """

    def __init__(self, logical: LogicalGraph, parallelism: Dict[str, int],
                 compress_ratio: int = 1, routes=None):
        assert compress_ratio >= 1
        self.logical = logical
        self.parallelism = dict(parallelism)
        self.compress_ratio = compress_ratio
        self.routes = routes
        self.replicas: List[Replica] = []
        self._by_op: Dict[str, List[int]] = {}
        for name in logical.topo_order():
            k = self.parallelism.get(name, 1)
            assert k >= 1
            groups = _split_groups(k, compress_ratio)
            idxs = []
            for gi, gsize in enumerate(groups):
                idxs.append(len(self.replicas))
                self.replicas.append(
                    Replica(name, gi, gsize, logical.operators[name]))
            self._by_op[name] = idxs
        # Replica-level edges: producer unit u routes sel(u,v) output tuples
        # per processed input, split over consumer units by group weight
        # (shuffle partitioning).  Edge weight = sel * group_v / k_v, i.e. the
        # tuples arriving at unit v per tuple *processed* by unit u.
        self.edges: List[Tuple[int, int, float]] = []   # (u, v, weight)
        self.in_edges: Dict[int, List[Tuple[int, float]]] = {
            i: [] for i in range(len(self.replicas))}
        self.out_edges: Dict[int, List[Tuple[int, float]]] = {
            i: [] for i in range(len(self.replicas))}
        for pu, cv in logical.edges:
            k_c = self.parallelism.get(cv, 1)
            sel = logical.sel(pu, cv)
            for ui in self._by_op[pu]:
                for vi in self._by_op[cv]:
                    if routes is not None:
                        w = routes.unit_weight(pu, cv,
                                               self.replicas[vi].group, k_c)
                    else:
                        w = sel * self.replicas[vi].group / k_c
                    self.edges.append((ui, vi, w))
                    self.in_edges[vi].append((ui, w))
                    self.out_edges[ui].append((vi, w))

    # -- convenience ------------------------------------------------------
    def units_of(self, op: str) -> List[int]:
        return self._by_op[op]

    @property
    def n_units(self) -> int:
        return len(self.replicas)

    def total_threads(self) -> int:
        return sum(r.group for r in self.replicas)

    def topo_unit_order(self) -> List[int]:
        order: List[int] = []
        for name in self.logical.topo_order():
            order.extend(self._by_op[name])
        return order

    def sink_units(self) -> List[int]:
        return [i for name in self.logical.sinks() for i in self._by_op[name]]

    def spout_units(self) -> List[int]:
        return [i for name in self.logical.spouts() for i in self._by_op[name]]


def _split_groups(k: int, ratio: int) -> List[int]:
    """Split k replicas into ceil(k/ratio) units of size <= ratio."""
    n_units = math.ceil(k / ratio)
    base, rem = divmod(k, n_units)
    return [base + (1 if i < rem else 0) for i in range(n_units)]
