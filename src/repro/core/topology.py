"""Machine topology specifications (paper Table 1, "machine specific" rows).

A :class:`MachineSpec` abstracts a set of *locality domains* ("sockets" in the
paper).  Each domain has compute capacity ``C`` (utilisation units — one unit is
one fully-busy execution context, i.e. a core on the paper's servers or a chip
in a TPU pod), local memory bandwidth ``B`` (bytes/s), and pairwise remote
channel bandwidth ``Q[i][j]`` (bytes/s) / worst-case access latency ``L[i][j]``
(seconds).  ``S`` is the transfer granule (cache-line bytes on CPU; DMA chunk
on TPU — see DESIGN.md §2 hardware-adaptation notes).

Two concrete families are provided:

* ``server_a()`` / ``server_b()`` — the paper's two eight-socket machines
  (Table 2), used by the reproduction benchmarks.
* ``tpu_pod_spec()`` — multi-pod TPU topologies where a "socket" is a pod (or
  an ICI sub-torus), used by :mod:`repro.core.autoshard`.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

NS = 1e-9
GB = 1e9


@dataclasses.dataclass(frozen=True)
class MachineSpec:
    """Hardware model consumed by the performance model and the optimizer."""

    name: str
    n_sockets: int
    cores_per_socket: int          # C, in utilisation units per socket
    local_bw: float                # B, bytes/s attainable from local DRAM/HBM
    Q: np.ndarray                  # (n, n) remote channel bandwidth, bytes/s
    L: np.ndarray                  # (n, n) worst-case access latency, seconds
    cache_line: int = 64           # S, bytes per transfer granule
    ghz: float = 1.0               # clock, used only for cycle<->sec conversions

    def __post_init__(self):
        assert self.Q.shape == (self.n_sockets, self.n_sockets)
        assert self.L.shape == (self.n_sockets, self.n_sockets)

    @property
    def total_cores(self) -> int:
        return self.n_sockets * self.cores_per_socket

    def distance_tiers(self) -> np.ndarray:
        """Integer tier per socket pair (0=local) — used for symmetry collapse."""
        _, inv = np.unique(np.round(self.L / NS, 3), return_inverse=True)
        return inv.reshape(self.L.shape)

    def fetch_time(self, i: int, j: int, n_bytes: float) -> float:
        """T^f for one tuple of ``n_bytes`` fetched by a consumer on socket j
        from a producer on socket i (paper Formula 2)."""
        if i == j:
            return 0.0
        return float(np.ceil(n_bytes / self.cache_line) * self.L[i, j])


def _two_tray_matrices(n: int, local: float, one_hop: float, max_hop: float,
                       tray: int = 4) -> np.ndarray:
    """Paper servers: 8 sockets in 2 trays of 4; same-tray=1 hop, cross=max."""
    m = np.full((n, n), max_hop)
    for i in range(n):
        for j in range(n):
            if i == j:
                m[i, j] = local
            elif i // tray == j // tray:
                m[i, j] = one_hop
    return m


def server_a() -> MachineSpec:
    """HUAWEI KunLun (Server A, Table 2): 8x18 Xeon E7-8890 @1.2GHz."""
    L = _two_tray_matrices(8, 50 * NS, 307.7 * NS, 548.0 * NS)
    Q = _two_tray_matrices(8, 54.3 * GB, 13.2 * GB, 5.8 * GB)
    return MachineSpec("server_a", 8, 18, 54.3 * GB, Q, L, ghz=1.2)


def server_b() -> MachineSpec:
    """HP ProLiant DL980 G7 (Server B, Table 2): 8x8 Xeon E7-2860 @2.27GHz.

    The XNC node controller makes remote bandwidth nearly distance-invariant
    (10.6 vs 10.8 GB/s) — reproduced here.
    """
    L = _two_tray_matrices(8, 50 * NS, 185.2 * NS, 349.6 * NS)
    Q = _two_tray_matrices(8, 24.2 * GB, 10.6 * GB, 10.8 * GB)
    return MachineSpec("server_b", 8, 8, 24.2 * GB, Q, L, ghz=2.27)


def subset(spec: MachineSpec, n_sockets: int) -> MachineSpec:
    """Restrict a machine to its first ``n_sockets`` sockets (Fig. 9 scaling)."""
    assert 1 <= n_sockets <= spec.n_sockets
    return dataclasses.replace(
        spec, name=f"{spec.name}[{n_sockets}]", n_sockets=n_sockets,
        Q=spec.Q[:n_sockets, :n_sockets].copy(),
        L=spec.L[:n_sockets, :n_sockets].copy())


# --------------------------------------------------------------------------
# TPU multi-pod topologies (DESIGN.md §2).  A "socket" is a locality domain:
# a pod, or an ICI sub-torus within a pod when ``domains_per_pod > 1``.
# --------------------------------------------------------------------------

TPU_V5E_PEAK_FLOPS = 197e12     # bf16 FLOP/s per chip
TPU_V5E_HBM_BW = 819e9          # bytes/s per chip
TPU_ICI_BW = 50e9               # bytes/s per ICI link (per direction)
TPU_DCN_BW = 25e9               # bytes/s per pod-to-pod (DCN) connection
TPU_ICI_LAT = 1e-6              # ~1us per ICI hop
TPU_DCN_LAT = 10e-6             # ~10us across pods


def tpu_pod_spec(n_pods: int = 2, chips_per_pod: int = 256,
                 domains_per_pod: int = 1) -> MachineSpec:
    """Multi-pod TPU as a NUMA machine.

    Each locality domain contributes ``chips * 1.0`` utilisation units (a chip
    is a single execution context, like a core).  ``local_bw`` aggregates HBM
    over the domain; Q/L encode ICI (intra-pod) vs DCN (inter-pod) tiers.
    """
    n = n_pods * domains_per_pod
    chips = chips_per_pod // domains_per_pod
    Q = np.zeros((n, n))
    L = np.zeros((n, n))
    for i in range(n):
        for j in range(n):
            if i == j:
                Q[i, j] = chips * TPU_V5E_HBM_BW
                L[i, j] = 0.0
            elif i // domains_per_pod == j // domains_per_pod:
                # sub-tori within one pod: full ICI bisection of the slice
                Q[i, j] = chips * TPU_ICI_BW
                L[i, j] = TPU_ICI_LAT
            else:
                Q[i, j] = TPU_DCN_BW * chips / 8  # DCN NICs are scarcer
                L[i, j] = TPU_DCN_LAT
    return MachineSpec(
        name=f"tpu_{n_pods}x{chips_per_pod}",
        n_sockets=n, cores_per_socket=chips,
        local_bw=chips * TPU_V5E_HBM_BW, Q=Q, L=L,
        cache_line=512,   # DMA granule; Formula 2's S analog
        ghz=0.94)
