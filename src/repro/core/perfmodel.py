"""Rate-based NUMA-aware performance model (paper §3.1).

Given an execution graph, a machine spec and a (possibly partial) placement,
estimate every unit's input/processed/output rates, application throughput
``R = sum_sink r_o`` and the resource-constraint slack of Eq. 3–5.

Faithful elements
-----------------
* ``T(p) = T^e + T^f`` with ``T^f = ceil(N/S) * L(i,j)`` for anti-collocated
  producer/consumer pairs and 0 when collocated (Formula 2).
* Over-supplied vs under-supplied cases (Case 1/2): an over-supplied unit
  saturates at its capacity; per-producer shares are proportional to the
  corresponding input rates (FCFS mixing).
* The bounding relaxation: unplaced units are assumed collocated with all of
  their producers (``T^f = 0``), giving an optimistic completion.

Deviation (documented, see DESIGN.md §6): the paper aggregates per-producer
service times by *FCFS weighted mixing*, which makes rates non-monotonic in
input rates (a faster upstream can shift the service mix toward a slow remote
edge and *lower* downstream capacity), so the paper's bound is not a strict
upper bound in adversarial cases.  ``evaluate(..., mix="min")`` instead uses
the per-unit *minimum* service time, which restores monotonicity; the branch
and bound uses that form for provably-safe pruning, while plan evaluation
keeps the faithful weighted mix (``mix="weighted"``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .graph import ExecutionGraph
from .topology import MachineSpec

UNPLACED = -1


@dataclasses.dataclass
class PlanEval:
    """Model outputs for one (execution graph, placement) pair."""

    R: float                          # application throughput, tuples/s
    r_in: np.ndarray                  # per-unit total input rate
    processed: np.ndarray             # per-unit processed-tuple rate
    utilization: np.ndarray           # per-unit core-seconds/sec demand
    feasible: bool                    # Eq.3-5 satisfied (placed units only)
    violations: List[str]
    cpu_usage: np.ndarray             # per-socket core-seconds/sec
    mem_usage: np.ndarray             # per-socket bytes/s
    chan_usage: np.ndarray            # (n,n) cross-socket bytes/s
    bottlenecks: Dict[str, float]     # logical op -> max oversupply ratio
    over_supplied: np.ndarray         # per-unit bool
    state_usage: Optional[np.ndarray] = None  # per-socket bytes/s from
    # declared operator state (OperatorSpec.state_bytes) — the share of
    # mem_usage that managed keyed/broadcast/window state accounts for
    state_resident_bytes: Optional[np.ndarray] = None  # per-socket bytes
    # held RESIDENT by in-flight window pane batches: buffer occupancy in
    # tuples x tuple size (OperatorSpec.state_resident_tuples, shared
    # across an operator's replicas) — how much memory window buffering
    # pins on each socket.  Occupancy is rate-independent: the retired
    # wall-seconds Little's-law form priced panes, not pane batches, and
    # over-charged event-time operators by orders of magnitude.

    def summary(self) -> str:
        return (f"R={self.R:,.0f} tuples/s feasible={self.feasible} "
                f"bottlenecks={ {k: round(v, 2) for k, v in self.bottlenecks.items()} }")


def fetch_ns(spec_bytes: float, machine: MachineSpec, si: int, sj: int) -> float:
    """Formula 2 in seconds; 0 when collocated or either side unplaced."""
    if si == UNPLACED or sj == UNPLACED or si == sj:
        return 0.0
    return machine.fetch_time(si, sj, spec_bytes)


def evaluate(graph: ExecutionGraph, machine: MachineSpec,
             placement: Sequence[int], input_rate: Optional[float] = None,
             mix: str = "weighted", tf_mode: str = "relative",
             constrained_only_placed: bool = True) -> PlanEval:
    """Run the rate model over ``graph`` under ``placement``.

    placement[i] is the socket of unit i, or UNPLACED (-1).
    ``input_rate`` is I, the external ingress rate; ``None`` means unbounded
    (the paper's max-capacity configuration, §5.3).
    ``tf_mode``: 'relative' (RLAS), 'zero' (RLAS_fix(U)), 'worst' (RLAS_fix(L)).
    """
    n = graph.n_units
    placement = list(placement)
    assert len(placement) == n
    r_in = np.zeros(n)
    processed = np.zeros(n)
    util = np.zeros(n)
    over = np.zeros(n, dtype=bool)
    # per-edge processed-from-producer rate, for channel constraints
    edge_fetch: Dict[Tuple[int, int], float] = {}

    worst_lat = float(np.max(machine.L))

    def tf(u: int, v: int, nbytes: float) -> float:
        if tf_mode == "zero":
            return 0.0
        if tf_mode == "worst":
            return math.ceil(nbytes / machine.cache_line) * worst_lat
        return fetch_ns(nbytes, machine, placement[u], placement[v])

    for v in graph.topo_unit_order():
        rep = graph.replicas[v]
        te = rep.spec.exec_s
        group = rep.group
        ins = graph.in_edges[v]
        if rep.spec.is_spout:
            cap = group / te if te > 0 else math.inf
            if input_rate is None:
                share = math.inf
            else:
                k = graph.parallelism[rep.op]
                share = input_rate * group / k
            r_in[v] = share
            processed[v] = min(share, cap)
            over[v] = share > cap or input_rate is None
            util[v] = processed[v] * te
            continue
        rates = np.array([processed[u] * w for u, w in ins])
        tot_in = float(rates.sum())
        r_in[v] = tot_in
        svc = np.array([te + tf(u, v, rep.spec.tuple_bytes) for u, _ in ins])
        if tot_in <= 0:
            processed[v] = 0.0
            continue
        if mix == "weighted":
            t_mix = float((rates * svc).sum() / tot_in)
        elif mix == "min":
            t_mix = float(svc.min())
        else:
            raise ValueError(mix)
        cap = group / t_mix if t_mix > 0 else math.inf
        if tot_in > cap:
            processed[v] = cap
            over[v] = True
        else:
            processed[v] = tot_in
        util[v] = processed[v] * t_mix
        # what this unit actually pulls from each producer (Case 1 share)
        for (u, _), rate in zip(ins, rates):
            edge_fetch[(u, v)] = edge_fetch.get((u, v), 0.0) + \
                processed[v] * (rate / tot_in)

    # ---- constraints (Eq. 3-5) over placed units ------------------------
    ns = machine.n_sockets
    cpu = np.zeros(ns)
    mem = np.zeros(ns)
    state_mem = np.zeros(ns)
    state_resident = np.zeros(ns)
    chan = np.zeros((ns, ns))
    violations: List[str] = []
    for v in range(n):
        s = placement[v]
        if s == UNPLACED:
            if constrained_only_placed:
                continue
            s = 0
        rep = graph.replicas[v]
        cpu[s] += util[v]
        mem[s] += processed[v] * rep.spec.mem_bytes
        state_mem[s] += processed[v] * rep.spec.state_bytes
        # occupancy is a property of the window, not the rate.  Stream-
        # sharded buffers (event-time panes) split across the operator's
        # replicas, so a unit's share scales with group/fan-out; per-
        # replica buffers (count-window history) replicate with the group
        occ = rep.spec.state_resident_tuples * rep.spec.tuple_bytes \
            * rep.group
        if rep.spec.state_resident_shared:
            occ /= graph.parallelism[rep.op]
        state_resident[s] += occ
    for (u, v), rate in edge_fetch.items():
        su, sv = placement[u], placement[v]
        if su == UNPLACED or sv == UNPLACED or su == sv:
            continue
        chan[su, sv] += rate * graph.replicas[v].spec.tuple_bytes
    for s in range(ns):
        if cpu[s] > machine.cores_per_socket + 1e-9:
            violations.append(f"cpu@S{s}: {cpu[s]:.2f}>{machine.cores_per_socket}")
        if mem[s] > machine.local_bw * (1 + 1e-9):
            violations.append(f"mem@S{s}: {mem[s]:.2e}>{machine.local_bw:.2e}")
    for i in range(ns):
        for j in range(ns):
            if i != j and chan[i, j] > machine.Q[i, j] * (1 + 1e-9):
                violations.append(
                    f"chan S{i}->S{j}: {chan[i, j]:.2e}>{machine.Q[i, j]:.2e}")

    R = float(sum(processed[v] for v in graph.sink_units()))
    bottlenecks: Dict[str, float] = {}
    for v in range(n):
        if over[v]:
            rep = graph.replicas[v]
            cap = processed[v]
            ratio = math.inf if not np.isfinite(r_in[v]) else (
                r_in[v] / cap if cap > 0 else math.inf)
            bottlenecks[rep.op] = max(bottlenecks.get(rep.op, 0.0), ratio)
    return PlanEval(R=R, r_in=r_in, processed=processed, utilization=util,
                    feasible=not violations, violations=violations,
                    cpu_usage=cpu, mem_usage=mem, chan_usage=chan,
                    bottlenecks=bottlenecks, over_supplied=over,
                    state_usage=state_mem,
                    state_resident_bytes=state_resident)


def bound_value(graph: ExecutionGraph, machine: MachineSpec,
                placement: Sequence[int],
                input_rate: Optional[float] = None,
                paper_bound: bool = False) -> float:
    """Bounding function of the B&B (§4): optimistic throughput of any
    completion of ``placement``.

    With ``paper_bound=True`` this is the paper's exact formulation (weighted
    FCFS mix, unplaced edges at T^f=0); the default uses the monotone ``min``
    mix which is a provable upper bound (see module docstring).
    """
    ev = evaluate(graph, machine, placement, input_rate,
                  mix="weighted" if paper_bound else "min")
    return ev.R
