"""Branch-and-bound placement optimization (paper §4, Algorithm 2).

Search organisation
-------------------
Units are branched in topological order, so every producer is placed before
its consumers.  Placing unit ``v`` on socket ``s`` *is* the set of collocation
decisions for all edges into ``v`` (heuristic 1 — decisions are per
producer-consumer pair; a vertex placement that touches no pending edge is
never branched).  Because the rate model is feed-forward, a placed unit's
rates are final, enabling incremental evaluation.

Heuristics (paper §4):
1. *Collocation/edge branching* — realised by the topological unit order, plus
   socket symmetry collapse: untouched sockets with identical distance tiers
   to all used sockets are interchangeable, so only one representative is
   branched ("S1 is identical to S0 ... does not need to repeatedly consider").
2. *Best-fit + redundancy elimination* — when all predecessors of the unit are
   placed (always true in our order), optionally branch only the socket(s)
   maximising the unit's own output rate, tie-broken by least remaining CPU
   resource, keeping a single child (``bestfit=True``, the paper's behaviour).
   With ``bestfit=False`` all sockets are branched best-bound-first, which is
   exhaustive and provably optimal (tested against brute force).
3. *Graph compression* — handled upstream by ``ExecutionGraph(compress_ratio)``.

Bounding function: unplaced units are assumed collocated with all producers
(``T^f = 0``); see :func:`repro.core.perfmodel.bound_value` for why the bound
uses the monotone ``min`` service aggregation.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .graph import ExecutionGraph
from .perfmodel import UNPLACED, PlanEval, evaluate, fetch_ns
from .topology import MachineSpec


@dataclasses.dataclass
class PlacementResult:
    placement: List[int]
    eval: Optional[PlanEval]
    feasible: bool
    nodes_explored: int
    exhausted: bool               # search ran to completion (vs. node budget)
    wall_s: float

    @property
    def R(self) -> float:
        return self.eval.R if self.eval is not None and self.feasible else 0.0


class _State:
    """Incremental per-node search state (copied on branch)."""

    __slots__ = ("placement", "proc_w", "proc_b", "cpu", "mem", "chan")

    def __init__(self, n_units: int, machine: MachineSpec):
        self.placement = np.full(n_units, UNPLACED, dtype=np.int64)
        self.proc_w = np.zeros(n_units)     # faithful weighted-mix rates
        self.proc_b = np.zeros(n_units)     # monotone min-mix rates (bound)
        self.cpu = np.zeros(machine.n_sockets)
        self.mem = np.zeros(machine.n_sockets)
        self.chan = np.zeros((machine.n_sockets, machine.n_sockets))

    def copy(self) -> "_State":
        st = _State.__new__(_State)
        st.placement = self.placement.copy()
        st.proc_w = self.proc_w.copy()
        st.proc_b = self.proc_b.copy()
        st.cpu = self.cpu.copy()
        st.mem = self.mem.copy()
        st.chan = self.chan.copy()
        return st


class _Search:
    def __init__(self, graph: ExecutionGraph, machine: MachineSpec,
                 input_rate: Optional[float], bestfit: bool,
                 max_nodes: int,
                 time_limit: Optional[float], tf_mode: str = "relative"):
        self.g = graph
        self.m = machine
        self.I = input_rate
        self.bestfit = bestfit
        self.max_nodes = max_nodes
        self.time_limit = time_limit
        self.tf_mode = tf_mode
        self.worst_lat = float(np.max(machine.L))
        self.order = graph.topo_unit_order()
        self.tiers = machine.distance_tiers()
        self.nodes = 0
        self.best_R = 0.0
        self.best_placement: Optional[np.ndarray] = None
        self.exhausted = True

    def _tf(self, su: int, sv: int, nbytes: float) -> float:
        """T^f under the search's capability assumption (RLAS / fix(L) / fix(U))."""
        if self.tf_mode == "zero":
            return 0.0
        if self.tf_mode == "worst":
            return math.ceil(nbytes / self.m.cache_line) * self.worst_lat
        return fetch_ns(nbytes, self.m, su, sv)

    # -- rate updates ------------------------------------------------------
    def _unit_rates(self, st: _State, v: int, socket: int
                    ) -> Tuple[float, float, float, float, List[Tuple[int, float]]]:
        """Rates of unit v if placed on ``socket`` given the placed prefix.

        Returns (processed_w, processed_b, util_w, r_in, fetch_shares)."""
        rep = self.g.replicas[v]
        te = rep.spec.exec_s
        group = rep.group
        if rep.spec.is_spout:
            cap = group / te if te > 0 else math.inf
            if self.I is None:
                share = math.inf
            else:
                share = self.I * group / self.g.parallelism[rep.op]
            p = min(share, cap)
            return p, p, p * te, share, []
        ins = self.g.in_edges[v]
        rates_w, rates_b, svcs = [], [], []
        for u, w in ins:
            rates_w.append(st.proc_w[u] * w)
            rates_b.append(st.proc_b[u] * w)
            su = st.placement[u]
            tf = self._tf(su, socket, rep.spec.tuple_bytes) \
                if socket != UNPLACED else self._tf(UNPLACED, UNPLACED,
                                                    rep.spec.tuple_bytes)
            svcs.append(te + tf)
        tot_w = sum(rates_w)
        tot_b = sum(rates_b)
        if tot_w <= 0:
            pw = 0.0
            util = 0.0
            shares: List[Tuple[int, float]] = []
        else:
            t_mix = sum(r * s for r, s in zip(rates_w, svcs)) / tot_w
            cap_w = group / t_mix if t_mix > 0 else math.inf
            pw = min(tot_w, cap_w)
            util = pw * t_mix
            shares = [(u, pw * (r / tot_w)) for (u, _), r in zip(ins, rates_w)]
        if tot_b <= 0:
            pb = 0.0
        else:
            t_min = min(svcs)
            cap_b = group / t_min if t_min > 0 else math.inf
            pb = min(tot_b, cap_b)
        return pw, pb, util, tot_w, shares

    def _apply(self, st: _State, v: int, socket: int) -> bool:
        """Place v on socket, updating usage. False if constraints violated."""
        pw, pb, util, _, shares = self._unit_rates(st, v, socket)
        rep = self.g.replicas[v]
        st.placement[v] = socket
        st.proc_w[v] = pw
        st.proc_b[v] = pb
        st.cpu[socket] += util
        st.mem[socket] += pw * rep.spec.mem_bytes
        ok = True
        if st.cpu[socket] > self.m.cores_per_socket + 1e-9:
            ok = False
        if st.mem[socket] > self.m.local_bw * (1 + 1e-9):
            ok = False
        for u, fr in shares:
            su = st.placement[u]
            if su != socket and su != UNPLACED:
                st.chan[su, socket] += fr * rep.spec.tuple_bytes
                if st.chan[su, socket] > self.m.Q[su, socket] * (1 + 1e-9):
                    ok = False
        return ok

    # -- bounding ----------------------------------------------------------
    def _bound(self, st: _State, depth: int) -> float:
        """Optimistic R: propagate min-mix rates with T^f=0 for unplaced."""
        proc = st.proc_b.copy()
        for d in range(depth, len(self.order)):
            v = self.order[d]
            rep = self.g.replicas[v]
            if rep.spec.is_spout:
                te = rep.spec.exec_s
                cap = rep.group / te if te > 0 else math.inf
                share = math.inf if self.I is None else \
                    self.I * rep.group / self.g.parallelism[rep.op]
                proc[v] = min(share, cap)
                continue
            te = rep.spec.exec_s + self._tf(UNPLACED, UNPLACED,
                                            rep.spec.tuple_bytes)
            tot = sum(proc[u] * w for u, w in self.g.in_edges[v])
            cap = rep.group / te if te > 0 else math.inf
            proc[v] = min(tot, cap)
        return float(sum(proc[v] for v in self.g.sink_units()))

    # -- candidate sockets with symmetry collapse (heuristic 1) -------------
    def _candidates(self, st: _State) -> List[int]:
        used = [s for s in range(self.m.n_sockets)
                if st.cpu[s] > 0 or st.mem[s] > 0]
        out: List[int] = []
        seen_sigs = set()
        for s in range(self.m.n_sockets):
            if s in used:
                out.append(s)
                continue
            sig = tuple(self.tiers[s, t] for t in used)
            if sig in seen_sigs:
                continue
            seen_sigs.add(sig)
            out.append(s)
        return out

    # -- main DFS ------------------------------------------------------------
    def run(self) -> PlacementResult:
        t0 = time.time()
        n = self.g.n_units
        root = _State(n, self.m)
        stack: List[Tuple[_State, int]] = [(root, 0)]
        while stack:
            if self.nodes >= self.max_nodes or (
                    self.time_limit and time.time() - t0 > self.time_limit):
                self.exhausted = False
                break
            st, depth = stack.pop()
            self.nodes += 1
            if depth == n:
                R = float(sum(st.proc_w[v] for v in self.g.sink_units()))
                if R > self.best_R:
                    self.best_R = R
                    self.best_placement = st.placement.copy()
                continue
            if self._bound(st, depth) <= self.best_R * (1 + 1e-12):
                continue
            v = self.order[depth]
            children: List[Tuple[float, float, int, _State]] = []
            for s in self._candidates(st):
                child = st.copy()
                ok = self._apply(child, v, s)
                if not ok:
                    # Rates of placed units are final (the model is
                    # feed-forward), so resource usage only grows with depth:
                    # a violated prefix can never become feasible -> exact prune.
                    continue
                bound = self._bound(child, depth + 1)
                if bound <= self.best_R * (1 + 1e-12):
                    continue
                # best-fit key: own output rate, then least remaining CPU
                remaining = self.m.cores_per_socket - child.cpu[s]
                children.append((child.proc_w[v], -remaining, s, child))
            if not children:
                continue
            children.sort(key=lambda c: (c[0], c[1]))
            if self.bestfit:
                # heuristic 2: keep only the best-fit child
                children = children[-1:]
            for _, _, _, child in children:      # best last -> popped first
                stack.append((child, depth + 1))
        placement = self.best_placement
        if placement is None:
            return PlacementResult(
                placement=[UNPLACED] * n, eval=None, feasible=False,
                nodes_explored=self.nodes, exhausted=self.exhausted,
                wall_s=time.time() - t0)
        # Final value is always reported under the *true* relative model, even
        # when the search optimized under a fixed-capability assumption
        # (RLAS_fix evaluation protocol, paper §6.4).
        ev = evaluate(self.g, self.m, list(placement), self.I, mix="weighted",
                      tf_mode="relative")
        return PlacementResult(
            placement=[int(s) for s in placement], eval=ev,
            feasible=ev.feasible, nodes_explored=self.nodes,
            exhausted=self.exhausted, wall_s=time.time() - t0)


def bnb_place(graph: ExecutionGraph, machine: MachineSpec,
              input_rate: Optional[float] = None, bestfit: bool = False,
              max_nodes: int = 200_000,
              time_limit: Optional[float] = None,
              tf_mode: str = "relative") -> PlacementResult:
    """Optimize placement of ``graph`` on ``machine`` (Algorithm 2)."""
    return _Search(graph, machine, input_rate, bestfit,
                   max_nodes, time_limit, tf_mode).run()


def brute_force_place(graph: ExecutionGraph, machine: MachineSpec,
                      input_rate: Optional[float] = None) -> PlacementResult:
    """Exhaustive reference optimizer for tests (tiny instances only)."""
    import itertools
    n = graph.n_units
    assert machine.n_sockets ** n <= 3_000_000, "instance too large"
    best_R, best_p = 0.0, None
    count = 0
    t0 = time.time()
    for combo in itertools.product(range(machine.n_sockets), repeat=n):
        count += 1
        ev = evaluate(graph, machine, list(combo), input_rate, mix="weighted")
        if ev.feasible and ev.R > best_R:
            best_R, best_p = ev.R, list(combo)
    if best_p is None:
        return PlacementResult([UNPLACED] * n, None, False, count, True,
                               time.time() - t0)
    ev = evaluate(graph, machine, best_p, input_rate, mix="weighted")
    return PlacementResult(best_p, ev, True, count, True, time.time() - t0)
