"""Batched decode server (example driver).

A bounded request queue feeds a batching loop: requests are grouped into
fixed slots (continuous-batching-lite), prompts are prefilled token-by-token
into per-slot caches, then decode steps run the whole batch in lockstep —
the streaming paper's jumbo-tuple batching applied to serving.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --requests 8
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get
from repro.launch.steps import make_decode_step
from repro.models import model_api


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (P,) int32
    max_new: int
    out: Optional[np.ndarray] = None
    latency_s: float = 0.0


def serve_batch(cfg, params, requests: List[Request], max_len: int = 256,
                greedy: bool = True, seed: int = 0):
    """Run one batch of requests to completion; returns the requests."""
    api = model_api(cfg)
    b = len(requests)
    step_fn = jax.jit(make_decode_step(cfg), donate_argnums=(1,))
    cache = api.init_cache(cfg, b, max_len=max_len)
    maxp = max(len(r.prompt) for r in requests)
    pad = np.zeros((b, maxp), np.int32)
    for i, r in enumerate(requests):
        pad[i, :len(r.prompt)] = r.prompt
    t0 = time.time()
    tok = jnp.asarray(pad[:, 0])
    outs = [[] for _ in range(b)]
    last_logits = None
    # prefill (token-by-token; each step also warms the caches)
    for t in range(maxp):
        nxt, logits, cache = step_fn(params, cache, jnp.asarray(pad[:, t]),
                                     jnp.int32(t))
        last_logits = logits
    cur = np.asarray(nxt)
    max_new = max(r.max_new for r in requests)
    for t in range(maxp, maxp + max_new):
        for i in range(b):
            outs[i].append(int(cur[i]))
        nxt, logits, cache = step_fn(params, cache, jnp.asarray(cur),
                                     jnp.int32(t))
        cur = np.asarray(nxt)
    dt = time.time() - t0
    for i, r in enumerate(requests):
        r.out = np.asarray(outs[i][:r.max_new], np.int32)
        r.latency_s = dt
    return requests, dt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    args = ap.parse_args()
    cfg = get(args.arch, smoke=True)
    api = model_api(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab, args.prompt_len,
                                    dtype=np.int32), args.max_new)
            for i in range(args.requests)]
    reqs, dt = serve_batch(cfg, params, reqs,
                           max_len=args.prompt_len + args.max_new + 1)
    toks = sum(r.max_new for r in reqs)
    print(f"[serve] {len(reqs)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s batched)")
    for r in reqs[:2]:
        print(f"  req {r.rid}: {r.out[:10]}...")


if __name__ == "__main__":
    main()
