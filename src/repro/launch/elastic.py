"""Elastic scaling / fault recovery (paper §5.3 made concrete).

On topology change (pod loss, resize), the recovery path is:

1. ``replan`` — re-run the RLAS optimizer against the *surviving* topology
   (the paper's "application needs to be re-optimized in response to
   changes"): pipeline-stage placement and DP/TP degrees are re-derived from
   the same performance model, not hand-edited.
2. ``reshard_checkpoint`` — restore the last committed checkpoint with the
   new mesh's shardings (ckpt.restore does device_put per leaf).
3. Resume the data pipeline from its checkpointed counter (deterministic
   stream ⇒ no sample loss/duplication within a committed step).

``simulate_pod_failure`` drives the whole loop in-process for tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax

from repro.core import tpu_pod_spec
from repro.core.autoshard import plan_stages
from repro.models.config import ModelConfig


@dataclasses.dataclass
class ElasticPlan:
    n_pods: int
    chips_per_pod: int
    stage_assignment: Dict[str, int]      # stage -> pod
    dp_degree: int
    est_throughput: float                 # microbatches/sec (model estimate)


def replan(cfg: ModelConfig, n_pods: int, chips_per_pod: int = 256,
           microbatch: int = 16, seq: int = 4096) -> ElasticPlan:
    """RLAS re-optimization for the surviving topology."""
    result = plan_stages(cfg, n_pods=n_pods, chips_per_pod=chips_per_pod,
                         microbatch=microbatch, seq=seq)
    return ElasticPlan(n_pods=n_pods, chips_per_pod=chips_per_pod,
                       stage_assignment=result.assignment,
                       dp_degree=result.dp_degree,
                       est_throughput=result.throughput)


def reshard_checkpoint(ckpt_dir: str, step: int, target_tree,
                       new_shardings):
    """Restore a checkpoint onto a different mesh/sharding layout."""
    from repro.ckpt import checkpoint as ckpt
    return ckpt.restore(ckpt_dir, step, target_tree,
                        shardings=new_shardings)


def simulate_pod_failure(cfg: ModelConfig, before_pods: int = 2,
                         after_pods: int = 1) -> Tuple[ElasticPlan, ElasticPlan]:
    """Plan before/after a pod loss; throughput degrades gracefully."""
    before = replan(cfg, before_pods)
    after = replan(cfg, after_pods)
    return before, after
