"""ShapeDtypeStruct stand-ins for every model input (dry-run, no allocation).

``input_specs(cfg, shape_name)`` returns the batch pytree for train/prefill
cells or the (cache, tokens, pos) pytree for decode cells, shaped per the
assigned input-shape table.  ``cell_plan`` decides applicability (long_500k
needs sub-quadratic attention; see DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import model_api
from repro.models.config import ModelConfig

SHAPES: Dict[str, Tuple[int, int, str]] = {
    # name: (seq_len, global_batch, kind)
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}

# archs allowed to run long_500k (sub-quadratic family)
LONG_OK_FAMILIES = {"ssm", "hybrid"}


def long_ok(cfg: ModelConfig) -> bool:
    return cfg.family in LONG_OK_FAMILIES or cfg.window is not None


def cell_plan(cfg: ModelConfig, shape_name: str) -> Optional[str]:
    """None if runnable, else a skip reason string."""
    if shape_name == "long_500k" and not long_ok(cfg):
        return ("pure full-attention arch: unwindowed 524288-token cache is "
                "the disallowed quadratic-family case (DESIGN.md §4)")
    return None


def _sd(shape, dtype=jnp.int32):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ModelConfig, seq: int, batch: int) -> Dict:
    if cfg.family == "vlm":
        return {"embeds": _sd((batch, seq, cfg.d_model), jnp.float32),
                "labels": _sd((batch, seq)),
                "mask": _sd((batch, seq), jnp.float32)}
    if cfg.family == "audio":
        return {"frames": _sd((batch, cfg.encoder_seq, cfg.d_model),
                              jnp.float32),
                "inputs": _sd((batch, seq)),
                "labels": _sd((batch, seq))}
    return {"inputs": _sd((batch, seq)), "labels": _sd((batch, seq))}


def decode_input_specs(cfg: ModelConfig, seq: int, batch: int):
    """(cache_specs, tokens, pos) for one serve_step."""
    api = model_api(cfg)
    if cfg.is_encdec:
        from repro.models import encdec
        cache = jax.eval_shape(
            lambda: encdec.init_cache(cfg, batch, max_len=seq))
    else:
        cache = jax.eval_shape(
            lambda: api.init_cache(cfg, batch, max_len=seq))
    return cache, _sd((batch,)), _sd((), jnp.int32)


def input_specs(cfg: ModelConfig, shape_name: str):
    seq, batch, kind = SHAPES[shape_name]
    if kind in ("train", "prefill"):
        return train_batch_specs(cfg, seq, batch)
    return decode_input_specs(cfg, seq, batch)
