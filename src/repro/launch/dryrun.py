import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run driver (deliverable e).

For every (arch x input-shape x mesh) cell: build ShapeDtypeStruct inputs,
``jax.jit(step, in_shardings, out_shardings).lower(...).compile()`` on the
production mesh, record ``memory_analysis()`` / ``cost_analysis()`` and the
collective schedule parsed from the partitioned HLO.

Scan correction (EXPERIMENTS.md §Roofline methodology): XLA cost_analysis
counts a while-loop body ONCE regardless of trip count, and layer stacks run
under ``lax.scan``.  The driver therefore additionally lowers the *period
body* (fwd+bwd for train, decode body for serve) under the same shardings and
reports   total = full_step + (n_repeats - 1) * body   for flops, bytes and
collective bytes.  sLSTM's time-scan gets an analytic recurrent-FLOPs
correction (the only non-associative recurrence in the zoo).

Usage:
  python -m repro.launch.dryrun --arch smollm-360m --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.jsonl]
Cells already present in --out are skipped (resumable sweep).
"""
import argparse
import dataclasses
import json
import re
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec
from jax.tree_util import DictKey

from repro.configs import all_archs, get
from repro.launch import shardings as SH
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import SHAPES, cell_plan, input_specs
from repro.launch.steps import (make_decode_step, make_prefill_step,
                                make_train_step)
from repro.models import model_api
from repro.models.config import ModelConfig
from repro.optim.optimizers import pick_optimizer

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(
    r"\b(f64|f32|f16|bf16|s64|s32|s16|s8|u64|u32|u16|u8|pred)\[([\d,]*)\]")
_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "s32": 4,
          "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1}


def _first_shape_bytes(line: str, op: str = None) -> float:
    """Bytes of the (possibly tuple) result shape on an HLO op line."""
    total = 0.0
    # result shape sits between '=' and the op name; tuple shapes are
    # parenthesised so we cut at the op token, not the first '('
    lhs = line.split("=", 1)
    hay = lhs[1] if len(lhs) > 1 else line
    if op is not None and f"{op}(" in hay:
        hay = hay.split(f"{op}(", 1)[0]
    else:
        hay = hay.split("(", 1)[0]
    for m in _SHAPE_RE.finditer(hay):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum result-shape bytes of every collective op, by kind.

    Uses the *partitioned* module text, so shapes are per-device; bytes are
    per-device traffic (result size ~= payload for AG/AR/A2A/CP; RS result is
    the reduced shard — we scale by the group factor conservatively below in
    roofline, not here)."""
    out = {k: 0.0 for k in COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        ls = line.strip()
        if ls.startswith("%") or ls.startswith("ROOT"):
            for kind in COLLECTIVES:
                # match ' all-reduce(' / ' all-gather(' etc as the op
                if f" {kind}(" in ls or f"= {kind}(" in ls or \
                        re.search(rf"\b{kind}(\.\d+)?\(", ls):
                    out[kind] += _first_shape_bytes(ls, kind)
                    out["count"] += 1
                    break
    return out


def _sharded_specs(tree, shards):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        tree, shards)


def _bytes_per_device(tree, shards, mesh) -> float:
    n_dev = int(np.prod(list(mesh.shape.values())))
    total = 0.0
    for leaf, sh in zip(jax.tree.leaves(tree), jax.tree.leaves(
            shards, is_leaf=lambda x: hasattr(x, "spec"))):
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        shard_frac = 1.0
        spec = sh.spec
        for dim, ax in enumerate(spec):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else ax
            for a in axes:
                shard_frac /= mesh.shape[a]
        total += n * leaf.dtype.itemsize * shard_frac
    return total


@dataclasses.dataclass
class CellResult:
    arch: str
    shape: str
    mesh: str
    status: str                     # ok | skipped | error
    reason: str = ""
    wall_s: float = 0.0
    flops: float = 0.0              # scan-corrected, whole step, all devices
    bytes_accessed: float = 0.0
    coll: Optional[Dict[str, float]] = None
    peak_bytes_per_device: float = 0.0
    param_bytes_per_device: float = 0.0
    opt_bytes_per_device: float = 0.0
    cache_bytes_per_device: float = 0.0
    n_params: float = 0.0
    n_active: float = 0.0
    optimizer: str = ""
    body_repeats: int = 0
    extra_flops: float = 0.0        # analytic corrections (sLSTM time scan)

    def to_json(self):
        return json.dumps(dataclasses.asdict(self))


def _cost(compiled) -> Dict[str, float]:
    from repro.compat import cost_analysis
    try:
        c = cost_analysis(compiled)
        return {"flops": float(c.get("flops", 0.0)),
                "bytes": float(c.get("bytes accessed", 0.0))}
    except Exception:
        return {"flops": 0.0, "bytes": 0.0}


def _memory(compiled) -> float:
    try:
        m = compiled.memory_analysis()
        return float(getattr(m, "temp_size_in_bytes", 0) +
                     getattr(m, "argument_size_in_bytes", 0) +
                     getattr(m, "output_size_in_bytes", 0) / 2)
    except Exception:
        return 0.0


def _slstm_extra_flops(cfg: ModelConfig, seq: int, batch: int) -> float:
    """Analytic recurrent FLOPs for sLSTM time-scan (counted once by XLA)."""
    n_slstm = sum(1 for mixer, _ in cfg.blocks() if mixer == "slstm")
    if n_slstm == 0:
        return 0.0
    d, h = cfg.d_model, cfg.n_heads
    dh = d // h
    per_step = 4 * h * dh * dh * 2 + 10 * d        # recurrent matvecs + gates
    return float(n_slstm * batch * (seq - 1) * per_step)


# --------------------------------------------------------------------------

def _slice_param_shards(slice_shapes, cfg, mesh, fsdp):
    """Shardings for one scan-body slice: compute the stacked spec under a
    fake ('stack', ...) path and strip the leading layer axis."""
    def one(path, leaf):
        fake = jax.ShapeDtypeStruct((1,) + leaf.shape, leaf.dtype)
        spec = SH.param_pspec((DictKey("stack"),) + path, fake, cfg, mesh,
                              fsdp)
        return NamedSharding(mesh, PartitionSpec(*tuple(spec)[1:]))
    return jax.tree_util.tree_map_with_path(one, slice_shapes)


def _slice_cache_shards(slice_shapes, cfg, mesh):
    def one(path, leaf):
        fake = jax.ShapeDtypeStruct((1,) + leaf.shape, leaf.dtype)
        spec = SH.cache_pspec((DictKey("stack"),) + path, fake, cfg, mesh)
        return NamedSharding(mesh, PartitionSpec(*tuple(spec)[1:]))
    return jax.tree_util.tree_map_with_path(one, slice_shapes)


def _stack_slice_shapes(cfg):
    from repro.models import transformer
    stack = jax.eval_shape(
        lambda k: transformer.init(k, cfg), jax.random.PRNGKey(0))["stack"]
    return jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype), stack)


def _body_x_shard(cfg, mesh, batch, extra_dims):
    """x sharding for body lowering — must mirror the full step's batch
    sharding (incl. pure_dp / seq_shard modes) or the x(n_periods-1)
    correction is computed at the wrong parallelism."""
    spec = SH.batch_pspec(mesh, batch, extra_dims, pure_dp=cfg.pure_dp)
    if cfg.seq_shard and extra_dims >= 2:
        spec = PartitionSpec(spec[0], "model",
                             *([None] * (extra_dims - 1)))
    return NamedSharding(mesh, spec)


def lower_body_train(cfg, mesh, seq, batch, fsdp, wrt="both"):
    """Lower one period super-block fwd+bwd under matching shardings.

    ``wrt="both"`` (params + activations) is used for the FLOPs/bytes
    correction.  ``wrt="x"`` is used for the *collective* correction: the
    parameter-gradient all-reduce/reduce-scatter happens ONCE per step on the
    stacked gradients (outside the layer scan) and is already present in the
    full-step HLO, so the per-layer body must not re-count it; per-layer
    activation collectives (TP psums, FSDP weight gathers) remain."""
    from repro.models import transformer
    slice_shapes = _stack_slice_shapes(cfg)
    shards = _slice_param_shards(slice_shapes, cfg, mesh, fsdp)
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x_shard = _body_x_shard(cfg, mesh, batch, 2)

    def body_loss(stack_slice, x):
        positions = jnp.arange(x.shape[1])
        for i, spec in enumerate(cfg.period):
            x, _ = transformer.block_apply(stack_slice[f"pos{i}"], x, spec,
                                           cfg, positions)
        return jnp.sum(x.astype(jnp.float32))

    grad_fn = jax.grad(body_loss, argnums=(0, 1) if wrt == "both" else (1,))
    lowered = jax.jit(grad_fn, in_shardings=(shards, x_shard)).lower(
        _sharded_specs(slice_shapes, shards),
        jax.ShapeDtypeStruct((batch, seq, cfg.d_model), dt,
                             sharding=x_shard))
    return lowered.compile()


def lower_body_prefill(cfg, mesh, seq, batch, fsdp):
    from repro.models import transformer
    slice_shapes = _stack_slice_shapes(cfg)
    shards = _slice_param_shards(slice_shapes, cfg, mesh, fsdp)
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x_shard = _body_x_shard(cfg, mesh, batch, 2)

    def body(stack_slice, x):
        positions = jnp.arange(x.shape[1])
        for i, spec in enumerate(cfg.period):
            x, _ = transformer.block_apply(stack_slice[f"pos{i}"], x, spec,
                                           cfg, positions)
        return x

    lowered = jax.jit(body, in_shardings=(shards, x_shard)).lower(
        _sharded_specs(slice_shapes, shards),
        jax.ShapeDtypeStruct((batch, seq, cfg.d_model), dt,
                             sharding=x_shard))
    return lowered.compile()


def lower_body_decode(cfg, mesh, seq, batch):
    from repro.models import transformer
    slice_shapes = _stack_slice_shapes(cfg)
    p_shards = _slice_param_shards(slice_shapes, cfg, mesh, False)
    cache_full = jax.eval_shape(
        lambda: model_api(cfg).init_cache(cfg, batch, max_len=seq))
    cache_slice = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype),
        cache_full["stack"])
    c_shards = _slice_cache_shards(cache_slice, cfg, mesh)
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x_shard = _body_x_shard(cfg, mesh, batch, 1)

    def body(stack_slice, cache_slice, x, pos):
        new_c = {}
        for i, spec in enumerate(cfg.period):
            x, new_c[f"pos{i}"] = transformer.block_decode(
                stack_slice[f"pos{i}"], x, cache_slice[f"pos{i}"], spec,
                cfg, pos)
        return x, new_c

    lowered = jax.jit(body,
                      in_shardings=(p_shards, c_shards, x_shard, None)
                      ).lower(
        _sharded_specs(slice_shapes, p_shards),
        _sharded_specs(cache_slice, c_shards),
        jax.ShapeDtypeStruct((batch, cfg.d_model), dt, sharding=x_shard),
        jax.ShapeDtypeStruct((), jnp.int32))
    return lowered.compile()


# --------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, multi_pod: bool,
             fsdp_threshold: float = 8e9) -> CellResult:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cfg = get(arch)
    t0 = time.time()
    skip = cell_plan(cfg, shape_name)
    if skip:
        return CellResult(arch, shape_name, mesh_name, "skipped", skip)
    seq, batch, kind = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_params, n_active = cfg.param_count()
    fsdp = cfg.force_fsdp or n_params > fsdp_threshold
    api = model_api(cfg)
    res = CellResult(arch, shape_name, mesh_name, "ok",
                     n_params=float(n_params), n_active=float(n_active))

    params_shapes = jax.eval_shape(lambda k: api.init(k, cfg),
                                   jax.random.PRNGKey(0))
    p_shards = SH.param_shardings(cfg, params_shapes, mesh, fsdp)
    res.param_bytes_per_device = _bytes_per_device(params_shapes, p_shards,
                                                   mesh)

    from repro.models import partitioning as part
    from repro.launch.mesh import batch_axes as _ba
    part.set_mesh(mesh, _ba(mesh))
    with mesh:
        if kind == "train":
            opt_name, optimizer = pick_optimizer(n_params, 1e-4)
            res.optimizer = opt_name
            opt_shapes = jax.eval_shape(optimizer.init, params_shapes)
            o_shards = SH.param_shardings(cfg, opt_shapes, mesh, fsdp)
            # moments mirror params; stats trees reuse the param rule per leaf
            res.opt_bytes_per_device = _bytes_per_device(opt_shapes, o_shards,
                                                         mesh)
            batch_specs = input_specs(cfg, shape_name)
            b_shards = SH.input_shardings(cfg, batch_specs, mesh)
            step = make_train_step(cfg, optimizer)
            lowered = jax.jit(
                step, in_shardings=(p_shards, o_shards, b_shards),
                donate_argnums=(0, 1)).lower(
                _sharded_specs(params_shapes, p_shards),
                _sharded_specs(opt_shapes, o_shards),
                _sharded_specs(batch_specs, b_shards))
            compiled = lowered.compile()
            cost = _cost(compiled)
            hlo = compiled.as_text()
            coll = collective_bytes(hlo)
            res.body_repeats = cfg.n_periods
            body_cost = {"flops": 0.0, "bytes": 0.0}
            body_coll = {k: 0.0 for k in coll}
            if cfg.scan_layers and cfg.n_periods > 1 and not cfg.is_encdec:
                body = lower_body_train(cfg, mesh, seq, batch, fsdp)
                body_cost = _cost(body)
                body_x = lower_body_train(cfg, mesh, seq, batch, fsdp,
                                          wrt="x")
                body_coll = collective_bytes(body_x.as_text())
            rep = max(cfg.n_periods - 1, 0)
            res.flops = cost["flops"] + rep * body_cost["flops"]
            res.bytes_accessed = cost["bytes"] + rep * body_cost["bytes"]
            res.coll = {k: coll.get(k, 0.0) + rep * body_coll.get(k, 0.0)
                        for k in coll}
            res.extra_flops = _slstm_extra_flops(cfg, seq, batch) * 3  # fwd+bwd
            res.peak_bytes_per_device = _memory(compiled)
        elif kind == "prefill":
            batch_specs = input_specs(cfg, shape_name)
            b_shards = SH.input_shardings(cfg, batch_specs, mesh)
            step = make_prefill_step(cfg)
            lowered = jax.jit(step, in_shardings=(p_shards, b_shards)).lower(
                _sharded_specs(params_shapes, p_shards),
                _sharded_specs(batch_specs, b_shards))
            compiled = lowered.compile()
            cost = _cost(compiled)
            coll = collective_bytes(compiled.as_text())
            res.body_repeats = cfg.n_periods
            body_cost = {"flops": 0.0, "bytes": 0.0}
            body_coll = {k: 0.0 for k in coll}
            if cfg.scan_layers and cfg.n_periods > 1 and not cfg.is_encdec:
                body = lower_body_prefill(cfg, mesh, seq, batch, fsdp)
                body_cost = _cost(body)
                body_coll = collective_bytes(body.as_text())
            rep = max(cfg.n_periods - 1, 0)
            res.flops = cost["flops"] + rep * body_cost["flops"]
            res.bytes_accessed = cost["bytes"] + rep * body_cost["bytes"]
            res.coll = {k: coll.get(k, 0.0) + rep * body_coll.get(k, 0.0)
                        for k in coll}
            res.extra_flops = _slstm_extra_flops(cfg, seq, batch)
            res.peak_bytes_per_device = _memory(compiled)
        else:                                     # decode
            cache_specs, tok_spec, pos_spec = input_specs(cfg, shape_name)
            c_shards = SH.cache_shardings(cfg, cache_specs, mesh)
            res.cache_bytes_per_device = _bytes_per_device(
                cache_specs, c_shards, mesh)
            t_shard = NamedSharding(mesh, SH.batch_pspec(mesh, batch, 0))
            step = make_decode_step(cfg)
            lowered = jax.jit(
                step, in_shardings=(p_shards, c_shards, t_shard, None),
                donate_argnums=(1,)).lower(
                _sharded_specs(params_shapes, p_shards),
                _sharded_specs(cache_specs, c_shards),
                jax.ShapeDtypeStruct(tok_spec.shape, tok_spec.dtype,
                                     sharding=t_shard),
                jax.ShapeDtypeStruct((), jnp.int32))
            compiled = lowered.compile()
            cost = _cost(compiled)
            coll = collective_bytes(compiled.as_text())
            res.body_repeats = cfg.n_periods
            body_cost = {"flops": 0.0, "bytes": 0.0}
            body_coll = {k: 0.0 for k in coll}
            if cfg.scan_layers and cfg.n_periods > 1 and not cfg.is_encdec:
                body = lower_body_decode(cfg, mesh, seq, batch)
                body_cost = _cost(body)
                body_coll = collective_bytes(body.as_text())
            rep = max(cfg.n_periods - 1, 0)
            res.flops = cost["flops"] + rep * body_cost["flops"]
            res.bytes_accessed = cost["bytes"] + rep * body_cost["bytes"]
            res.coll = {k: coll.get(k, 0.0) + rep * body_coll.get(k, 0.0)
                        for k in coll}
            res.extra_flops = _slstm_extra_flops(cfg, 1, batch)
            res.peak_bytes_per_device = _memory(compiled)
    part.set_mesh(None)
    res.wall_s = time.time() - t0
    return res


def lower_body_prefill(cfg, mesh, seq, batch, fsdp):
    from repro.models import transformer

    key = jax.random.PRNGKey(0)
    stack_shapes = jax.eval_shape(
        lambda k: transformer.init(k, cfg), key)["stack"]
    slice_shapes = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype), stack_shapes)
    shards = jax.tree_util.tree_map_with_path(
        lambda p, l: jax.NamedSharding(
            mesh, jax.sharding.PartitionSpec(*SH.param_pspec(
                (jax.tree_util.DictKey("stack"),
                 jax.tree_util.DictKey("pos0"),) + p,
                jax.ShapeDtypeStruct((1,) + l.shape, l.dtype),
                cfg, mesh, fsdp)[1:])),
        slice_shapes)
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x_shard = NamedSharding(mesh, SH.batch_pspec(mesh, batch, 2))

    def body(stack_slice, x):
        positions = jnp.arange(x.shape[1])
        for i, spec in enumerate(cfg.period):
            x, _ = transformer.block_apply(stack_slice[f"pos{i}"], x, spec,
                                           cfg, positions)
        return x

    lowered = jax.jit(body, in_shardings=(shards, x_shard)).lower(
        _sharded_specs(slice_shapes, shards),
        jax.ShapeDtypeStruct((batch, seq, cfg.d_model), dt,
                             sharding=x_shard))
    return lowered.compile()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = all_archs() if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    done = set()
    if args.out and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    done.add((r["arch"], r["shape"], r["mesh"]))
                except Exception:
                    pass

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                key = (arch.replace("_", "-"), shape, mesh_name)
                norm_key = (get(arch).name, shape, mesh_name)
                if args.out and (key in done or norm_key in done):
                    print(f"[skip existing] {arch} {shape} {mesh_name}")
                    continue
                print(f"[dryrun] {arch} {shape} {mesh_name} ...",
                      flush=True)
                try:
                    res = run_cell(arch, shape, mp)
                except Exception as e:
                    res = CellResult(arch, shape, mesh_name, "error",
                                     reason=f"{type(e).__name__}: {e}\n"
                                     + traceback.format_exc()[-2000:])
                res.arch = get(arch).name
                print(f"  -> {res.status} flops={res.flops:.3e} "
                      f"peak/dev={res.peak_bytes_per_device/2**30:.2f}GiB "
                      f"wall={res.wall_s:.1f}s "
                      f"{res.reason.splitlines()[0] if res.reason else ''}",
                      flush=True)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(res.to_json() + "\n")


if __name__ == "__main__":
    main()
