"""Jitted step functions shared by the trainer, server and dry-run."""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import ModelAPI, model_api
from repro.models.config import ModelConfig
from repro.optim.optimizers import Optimizer, clip_by_global_norm


def make_train_step(cfg: ModelConfig, optimizer: Optimizer,
                    clip_norm: float = 1.0) -> Callable:
    api = model_api(cfg)

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            loss, metrics = api.loss(p, batch, cfg)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        params, opt_state = optimizer.update(grads, opt_state, params)
        out = {"loss": loss, "grad_norm": gnorm}
        out.update({k: v for k, v in metrics.items()})
        return params, opt_state, out

    return train_step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    """Inference prefill: full no-grad forward, last-token logits.

    (Cache extraction happens in the step-wise serving path; prefill compute
    and memory are dominated by the forward pass lowered here.)"""
    api = model_api(cfg)

    def prefill_step(params, batch):
        if cfg.is_encdec:
            from repro.models import encdec
            enc = encdec.encode(params, batch["frames"], cfg)
            h = encdec.decode_train(params, enc, batch["inputs"], cfg)
            w = params["embed"].T
            return (h[:, -1] @ w).astype(jnp.float32)
        from repro.models import transformer
        if "embeds" in batch:
            x = batch["embeds"].astype(
                jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
        else:
            x = transformer.embed_tokens(params, batch["inputs"], cfg)
        positions = jnp.arange(x.shape[1])
        h, _ = transformer.forward(params, x, cfg, positions)
        return transformer.logits_fn(params, h[:, -1:], cfg)[:, 0]

    return prefill_step


def make_decode_step(cfg: ModelConfig) -> Callable:
    api = model_api(cfg)

    def serve_step(params, cache, tokens, pos):
        logits, cache = api.decode_step(params, cache, tokens, pos, cfg)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), logits, cache

    return serve_step
