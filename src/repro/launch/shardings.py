"""PartitionSpec rules: parameter, optimizer-state, input and cache sharding.

Layout (DESIGN.md §5):
* TP over ``model``: attention heads / MoE experts / FFN hidden / SSM inner.
* FSDP (ZeRO-3) over ``data`` for parameters + optimizer state when
  ``fsdp=True`` (the non-TP dim of each large matrix) — gathered per scanned
  layer by GSPMD.
* Batch over ``pod`` x ``data``.
* Decode caches: batch-sharded when divisible; KV heads over ``model`` when
  divisible, otherwise cache *length* over ``model`` (flash-decoding style);
  batch-1 long-context shards the length/state over every axis available.

Rules are path-based and block-type aware (the same leaf name 'wq' is an
output-sharded head projection in attention but an input-sharded d_inner
matrix in mLSTM).
"""
from __future__ import annotations

import math
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, SequenceKey

from repro.models.config import ModelConfig
from .mesh import batch_axes, fsdp_axis

NORMS = {"ln1", "ln2", "ln_x", "final_norm", "enc_norm", "norm", "q_norm",
         "kv_norm", "norm_h", "norm_e"}
REPLICATED = NORMS | {"b", "gate_bias", "dt_bias", "router", "w_gates",
                      "enc_pos", "dec_pos", "r", "wkr"}
ATTN_QKV = {"wq", "wk", "wv", "wuq", "wukv", "wdq", "wdkv"}


def _names(path) -> list:
    out = []
    for k in path:
        if isinstance(k, DictKey):
            out.append(str(k.key))
        elif isinstance(k, SequenceKey):
            out.append(f"[{k.idx}]")
    return out


def _mixer_of(names, cfg: ModelConfig) -> Optional[str]:
    if any(n in ("self", "cross", "attn") for n in names):
        return "attn"
    if "mixer" in names:
        pos = [n for n in names if n.startswith("pos")]
        if pos:
            return cfg.period[int(pos[0][3:])][0]
        return cfg.period[0][0]          # prefix / mtp block
    return None


def _divisible(mesh: Mesh, axis, size: int) -> bool:
    if axis is None:
        return True
    axes = (axis,) if isinstance(axis, str) else axis
    prod = int(np.prod([mesh.shape[a] for a in axes]))
    return size % prod == 0


def _guard(spec: Tuple, shape, mesh: Mesh) -> P:
    """Drop axes that don't divide the corresponding dim."""
    fixed = []
    for dim, ax in zip(shape, spec):
        fixed.append(ax if ax is not None and _divisible(mesh, ax, dim)
                     else (ax if ax is None else None))
    return P(*fixed)


def param_pspec(path, leaf, cfg: ModelConfig, mesh: Mesh,
                fsdp: bool) -> P:
    if cfg.pure_dp:
        return P(*([None] * leaf.ndim))
    names = _names(path)
    n = names[-1]
    f = fsdp_axis(mesh) if fsdp else None
    stacked = any(x in names for x in ("stack", "encoder", "decoder"))
    core = leaf.ndim - (1 if stacked else 0)

    def out(*spec):
        spec = (None,) * (core - len(spec)) + spec if len(spec) < core else spec
        full = ((None,) if stacked else ()) + tuple(spec)
        return _guard(full, leaf.shape, mesh)

    if n in REPLICATED or core == 0:
        # SSM per-channel vectors still shard over model when sized d_inner
        if n in ("A_log",):
            return out("model", None)
        if n in ("D",) and core == 1:
            return out("model")
        return out(*([None] * core))
    if n == "embed":
        return out("model", f)
    if n == "head":
        return out(f, "model")
    if n == "proj" and "mtp" in names:
        return out(f, "model")
    if "experts" in names:
        if n in ("gate", "up"):
            return out("model", f, None)
        if n == "down":
            return out("model", None, f)
    mixer = _mixer_of(names, cfg)
    if n in ATTN_QKV and mixer in ("attn", "mla", None):
        return out(f, "model")
    if n == "wo":
        return out("model", f)
    if n in ("gate", "up"):                      # dense MLP / shared expert
        return out(f, "model")
    if n == "down":
        return out("model", f)
    if mixer == "mamba":
        table = {"in_proj": (f, "model"), "conv": ("model", None),
                 "x_proj": ("model", None), "dt_proj": (None, "model"),
                 "A_log": ("model", None), "D": ("model",),
                 "out_proj": ("model", f)}
        if n in table:
            return out(*table[n])
    if mixer == "mlstm":
        table = {"in_proj": (f, "model"), "conv": ("model", None),
                 "wq": ("model", None), "wk": ("model", None),
                 "wv": ("model", None), "out_proj": ("model", f)}
        if n in table:
            return out(*table[n])
    if mixer == "slstm":
        table = {"w": (f, "model"), "out_proj": ("model", f)}
        if n in table:
            return out(*table[n])
    if n in ("wq", "wk", "wv"):                  # whisper enc/dec attention
        return out(f, "model")
    return out(*([None] * core))


def param_shardings(cfg: ModelConfig, tree, mesh: Mesh, fsdp: bool):
    """Tree of NamedShardings matching ``tree`` (params or shape pytree)."""
    def one(path, leaf):
        return NamedSharding(mesh, param_pspec(path, leaf, cfg, mesh, fsdp))
    return jax.tree_util.tree_map_with_path(one, tree)


# --------------------------------------------------------------------------
# Inputs and caches
# --------------------------------------------------------------------------

def batch_pspec(mesh: Mesh, batch: int, extra_dims: int = 1,
                pure_dp: bool = False) -> P:
    ba = tuple(mesh.axis_names) if pure_dp else batch_axes(mesh)
    if not _divisible(mesh, ba, batch):
        ba = batch_axes(mesh)
        if not _divisible(mesh, ba, batch):
            ba = None
    return P(ba, *([None] * extra_dims))


def input_shardings(cfg: ModelConfig, batch_tree, mesh: Mesh):
    """Shardings for a train batch of ShapeDtypeStructs."""
    def one(path, leaf):
        spec = batch_pspec(mesh, leaf.shape[0], leaf.ndim - 1,
                           pure_dp=cfg.pure_dp)
        if cfg.seq_shard and leaf.ndim >= 2 and \
                _divisible(mesh, "model", leaf.shape[1]):
            # context parallelism: tokens sharded over 'model'
            spec = P(spec[0], "model", *([None] * (leaf.ndim - 2)))
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, batch_tree)


def cache_pspec(path, leaf, cfg: ModelConfig, mesh: Mesh) -> P:
    names = _names(path)
    n = names[-1]
    # 'stack' (decoder-only) and encdec 'self'/'cross' carry a leading L dim
    stacked = "stack" in names or ("self" in names or "cross" in names)
    lead = (None,) if stacked else ()
    core_shape = leaf.shape[1:] if stacked else leaf.shape
    b = core_shape[0]
    ba = batch_axes(mesh)
    all_axes = tuple(mesh.axis_names)
    b_ok = _divisible(mesh, ba, b) and b >= int(
        np.prod([mesh.shape[a] for a in ba]))

    def guard(*spec):
        return _guard(lead + spec, leaf.shape, mesh)

    if n in ("k", "v"):                          # (B, Hkv, C, hd)
        hkv, c = core_shape[1], core_shape[2]
        if b_ok:
            if _divisible(mesh, "model", hkv):
                return guard(ba, "model", None, None)
            return guard(ba, None, "model", None)
        # batch-1 long context: shard the cache length over everything
        if _divisible(mesh, all_axes, c):
            return guard(None, None, all_axes, None)
        return guard(None, None, ("data", "model"), None)
    if n in ("c_kv", "k_rope"):                  # MLA (B, S, r)
        if b_ok:
            return guard(ba, "model", None)
        return guard(None, ("data", "model"), None)
    if n == "conv":                              # (B, K-1, di)
        if b_ok:
            return guard(ba, None, "model")
        return guard(None, None, all_axes)
    if n == "h" and len(core_shape) == 3:        # mamba (B, di, N)
        if b_ok:
            return guard(ba, "model", None)
        return guard(None, all_axes, None)
    if n == "h" and len(core_shape) == 2:        # slstm (B, D)
        if b_ok:
            return guard(ba, "model")
        return guard(None, all_axes)
    if n == "C":                                 # mLSTM (B, H, dk, dv)
        if b_ok:
            return guard(ba, None, None, "model")
        return guard(None, None, None, "model")
    if n in ("n",):                              # mLSTM (B, H, dk) | slstm
        if b_ok:
            return guard(*((ba,) + (None,) * (len(core_shape) - 1)))
        return guard(*((None,) * len(core_shape)))
    if n == "m":
        if b_ok:
            return guard(*((ba,) + (None,) * (len(core_shape) - 1)))
        return guard(*((None,) * len(core_shape)))
    if n in ("c",):                              # slstm scalars (B, D)
        if b_ok:
            return guard(ba, "model")
        return guard(None, all_axes)
    # whisper cross kv tuple leaves: (L, B, Hkv, S_enc, hd)
    if leaf.ndim == 5:
        return _guard((None, ba if b_ok else None, None, None, None),
                      leaf.shape, mesh)
    if b_ok:
        return guard(*((ba,) + (None,) * (len(core_shape) - 1)))
    return guard(*((None,) * len(core_shape)))


def cache_shardings(cfg: ModelConfig, cache_tree, mesh: Mesh):
    def one(path, leaf):
        return NamedSharding(mesh, cache_pspec(path, leaf, cfg, mesh))
    return jax.tree_util.tree_map_with_path(one, cache_tree)
