"""End-to-end trainer (example driver; runs real steps on CPU or TPU).

Wires together: config -> mesh + shardings -> data pipeline -> jitted train
step -> async checkpointing with resume.  The same path the dry-run lowers is
the path that executes here.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import checkpoint as ckpt
from repro.configs import get
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.launch import shardings as SH
from repro.launch.mesh import batch_axes, make_mesh
from repro.launch.steps import make_train_step
from repro.models import frontends, model_api
from repro.models import partitioning as part
from repro.optim.optimizers import adamw, warmup_cosine


def train(arch: str, smoke: bool = True, steps: int = 50, batch: int = 8,
          seq: int = 128, lr: float = 3e-4, ckpt_dir: Optional[str] = None,
          ckpt_every: int = 25, mesh_shape=None, log_every: int = 10,
          width_mult: int = 1, seed: int = 0):
    cfg = get(arch, smoke=smoke)
    if width_mult > 1:                          # scale toward ~100M on demand
        cfg = dataclasses.replace(
            cfg, d_model=cfg.d_model * width_mult,
            d_ff=cfg.d_ff * width_mult)
    api = model_api(cfg)
    n_dev = len(jax.devices())
    mesh = make_mesh(mesh_shape or (n_dev, 1), ("data", "model"))
    part.set_mesh(mesh, batch_axes(mesh))

    optimizer = adamw(warmup_cosine(lr, warmup=max(steps // 10, 1),
                                    total=steps))
    key = jax.random.PRNGKey(seed)
    params = api.init(key, cfg)
    opt_state = optimizer.init(params)
    p_shards = SH.param_shardings(cfg, params, mesh, fsdp=False)
    params = jax.device_put(params, p_shards)

    source = SyntheticLM(batch, seq, cfg.vocab, seed=seed)
    start_step = 0
    if ckpt_dir:
        last = ckpt.latest_step(ckpt_dir)
        if last is not None:
            opt_shards = jax.tree.map(lambda _: None, opt_state)
            (params, opt_state), extra = ckpt.restore(
                ckpt_dir, last, (params, opt_state),
                shardings=(p_shards, opt_shards))
            source.restore(extra["data"])
            start_step = last
            print(f"[train] resumed from step {last}")
    data = Prefetcher(source)
    saver = ckpt.AsyncCheckpointer()

    step_fn = jax.jit(make_train_step(cfg, optimizer),
                      donate_argnums=(0, 1))
    losses = []
    t0 = time.time()
    with mesh:
        for step in range(start_step, steps):
            raw = data.next_batch()
            b = {"inputs": jnp.asarray(raw["inputs"]),
                 "labels": jnp.asarray(raw["labels"])}
            if cfg.family == "vlm":
                emb = frontends.image_patches(
                    jax.random.fold_in(key, step), cfg, batch)
                text = params["embed"][b["inputs"][:, :seq - cfg.img_tokens]]
                b = {"embeds": jnp.concatenate(
                        [emb.astype(text.dtype), text], axis=1),
                     "labels": b["labels"]}
            elif cfg.family == "audio":
                b["frames"] = frontends.audio_frames(
                    jax.random.fold_in(key, step), cfg, batch)
            params, opt_state, metrics = step_fn(params, opt_state, b)
            losses.append(float(metrics["loss"]))
            if step % log_every == 0 or step == steps - 1:
                dt = time.time() - t0
                print(f"[train] step={step} loss={losses[-1]:.4f} "
                      f"grad_norm={float(metrics['grad_norm']):.3f} "
                      f"({dt:.1f}s)", flush=True)
            if ckpt_dir and (step + 1) % ckpt_every == 0:
                saver.save(ckpt_dir, step + 1, (params, opt_state),
                           extra={"data": source.state(),
                                  "loss": losses[-1]})
    saver.join()
    data.close()
    part.set_mesh(None)
    return {"losses": losses, "params": params, "cfg": cfg}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--full", action="store_true",
                    help="full-size config (TPU scale)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--width-mult", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args()
    out = train(args.arch, smoke=not args.full, steps=args.steps,
                batch=args.batch, seq=args.seq, lr=args.lr,
                ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                width_mult=args.width_mult)
    first = np.mean(out["losses"][:5])
    last = np.mean(out["losses"][-5:])
    print(f"[train] loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
