"""Production mesh construction (multi-pod dry-run requirement).

``make_production_mesh`` is a FUNCTION so importing this module never touches
jax device state.  The dry-run driver sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benchmarks see the real single device.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh for tests/examples (e.g. (1, 1) on one CPU device)."""
    return jax.make_mesh(shape, axes)


def batch_axes(mesh) -> Tuple[str, ...]:
    """Axes the global batch is sharded over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def fsdp_axis(mesh) -> Optional[str]:
    """Axis parameters/optimizer state are fully-sharded over (ZeRO-3)."""
    return "data" if "data" in mesh.axis_names else None


def named(mesh, *spec) -> NamedSharding:
    return NamedSharding(mesh, P(*spec))
