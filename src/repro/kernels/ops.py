"""Public kernel entry points used by the model zoo.

Every op has three interchangeable implementations:

* ``impl="ref"``    — the naive oracle from :mod:`.ref` (tests, tiny shapes);
* ``impl="jnp"``    — memory-bounded blockwise jnp (default off-TPU; this is
  what the multi-pod dry-run lowers, so compile-time memory analysis reflects
  flash-style tiling rather than materialised S^2 score matrices);
* ``impl="pallas"`` — the Pallas TPU kernels (``interpret=True`` on CPU).

The blockwise jnp path implements *causal block skipping*: for causal and
sliding-window attention, key/value blocks that are entirely masked for a
query chunk are statically sliced away, so the compiled FLOPs reflect the
~2x triangle saving (visible in ``cost_analysis`` — see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.compat import shard_map as _shard_map

from . import ref as _ref

NEG_INF = -1e30


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6,
            impl: str = "jnp") -> jax.Array:
    if impl == "pallas":
        from .rmsnorm import rmsnorm_pallas
        return rmsnorm_pallas(x, scale, eps)
    return _ref.rmsnorm_ref(x, scale, eps)


# ---------------------------------------------------------------------------
# Blockwise (flash) attention
# ---------------------------------------------------------------------------

def _attend_block(qg, kc, vc, qpos, kpos, causal, window, scale, state,
                  kv_valid=None, kv_valid_lo=None):
    """Online-softmax update for one (q chunk, kv chunk) pair.

    qg: (B, Hkv, G, Qc, D); kc/vc: (B, Hkv, Kc, D); state = (acc, m, l).
    ``kv_valid``: exclusive upper bound on valid kv positions (padding).
    """
    acc, m, l = state
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kc.astype(jnp.float32)) * scale
    mask = jnp.ones((qg.shape[3], kc.shape[2]), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    if kv_valid is not None:
        mask &= kpos[None, :] < kv_valid
    if kv_valid_lo is not None:          # traced lower bound (CP ring edges)
        mask = mask & (kpos[None, :] >= kv_valid_lo)
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    m_new = jnp.maximum(m, logits.max(axis=-1))
    corr = jnp.exp(m - m_new)
    p = jnp.exp(logits - m_new[..., None])
    l_new = l * corr + p.sum(axis=-1)
    acc_new = acc * corr[..., None] + jnp.einsum(
        "bhgqk,bhkd->bhgqd", p, vc.astype(jnp.float32))
    return acc_new, m_new, l_new


def _flash_jnp(q, k, v, causal, window, offset, scale, q_chunk, kv_chunk,
               kv_valid_lo=None):
    b, h, sq0, d = q.shape
    _, hkv, skv0, _ = k.shape
    g = h // hkv
    q_chunk = min(q_chunk, sq0)
    kv_chunk = min(kv_chunk, skv0)
    # pad ragged sequence lengths up to chunk multiples (whisper's 1500
    # frames etc); padded kv columns are masked out, padded q rows dropped
    pq = (-sq0) % q_chunk
    pkv = (-skv0) % kv_chunk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pkv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pkv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pkv), (0, 0)))
    sq, skv = sq0 + pq, skv0 + pkv
    nq = sq // q_chunk
    outs = []
    for i in range(nq):
        qg = q[:, :, i * q_chunk:(i + 1) * q_chunk].reshape(
            b, hkv, g, q_chunk, d).astype(jnp.float32)
        q_lo = offset + i * q_chunk
        q_hi = offset + (i + 1) * q_chunk - 1
        # static kv range: causal upper bound, sliding-window lower bound
        kv_end = skv if not causal else max(0, min(skv, q_hi + 1))
        kv_start = 0 if window is None else max(0, q_lo - window + 1)
        kv_start = (kv_start // kv_chunk) * kv_chunk
        kv_end = min(skv, math.ceil(kv_end / kv_chunk) * kv_chunk)
        n_blocks = (kv_end - kv_start) // kv_chunk
        if n_blocks <= 0:                     # fully-masked chunk (offset<0)
            outs.append(jnp.zeros((b, h, q_chunk, d), q.dtype))
            continue
        qpos = q_lo + jnp.arange(q_chunk)
        state = (jnp.zeros((b, hkv, g, q_chunk, d), jnp.float32),
                 jnp.full((b, hkv, g, q_chunk), -jnp.inf, jnp.float32),
                 jnp.zeros((b, hkv, g, q_chunk), jnp.float32))
        # Static python loop over kv blocks: the compiled HLO contains only
        # the blocks that survive causal/window skipping, so cost_analysis
        # reflects the true triangle/window FLOPs (lax.scan would count the
        # body once regardless of trip count).
        for j in range(n_blocks):
            base = kv_start + j * kv_chunk
            kc = k[:, :, base:base + kv_chunk]
            vc = v[:, :, base:base + kv_chunk]
            kpos = base + jnp.arange(kv_chunk)
            state = _attend_block(qg, kc, vc, qpos, kpos, causal, window,
                                  scale, state,
                                  kv_valid=skv0 if pkv else None,
                                  kv_valid_lo=kv_valid_lo)
        acc, m, l = state
        out = (acc / jnp.maximum(l, 1e-30)[..., None]).reshape(
            b, h, q_chunk, d)
        outs.append(out.astype(q.dtype))
    out = jnp.concatenate(outs, axis=2)
    return out[:, :, :sq0] if pq else out


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, window: Optional[int] = None,
                    offset: int = 0, scale: Optional[float] = None,
                    impl: str = "jnp", q_chunk: int = 512,
                    kv_chunk: int = 1024) -> jax.Array:
    """Blockwise attention with GQA, causal masking and sliding windows.

    q: (B, Hq, Sq, D); k/v: (B, Hkv, Skv, D); returns (B, Hq, Sq, D).
    ``offset``: absolute position of q[0] relative to kv[0] (prefill chunks).
    """
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    if impl == "ref":
        return _ref.attention_ref(q, k, v, causal, window, offset, scale)
    if impl == "pallas":
        from .flash_attention import flash_attention_pallas
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      offset=offset, scale=scale)
    return _flash_jnp(q, k, v, causal, window, offset, scale, q_chunk,
                      kv_chunk)


def cp_flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                       mesh, axis: str = "model", causal: bool = True,
                       window: Optional[int] = None,
                       scale: Optional[float] = None,
                       q_chunk: int = 1024, kv_chunk: int = 1024,
                       batch_axes=None) -> jax.Array:
    """Context-parallel blockwise attention (shard_map ring gather).

    q/k/v: (B, H/ Hkv, S, D) with S sharded over ``axis``.  Each shard pulls
    the ``r`` previous shards' K/V via collective-permute — r = ceil(window/L)
    for sliding windows, n-1 for full causal — and runs the blockwise kernel
    in a *relative* frame (q row 0 sits at offset r*L), so causal/window
    block skipping stays static while a traced validity bound masks the
    ring edges.  Per-shard work is uniform (striped-attention-style balance);
    the collectives are the small K/V blocks instead of activation psums
    (EXPERIMENTS.md §Perf H2).
    """
    from jax.sharding import PartitionSpec as P
    n = mesh.shape[axis]
    sq = q.shape[2]
    assert sq % n == 0
    L = sq // n
    r = n - 1 if window is None else min(n - 1, -(-window // L))
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    # batch stays sharded over the data axes inside the shard_map (leaving it
    # unsharded forces a full-batch regather on entry — observed 16x blowup)
    ba = batch_axes
    if ba is None:
        axes = [a for a in mesh.axis_names if a != axis]
        ba = tuple(axes) if axes else None
    b = q.shape[0]
    import numpy as _np
    if ba and b % int(_np.prod([mesh.shape[a] for a in ba])) != 0:
        ba = None
    spec = P(ba, None, axis, None)

    @functools.partial(_shard_map, mesh=mesh,
                       in_specs=(spec, spec, spec), out_specs=spec,
                       check_vma=False)
    def f(ql, kl, vl):
        idx = jax.lax.axis_index(axis)
        kparts, vparts = [kl], [vl]
        for step in range(1, r + 1):
            perm = [(i, i + step) for i in range(n - step)]
            kparts.insert(0, jax.lax.ppermute(kl, axis, perm))
            vparts.insert(0, jax.lax.ppermute(vl, axis, perm))
        kg = jnp.concatenate(kparts, axis=2)      # ((r+1)*L,) kv window
        vg = jnp.concatenate(vparts, axis=2)
        # relative frame: local q row j is absolute idx*L + j; extended kv
        # col c is absolute (idx-r)*L + c -> valid iff c >= (r - idx)*L
        lo = jnp.maximum((r - idx) * L, 0)
        return _flash_jnp(ql, kg, vg, causal, window, r * L, scale,
                          q_chunk, kv_chunk, kv_valid_lo=lo)

    return f(q, k, v)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     length: Optional[jax.Array] = None,
                     window: Optional[int] = None,
                     scale: Optional[float] = None,
                     impl: str = "jnp") -> jax.Array:
    """One-token attention vs. a KV cache. q: (B, Hq, D); k/v: (B, Hkv, S, D)."""
    if impl == "pallas":
        from .decode_attention import decode_attention_pallas
        return decode_attention_pallas(q, k, v, length=length, window=window,
                                       scale=scale)
    return _ref.decode_attention_ref(q, k, v, length, window, scale)


# ---------------------------------------------------------------------------
# Mamba selective scan
# ---------------------------------------------------------------------------

def mamba_scan(u: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
               C: jax.Array, D: jax.Array, h0: Optional[jax.Array] = None,
               impl: str = "jnp"):
    """Selective SSM scan. Shapes as :func:`repro.kernels.ref.mamba_scan_ref`.

    The jnp path is a `lax.scan` over time — O(T) sequential, O(1) state
    memory; the Pallas path tiles d_inner into VMEM blocks.
    Returns (y (Bt,T,d_in), h_T (Bt,d_in,N)).
    """
    if impl == "ref":
        return _ref.mamba_scan_ref(u, dt, A, B, C, D, h0)
    if impl == "pallas":
        from .mamba_scan import mamba_scan_pallas
        return mamba_scan_pallas(u, dt, A, B, C, D, h0)
    bt, t, d_in = u.shape
    n = A.shape[1]
    h_init = jnp.zeros((bt, d_in, n), jnp.float32) if h0 is None else \
        h0.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Df = D.astype(jnp.float32)

    def step(h, xs):
        ut, dtt, Bt_, Ct = xs
        da = jnp.exp(dtt[..., None] * Af[None])            # (Bt, d_in, N)
        db = dtt[..., None] * Bt_[:, None, :]              # (Bt, d_in, N)
        h = da * h + db * ut[..., None]
        y = jnp.einsum("bdn,bn->bd", h, Ct) + Df * ut
        return h, y

    xs = (jnp.moveaxis(u, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(B, 1, 0).astype(jnp.float32),
          jnp.moveaxis(C, 1, 0).astype(jnp.float32))
    h_last, ys = jax.lax.scan(step, h_init, xs)
    return jnp.moveaxis(ys, 0, 1).astype(u.dtype), h_last


def mamba_step(u, dt, A, B, C, D, h):
    """Single decode step: u/dt (Bt, d_in); B/C (Bt, N); h (Bt, d_in, N)."""
    da = jnp.exp(dt.astype(jnp.float32)[..., None] * A.astype(jnp.float32))
    db = dt.astype(jnp.float32)[..., None] * B.astype(jnp.float32)[:, None, :]
    h = da * h + db * u.astype(jnp.float32)[..., None]
    y = jnp.einsum("bdn,bn->bd", h, C.astype(jnp.float32)) \
        + D.astype(jnp.float32) * u.astype(jnp.float32)
    return y.astype(u.dtype), h
