"""Pure-jnp oracles for every kernel in this package.

These are the *semantic references*: small, obviously-correct, memory-naive.
Pallas kernels and the memory-bounded jnp fallbacks in :mod:`.ops` are tested
against these with ``assert_allclose`` over shape/dtype sweeps.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def rmsnorm_ref(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


def _mask(sq: int, skv: int, causal: bool, window: Optional[int],
          offset: int) -> jax.Array:
    """(sq, skv) boolean mask. ``offset`` = absolute position of q row 0
    minus that of kv row 0 (for caches/prefill continuation)."""
    qpos = jnp.arange(sq)[:, None] + offset
    kpos = jnp.arange(skv)[None, :]
    m = jnp.ones((sq, skv), bool)
    if causal:
        m &= kpos <= qpos
    if window is not None:
        m &= kpos > qpos - window
    return m


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True, window: Optional[int] = None,
                  offset: int = 0, scale: Optional[float] = None) -> jax.Array:
    """Naive attention. q: (B, Hq, Sq, D); k/v: (B, Hkv, Skv, D); GQA via
    head-group broadcast. Returns (B, Hq, Sq, D)."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0
    g = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    qg = q.reshape(b, hkv, g, sq, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kf) * scale
    m = _mask(sq, skv, causal, window, offset)
    logits = jnp.where(m[None, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
    return out.reshape(b, hq, sq, d).astype(q.dtype)


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         length: Optional[jax.Array] = None,
                         window: Optional[int] = None,
                         scale: Optional[float] = None) -> jax.Array:
    """Single-token attention against a KV cache.

    q: (B, Hq, D); k/v: (B, Hkv, S, D); ``length``: (B,) valid cache length
    (the new token sits at position length-1). Returns (B, Hq, D).
    """
    b, hq, d = q.shape
    _, hkv, s, _ = k.shape
    g = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    qg = q.reshape(b, hkv, g, d).astype(jnp.float32)
    logits = jnp.einsum("bhgd,bhkd->bhgk", qg, k.astype(jnp.float32)) * scale
    kpos = jnp.arange(s)[None]
    if length is None:
        length = jnp.full((b,), s, jnp.int32)
    valid = kpos < length[:, None]
    if window is not None:
        valid &= kpos > (length[:, None] - 1 - window)
    logits = jnp.where(valid[:, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgk,bhkd->bhgd", p, v.astype(jnp.float32))
    return out.reshape(b, hq, d).astype(q.dtype)


def mlp_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Tanh-MLP scoring head: L layers of ``tanh(y @ w[i])`` then a
    feature-sum score.  x: (B, D); w: (L, D, D). Returns (B,).

    The oracle for the ``streaming_inference`` app's device predictor
    (``repro.streaming.apps``): the streaming operator runs exactly
    ``jax.jit(mlp_ref)``, so its end-to-end outputs are testable against
    this un-jitted reference.
    """
    y = x.astype(jnp.float32)
    for i in range(w.shape[0]):
        y = jnp.tanh(y @ w[i].astype(jnp.float32))
    return y.sum(axis=1).astype(x.dtype)


def mamba_scan_ref(u: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                   C: jax.Array, D: jax.Array,
                   h0: Optional[jax.Array] = None):
    """Selective state-space scan (Mamba), sequential reference.

    u/dt: (Bt, T, d_in); A: (d_in, N); B/C: (Bt, T, N); D: (d_in,).
    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t * u_t ;  y_t = C_t . h_t + D u_t
    Returns (y (Bt, T, d_in), h_T (Bt, d_in, N)).
    """
    bt, t, d_in = u.shape
    n = A.shape[1]
    uf, dtf = u.astype(jnp.float32), dt.astype(jnp.float32)
    Bf, Cf = B.astype(jnp.float32), C.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    h = jnp.zeros((bt, d_in, n), jnp.float32) if h0 is None else \
        h0.astype(jnp.float32)
    ys = []
    for i in range(t):
        da = jnp.exp(dtf[:, i, :, None] * Af[None])          # (Bt, d_in, N)
        db = dtf[:, i, :, None] * Bf[:, i, None, :]          # (Bt, d_in, N)
        h = da * h + db * uf[:, i, :, None]
        y = jnp.einsum("bdn,bn->bd", h, Cf[:, i]) + D * uf[:, i]
        ys.append(y)
    return jnp.stack(ys, 1).astype(u.dtype), h
