"""Pallas TPU flash attention (causal / sliding-window / GQA).

Grid: (batch, q_heads, q_blocks, kv_blocks) — the kv axis is minor-most, so
the f32 accumulator/max/denominator scratch persists across kv iterations of
one q block (the classic TPU flash pattern).  BlockSpecs stage one
(q_block, head_dim) query tile and one (kv_block, head_dim) key/value tile
into VMEM per step; GQA maps q-head h to kv-head h // group in the index map
so repeated K/V are never materialised.

Masked-out kv blocks (beyond the causal frontier or outside the sliding
window) skip their compute via ``pl.when`` — on hardware those grid steps
cost only the (prefetch-overlapped) DMA, giving the ~2x causal saving.

Validated in ``interpret=True`` mode against ``ref.attention_ref`` (CPU has
no Mosaic backend; see tests/test_kernels_pallas.py).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: Optional[int], offset: int,
            q_blk: int, kv_blk: int, n_kv: int):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_lo = offset + i * q_blk                 # absolute position of q row 0
    kv_lo = j * kv_blk
    relevant = True
    if causal:
        relevant = jnp.asarray(kv_lo <= q_lo + q_blk - 1)
    if window is not None:
        relevant = jnp.logical_and(
            relevant, kv_lo + kv_blk - 1 > q_lo - window)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)               # (q_blk, d)
        k = k_ref[0, 0].astype(jnp.float32)               # (kv_blk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ()))) * scale       # (q_blk, kv_blk)
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 0)
        kpos = kv_lo + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
        mask = jnp.ones_like(logits, dtype=jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        logits = jnp.where(mask, logits, NEG_INF)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, logits.max(axis=-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(logits - m_new[:, None])
        l_ref[...] = l_prev * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(j == n_kv - 1)
    def _finalize():
        o_ref[0, 0, :, :] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        ).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                           causal: bool = True,
                           window: Optional[int] = None, offset: int = 0,
                           scale: Optional[float] = None,
                           q_blk: int = 256, kv_blk: int = 256,
                           interpret: bool = True) -> jax.Array:
    """q: (B, Hq, Sq, D); k/v: (B, Hkv, Skv, D) -> (B, Hq, Sq, D)."""
    b, h, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    g = h // hkv
    scale = scale if scale is not None else d ** -0.5
    q_blk = min(q_blk, sq)
    kv_blk = min(kv_blk, skv)
    assert sq % q_blk == 0 and skv % kv_blk == 0
    n_q, n_kv = sq // q_blk, skv // kv_blk
    grid = (b, h, n_q, n_kv)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, offset=offset,
        q_blk=q_blk, kv_blk=kv_blk, n_kv=n_kv)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q_blk, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, kv_blk, d),
                         lambda b_, h_, i, j: (b_, h_ // g, j, 0)),
            pl.BlockSpec((1, 1, kv_blk, d),
                         lambda b_, h_, i, j: (b_, h_ // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_blk, d),
                               lambda b_, h_, i, j: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_blk, d), jnp.float32),   # acc
            pltpu.VMEM((q_blk,), jnp.float32),     # running max
            pltpu.VMEM((q_blk,), jnp.float32),     # running denom
        ],
        interpret=interpret,
    )(q, k, v)
