"""Pallas TPU selective-scan (Mamba) kernel.

Grid: (batch, d_inner blocks).  Each grid step keeps its (d_blk, N) state
resident in VMEM and walks the time axis with ``fori_loop``, fusing the
discretisation (exp(dt*A)), state update and C-projection — the HBM traffic
is exactly one read of u/dt/B/C and one write of y (the jnp fallback
materialises (B, T, d, N) discretised terms or re-reads per chunk).

TPU adaptation note (DESIGN.md §2): the CUDA kernel in the Mamba paper tiles
over threadblocks with warp shuffles for the chunk-carry; on TPU the carry
lives in VMEM scratch across sequential time steps of one grid cell instead.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(u_ref, dt_ref, A_ref, B_ref, C_ref, D_ref, h0_ref,
            y_ref, hT_ref, h_scr, *, t_len: int):
    h_scr[...] = h0_ref[0].astype(jnp.float32)          # (d_blk, N)
    A = A_ref[...].astype(jnp.float32)                  # (d_blk, N)
    D = D_ref[...].astype(jnp.float32)                  # (d_blk,)

    def step(t, _):
        u_t = u_ref[0, t].astype(jnp.float32)           # (d_blk,)
        dt_t = dt_ref[0, t].astype(jnp.float32)         # (d_blk,)
        b_t = B_ref[0, t].astype(jnp.float32)           # (N,)
        c_t = C_ref[0, t].astype(jnp.float32)           # (N,)
        da = jnp.exp(dt_t[:, None] * A)                 # (d_blk, N)
        db = dt_t[:, None] * b_t[None, :]
        h = da * h_scr[...] + db * u_t[:, None]
        h_scr[...] = h
        y_ref[0, t, :] = (h @ c_t + D * u_t).astype(y_ref.dtype)
        return ()

    jax.lax.fori_loop(0, t_len, step, ())
    hT_ref[0, :, :] = h_scr[...].astype(hT_ref.dtype)


def mamba_scan_pallas(u: jax.Array, dt: jax.Array, A: jax.Array,
                      B: jax.Array, C: jax.Array, D: jax.Array,
                      h0: Optional[jax.Array] = None,
                      d_blk: int = 256, interpret: bool = True):
    """Shapes as ref.mamba_scan_ref. Returns (y, h_T)."""
    bt, t, d_in = u.shape
    n = A.shape[1]
    d_blk = min(d_blk, d_in)
    assert d_in % d_blk == 0
    n_d = d_in // d_blk
    if h0 is None:
        h0 = jnp.zeros((bt, d_in, n), jnp.float32)
    grid = (bt, n_d)
    kernel = functools.partial(_kernel, t_len=t)
    y, hT = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, t, d_blk), lambda b_, i: (b_, 0, i)),   # u
            pl.BlockSpec((1, t, d_blk), lambda b_, i: (b_, 0, i)),   # dt
            pl.BlockSpec((d_blk, n), lambda b_, i: (i, 0)),          # A
            pl.BlockSpec((1, t, n), lambda b_, i: (b_, 0, 0)),       # B
            pl.BlockSpec((1, t, n), lambda b_, i: (b_, 0, 0)),       # C
            pl.BlockSpec((d_blk,), lambda b_, i: (i,)),              # D
            pl.BlockSpec((1, d_blk, n), lambda b_, i: (b_, i, 0)),   # h0
        ],
        out_specs=[
            pl.BlockSpec((1, t, d_blk), lambda b_, i: (b_, 0, i)),   # y
            pl.BlockSpec((1, d_blk, n), lambda b_, i: (b_, i, 0)),   # hT
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bt, t, d_in), u.dtype),
            jax.ShapeDtypeStruct((bt, d_in, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d_blk, n), jnp.float32)],
        interpret=interpret,
    )(u, dt, A, B, C, D, h0)
    return y, hT
