"""Pallas TPU kernels for the model zoo's compute hot-spots.

Layout per kernel: <name>.py holds the pl.pallas_call + BlockSpec tiling;
ops.py is the dispatching wrapper (pallas | blockwise-jnp | ref); ref.py the
pure-jnp oracle. Kernels validate in interpret=True mode on CPU.
"""
from . import ops, ref
