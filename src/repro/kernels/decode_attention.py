"""Pallas TPU decode attention (flash-decoding style).

One new query token per sequence attends to a long KV cache.  Grid:
(batch, kv_heads, kv_blocks) with the per-head query *group* (GQA) kept
resident in VMEM scratch; kv blocks stream through VMEM with an online
softmax.  ``length`` masks the valid cache prefix; ``window`` implements the
ring-buffer sliding-window case (every slot < length valid — see
layers.attn_decode).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, kv_blk: int, n_kv: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[0]
    kv_lo = j * kv_blk

    @pl.when(kv_lo < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)               # (G, d)
        k = k_ref[0, 0].astype(jnp.float32)               # (kv_blk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ()))) * scale       # (G, kv_blk)
        kpos = kv_lo + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
        logits = jnp.where(kpos < length, logits, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, logits.max(axis=-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(logits - m_new[:, None])
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(j == n_kv - 1)
    def _finalize():
        o_ref[0, 0, :, :] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        ).astype(o_ref.dtype)


def decode_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                            length: Optional[jax.Array] = None,
                            window: Optional[int] = None,
                            scale: Optional[float] = None,
                            kv_blk: int = 512,
                            interpret: bool = True) -> jax.Array:
    """q: (B, Hq, D); k/v: (B, Hkv, S, D); length: (B,) int32 -> (B, Hq, D).

    With a ring-buffer window cache (S == window), all slots < length are
    valid, so the same masking applies.
    """
    b, h, d = q.shape
    _, hkv, s, _ = k.shape
    g = h // hkv
    scale = scale if scale is not None else d ** -0.5
    kv_blk = min(kv_blk, s)
    assert s % kv_blk == 0
    n_kv = s // kv_blk
    if length is None:
        length = jnp.full((b,), s, jnp.int32)
    qg = q.reshape(b, hkv, g, d)
    grid = (b, hkv, n_kv)
    kernel = functools.partial(_kernel, scale=scale, kv_blk=kv_blk, n_kv=n_kv)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b_, h_, j: (b_,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, d), lambda b_, h_, j: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, kv_blk, d), lambda b_, h_, j: (b_, h_, j, 0)),
            pl.BlockSpec((1, 1, kv_blk, d), lambda b_, h_, j: (b_, h_, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda b_, h_, j: (b_, h_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
        ],
        interpret=interpret,
    )(length.astype(jnp.int32), qg, k, v)
    return out.reshape(b, h, d)
