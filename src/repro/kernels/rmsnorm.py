"""Pallas TPU fused RMSNorm.

Row-tiled: each grid step normalises a (rows_blk, d) tile in VMEM — one HBM
read and one write per element (the unfused jnp path reads x twice: once for
the variance, once for the scale-multiply).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_pallas(x: jax.Array, scale: jax.Array, eps: float = 1e-6,
                   rows_blk: int = 256, interpret: bool = True) -> jax.Array:
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = x.size // d
    x2 = x.reshape(rows, d)
    rows_blk = min(rows_blk, rows)
    # pad rows to a multiple of the block
    pad = (-rows) % rows_blk
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    grid = ((rows + pad) // rows_blk,)
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=grid,
        in_specs=[pl.BlockSpec((rows_blk, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((rows_blk, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, scale)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
