"""deepseek-v3-671b [moe]: MLA, 1 shared + 256 routed experts top-8, MTP.
61L d_model=7168 128H d_ff(expert)=2048 vocab=129280 [arXiv:2412.19437; hf].
First 3 layers dense-FFN; MLA dims per the paper (q_lora 1536, kv_lora 512,
qk 128+64 rope, v 128). Full-softmax attention -> long_500k skipped."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=18432, vocab=129280, period=(("mla", "moe"),), first_k_dense=3,
    n_experts=256, top_k=8, d_expert=2048, n_shared_experts=1,
    mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    mtp=True, rope_theta=10_000.0)

SMOKE = ModelConfig(
    name="deepseek-smoke", family="moe",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=160, vocab=256, period=(("mla", "moe"),), first_k_dense=1,
    n_experts=8, top_k=2, d_expert=48, n_shared_experts=1,
    mla=True, q_lora_rank=32, kv_lora_rank=16,
    qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
    mtp=True, dtype="float32")
