"""jamba-1.5-large-398b [hybrid]: Mamba+attention 1:7 interleave, MoE 16e
top-2 on every other layer. 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536 [arXiv:2403.19887; hf].
Period of 8: attention at position 0, mamba elsewhere; MoE on odd positions.
Hybrid (9 attention layers total) -> long_500k runs."""
from repro.models.config import ModelConfig

_PERIOD = tuple(
    ("attn" if i == 0 else "mamba", "moe" if i % 2 == 1 else "mlp")
    for i in range(8))

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=24576, vocab=65536, period=_PERIOD,
    n_experts=16, top_k=2, d_expert=24576,
    ssm_state=16, ssm_conv=4, ssm_expand=2, mamba_chunk=64)

SMOKE = ModelConfig(
    name="jamba-smoke", family="hybrid",
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256, period=_PERIOD,
    n_experts=4, top_k=2, d_expert=128,
    ssm_state=4, ssm_conv=4, ssm_expand=2, mamba_chunk=8, dtype="float32")
