"""smollm-360m [dense]: llama-arch small model.
32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152
[hf:HuggingFaceTB/SmolLM-135M family; hf]. Pure full attention ->
long_500k skipped (DESIGN.md SS4)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5,
    d_ff=2560, vocab=49152, tie_embeddings=True)

SMOKE = ModelConfig(
    name="smollm-smoke", family="dense",
    n_layers=2, d_model=60, n_heads=3, n_kv_heads=1,
    d_ff=96, vocab=256, tie_embeddings=True, dtype="float32")
