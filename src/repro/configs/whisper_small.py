"""whisper-small [audio]: encoder-decoder, conv frontend STUBBED
(input_specs provides post-conv frame embeddings (B, 1500, 768)).
12+12L d_model=768 12H d_ff=3072 vocab=51865 [arXiv:2212.04356; unverified].
Enc-dec (not encoder-only) -> decode shapes lower serve_step."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab=51865, encoder_layers=12, encoder_seq=1500,
    max_seq=32768, tie_embeddings=True,
    # unroll the 12-layer stacks: enc-dec has no scan-body cost correction
    # in the dry-run, so unrolled HLO keeps the roofline FLOPs exact; large
    # attention chunks keep the unrolled blockwise HLO compile-tractable
    scan_layers=False, q_chunk=4096, kv_chunk=4096)

SMOKE = ModelConfig(
    name="whisper-smoke", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256, encoder_layers=2, encoder_seq=32,
    max_seq=64, tie_embeddings=True, dtype="float32")
