"""llava-next-mistral-7b [vlm]: mistral-7b backbone + anyres patch stub.
32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified].
Frontend is a STUB: input_specs() provides projected patch embeddings
(img_tokens=2880 = 5 anyres tiles x 576). Full attention -> long_500k
skipped."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=32000, img_tokens=2880, rope_theta=1_000_000.0)

SMOKE = ModelConfig(
    name="llava-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256, img_tokens=8, dtype="float32")
