"""Assigned architecture configs (--arch <id>).

Each module exports CONFIG (full-size, dry-run only) and SMOKE (reduced,
CPU-runnable).  ``get(name)`` resolves by id with '-' or '_' separators.
"""
from __future__ import annotations

import importlib

ARCHS = [
    "h2o_danube_1_8b", "smollm_360m", "granite_3_2b", "stablelm_3b",
    "xlstm_125m", "llava_next_mistral_7b", "jamba_1_5_large_398b",
    "whisper_small", "qwen3_moe_235b_a22b", "deepseek_v3_671b",
]


def canon(name: str) -> str:
    return name.replace("-", "_").replace(".", "_")


def get(name: str, smoke: bool = False):
    cname = canon(name)
    # hillclimb variants ("<arch>+<change>" display names or module keys)
    from . import variants as _v
    vkey = cname.replace("+", "_")
    if vkey in _v.VARIANTS:
        return _v.VARIANTS[vkey]
    mod = importlib.import_module(f"repro.configs.{cname}")
    return mod.SMOKE if smoke else mod.CONFIG


def all_archs():
    return list(ARCHS)
