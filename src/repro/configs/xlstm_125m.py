"""xlstm-125m [ssm]: alternating sLSTM + mLSTM blocks, no FFN (d_ff=0).
12L d_model=768 4H vocab=50304 [arXiv:2405.04517; unverified].
Recurrent state -> long_500k runs."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304,
    period=(("slstm", None), ("mlstm", None)),
    ssm_expand=2, ssm_conv=4, lstm_chunk=256, tie_embeddings=True)

SMOKE = ModelConfig(
    name="xlstm-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=2, n_kv_heads=2,
    d_ff=0, vocab=256,
    period=(("slstm", None), ("mlstm", None)),
    ssm_expand=2, ssm_conv=4, lstm_chunk=16, tie_embeddings=True,
    dtype="float32")
