"""h2o-danube-1.8b [dense]: llama+mistral mix with sliding-window attention.
24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000 [arXiv:2401.16818; hf].
SWA window 4096 -> the KV cache is bounded, so long_500k decode runs."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b", family="dense",
    n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8,
    d_ff=6912, vocab=32000, window=4096, rope_theta=10_000.0)

SMOKE = ModelConfig(
    name="h2o-danube-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256, window=16, dtype="float32")
