"""Hillclimb variant configs (EXPERIMENTS.md §Perf).

Each variant is one hypothesis -> change step against a baseline cell; the
dry-run sweep accepts them as ``--arch <variant>``.  Baseline configs are
never mutated — both rows stay reportable side by side.
"""
from __future__ import annotations

import dataclasses

from .h2o_danube_1_8b import CONFIG as _danube
from .qwen3_moe_235b_a22b import CONFIG as _qwen3
from .smollm_360m import CONFIG as _smollm

# H1 (smollm train/prefill, worst roofline fraction): 15 q-heads / 5 kv-heads
# don't divide the 16-way model axis -> GSPMD all-gathers K/V and replicates
# the quadratic attention einsums over the TP axis.  Pad to TPU-friendly
# 16 q / 8 kv heads (arch variant: +2.3% params, GQA group 3 -> 2).
smollm_360m_padheads = dataclasses.replace(
    _smollm, name="smollm-360m+padheads", n_heads=16, n_kv_heads=8,
    head_dim=64)

# H2 (danube prefill_32k, most collective-bound): the big all-gathers are the
# FSDP-free layer-boundary activation gathers plus kv-head gathers; larger
# attention chunks cut the number of collective-bearing boundary ops, and
# q_chunk=2048 halves the block-boundary overhead of the blockwise loop.
h2o_danube_1_8b_bigchunk = dataclasses.replace(
    _danube, name="h2o-danube-1.8b+bigchunk", q_chunk=2048, kv_chunk=2048)

# H3 (qwen3 train, MoE dispatch = the paper's cross-socket shuffle analogue):
# drop the capacity factor to 1.0 (expert FLOPs scale linearly with it) and
# keep dispatch sharded hierarchically.  Overflow drops rise slightly (the
# standard throughput/quality trade, recorded in DESIGN.md).
qwen3_moe_235b_a22b_cap1 = dataclasses.replace(
    _qwen3, name="qwen3-moe-235b-a22b+cap1", capacity_factor=1.0)

# H1 iteration 2: after head padding the gradient all-reduce of replicated
# params dominates; FSDP over 'data' converts it into per-layer weight
# all-gathers + a reduce-scatter of stacked grads.
smollm_360m_padheads_fsdp = dataclasses.replace(
    smollm_360m_padheads, name="smollm-360m+padheads+fsdp", force_fsdp=True)

# H1 iteration 3 (iteration 2 refuted): the residual collectives are TP
# activation psums; a 371M model doesn't need TP at all on 256 chips.
# Pure DP = batch over both mesh axes, params replicated -- the paper's
# "right-size the resources" insight (Server B underutilization, SS6.4).
smollm_360m_padheads_dp = dataclasses.replace(
    smollm_360m_padheads, name="smollm-360m+padheads+puredp", pure_dp=True)

# H2 (danube prefill_32k): per-layer TP activation all-reduces (2 x 671MB
# f32) dwarf the kv gathers.  danube is 1.8B -> weights fit replicated;
# context parallelism (sequence over 'model', batch over 'data') removes the
# TP psums entirely and leaves only the small K/V gathers.
h2o_danube_1_8b_seqp = dataclasses.replace(
    _danube, name="h2o-danube-1.8b+seqp", pure_dp=True, seq_shard=True)

# H3 iteration 2: grouped local dispatch — align capacity slots with the 16
# data shards so dispatch moves tokens only across the expert axis
# (all-to-all shaped) instead of all-gathering every token everywhere.
qwen3_moe_235b_a22b_cap1_grouped = dataclasses.replace(
    qwen3_moe_235b_a22b_cap1, name="qwen3-moe-235b-a22b+cap1+grouped",
    moe_dispatch_groups=16)

# H3 iteration 3: the combine scatter accumulates in f32; top-k<=8 partial
# sums tolerate bf16 accumulation (standard practice) and halve the
# dispatch-side traffic that still dominates after grouping.
qwen3_moe_235b_a22b_cg_bf16 = dataclasses.replace(
    qwen3_moe_235b_a22b_cap1_grouped,
    name="qwen3-moe-235b-a22b+cap1+grouped+bf16c",
    moe_combine_dtype="bfloat16")

# H1 generalization: every sub-1B train cell shows the TP-overkill
# signature; pure DP applies wherever params + opt state fit replicated.
from .xlstm_125m import CONFIG as _xlstm
from .whisper_small import CONFIG as _whisper
xlstm_125m_puredp = dataclasses.replace(
    _xlstm, name="xlstm-125m+puredp", pure_dp=True)
whisper_small_puredp = dataclasses.replace(
    _whisper, name="whisper-small+puredp", pure_dp=True)

VARIANTS = {
    "xlstm_125m_puredp": xlstm_125m_puredp,
    "whisper_small_puredp": whisper_small_puredp,
    "qwen3_moe_235b_a22b_cg_bf16": qwen3_moe_235b_a22b_cg_bf16,
    "qwen3_moe_235b_a22b_cap1_grouped": qwen3_moe_235b_a22b_cap1_grouped,
    "h2o_danube_1_8b_seqp": h2o_danube_1_8b_seqp,
    "smollm_360m_padheads_dp": smollm_360m_padheads_dp,
    "smollm_360m_padheads_fsdp": smollm_360m_padheads_fsdp,
    "smollm_360m_padheads": smollm_360m_padheads,
    "h2o_danube_1_8b_bigchunk": h2o_danube_1_8b_bigchunk,
    "qwen3_moe_235b_a22b_cap1": qwen3_moe_235b_a22b_cap1,
}

# display names ("smollm-360m+padheads+puredp") must resolve too
for _cfg in list(VARIANTS.values()):
    _key = _cfg.name.replace("-", "_").replace(".", "_").replace("+", "_")
    VARIANTS.setdefault(_key, _cfg)
