"""The four benchmark applications (paper §6.1, Appendix B), declared through
the :class:`repro.streaming.api.Topology` builder.

Each factory returns a built :class:`StreamingApp` — logical graph, compute
kernels (operating on *jumbo batches*, arrays of tuples), spout sources,
partition declarations and *managed state* all come from one fluent
declaration, so the same object feeds planning (``Job(...).plan``), the
simulators, and the real threaded runtime.

Stateful operators declare :class:`~repro.streaming.state.StateSpec` instead
of mutating ad-hoc dicts: WC's counter and LR's account table are keyed
stores sharded exactly like their keyed routes (so replica stores union to
the single-replica store and survive a replan via
``repro.streaming.state.migrate_states``); SD's moving average is a
declarative sliding window; FD's model weights are a broadcast-replicated
table kept in sync by a dedicated model-sync stream.  The operators'
``mem_bytes`` (paper Table 1 ``M``) are *derived* from these declarations —
``tuple_bytes + state.bytes_per_tuple()`` — rather than hand-tuned.

Profile provenance: the per-tuple execution times anchor on the paper's
measurements where given — WC Splitter 1612.8 ns and Counter 612.3 ns local
(Table 3) — and on Fig. 8's qualitative statements (Parser has little
computation; BriskStream's T^e is 5–24% of Storm's) for the rest.  LR's
per-stream selectivities (paper Table 8 is not included in the text) are
plausible values documented here as assumptions; state access weights
(``item_bytes`` x reads/writes, cache-line-fraction granularity) are chosen
to reproduce the same profiled ``M`` the seed asserted as constants.
"""
from __future__ import annotations

import time

import numpy as np

from .api import StreamingApp, Topology
from .state import StateSpec, WindowSpec, segmented

__all__ = ["ALL_APPS", "StreamingApp", "word_count", "fraud_detection",
           "spike_detection", "spike_detection_eventtime",
           "spike_detection_keyed", "linear_road", "shuffle_within_skew",
           "streaming_inference", "inf_model_weights", "chain_pipeline"]


# ---------------------------------------------------------------------------
# Word Count (Fig. 2): spout -> parser -> splitter -> counter -> sink
# ---------------------------------------------------------------------------

WC_VOCAB = 4096
WC_WORDS_PER_SENTENCE = 10     # "a sentence with ten random words"


def word_count() -> StreamingApp:
    def source(batch, seed):
        rng = np.random.default_rng(seed)
        return rng.integers(0, WC_VOCAB,
                            size=(batch, WC_WORDS_PER_SENTENCE))

    def k_parser(batch, state):
        return [batch]                       # selectivity one; drops invalid

    def k_splitter(batch, state):
        return [batch.reshape(-1)]           # (B, 10) words -> (10B,)

    def k_counter(batch, state):
        counts = state.managed               # keyed store, route-sharded
        counts.add(batch, 1)
        return [counts.get(batch)]

    def k_sink(batch, state):
        state["seen"] = state.get("seen", 0) + len(batch)
        return []

    return (
        Topology("wc")
        .spout("spout", source, exec_ns=500.0, tuple_bytes=120.0)
        .op("parser", k_parser, exec_ns=350.0, tuple_bytes=120.0)
        .op("splitter", k_splitter, exec_ns=1612.8, tuple_bytes=120.0,
            mem_bytes=240.0, selectivity=10.0)
        .op("counter", k_counter, exec_ns=612.3, tuple_bytes=32.0,
            partition="key",
            state=StateSpec("keyed", item_bytes=32.0, reads_per_tuple=1,
                            writes_per_tuple=1, key_space=WC_VOCAB,
                            dtype=np.int64))
        .sink("sink", k_sink, exec_ns=100.0, tuple_bytes=32.0)
        .build())


# ---------------------------------------------------------------------------
# Fraud Detection (Fig. 18a style):
#   spout -> parser -> predictor -> sink
#   model_spout -> predictor        (broadcast model-sync stream)
# The predictor scores transactions against a weight table replicated to
# every replica; a slow second spout streams refreshed weights, broadcast so
# all replicas apply the same updates in order and stay identical.
# ---------------------------------------------------------------------------

FD_FEATURES = 16


def fd_model_weights(version: int) -> np.ndarray:
    """The version-``v`` model the sync stream publishes (deterministic)."""
    rng = np.random.default_rng(10_000 + version)
    return np.linspace(-1.0, 1.0, FD_FEATURES) * \
        (1.0 + 0.01 * rng.standard_normal(FD_FEATURES))


def fraud_detection() -> StreamingApp:
    def source(batch, seed):
        rng = np.random.default_rng(seed)
        return rng.normal(size=(batch, FD_FEATURES))

    def model_source(batch, seed):
        # model-sync stream: one refreshed weight vector per batch row,
        # rows = [version, w0..w15]; throttled — retraining is slow
        time.sleep(0.001)
        w = fd_model_weights(seed)
        return np.concatenate([[float(seed)], w])[None, :].repeat(batch, 0)

    def k_parser(batch, state):
        return [batch]

    def k_predictor(batch, state):
        table = state.managed                # broadcast-replicated weights
        if batch.ndim == 2 and batch.shape[1] == FD_FEATURES + 1:
            # a model-sync batch: apply the newest weights, emit nothing
            table.load(batch[-1, 1:], version=int(batch[-1, 0]))
            return [np.zeros(0, np.int8)]
        score = 1.0 / (1.0 + np.exp(-batch @ table.data))
        # "a signal is passed to Sink ... regardless of detection"
        return [(score > 0.5).astype(np.int8)]

    def k_sink(batch, state):
        state["seen"] = state.get("seen", 0) + len(batch)
        state["flagged"] = state.get("flagged", 0) + int(batch.sum())
        return []

    return (
        Topology("fd")
        .spout("spout", source, exec_ns=400.0, tuple_bytes=160.0)
        .op("parser", k_parser, exec_ns=300.0, tuple_bytes=160.0)
        .spout("model_spout", model_source, exec_ns=50_000.0,
               tuple_bytes=8.0 * (FD_FEATURES + 1))
        .op("predictor", k_predictor, inputs=["parser", "model_spout"],
            exec_ns=2400.0, tuple_bytes=160.0,
            partition={"model_spout": "broadcast"},
            state=StateSpec("broadcast", item_bytes=8.0 * FD_FEATURES,
                            reads_per_tuple=2.5, writes_per_tuple=0,
                            init=lambda: fd_model_weights(0)))
        .sink("sink", k_sink, exec_ns=100.0, tuple_bytes=16.0)
        .build())


# ---------------------------------------------------------------------------
# Spike Detection: spout -> parser -> moving_avg -> spike -> sink
# ---------------------------------------------------------------------------

SD_WINDOW = 16


def spike_detection() -> StreamingApp:
    def source(batch, seed):
        rng = np.random.default_rng(seed)
        return rng.normal(loc=10.0, scale=2.0, size=batch)

    def k_parser(batch, state):
        return [batch]

    def k_moving_avg(batch, state):
        vals = state.window.slide(batch)     # declared sliding window
        kernel = np.ones(SD_WINDOW) / SD_WINDOW
        avg = np.convolve(vals, kernel, mode="valid")[-len(batch):]
        return [np.stack([batch, avg], axis=1)]

    def k_spike(batch, state):
        val, avg = batch[:, 0], batch[:, 1]
        return [(np.abs(val - avg) > 0.3 * np.abs(avg) + 1e-9).astype(np.int8)]

    def k_sink(batch, state):
        state["seen"] = state.get("seen", 0) + len(batch)
        state["spikes"] = state.get("spikes", 0) + int(batch.sum())
        return []

    return (
        Topology("sd")
        .spout("spout", source, exec_ns=400.0, tuple_bytes=64.0)
        .op("parser", k_parser, exec_ns=250.0, tuple_bytes=64.0)
        .op("moving_avg", k_moving_avg, exec_ns=900.0, tuple_bytes=64.0,
            state=StateSpec("value", item_bytes=8.0, reads_per_tuple=0,
                            writes_per_tuple=0, window=WindowSpec(SD_WINDOW)))
        .op("spike", k_spike, exec_ns=350.0, tuple_bytes=64.0)
        .sink("sink", k_sink, exec_ns=100.0, tuple_bytes=16.0)
        .build())


# ---------------------------------------------------------------------------
# Linear Road (Fig. 18c style): the multi-stream, multi-spout topology.
#   spout -> dispatcher -> {avg_speed, count_vehicles, accident}
#   {avg_speed, count_vehicles} -> toll ; accident -> notification
#   hist_spout -> toll_history (keyed by vehicle id)
#   {toll, notification, toll_history} -> sink
# Assumed per-stream selectivities (Table 8 not in the provided text):
#   dispatcher->avg_speed 0.9, ->count 0.9, ->accident 0.1
#   avg_speed->toll 1.0, count->toll 1.0, accident->notification 1.0
# The historical-query stream is the benchmark's second spout: account
# balance requests arrive on their own source and are keyed to the replica
# owning that vehicle's account (LRB's "Type 2/3" queries).  The account
# table is declared keyed state, so it is sharded by the same route and can
# be migrated across replica sets on replan.
# ---------------------------------------------------------------------------

LR_VEHICLES = 512


def linear_road() -> StreamingApp:
    def source(batch, seed):
        rng = np.random.default_rng(seed)
        seg = rng.integers(0, 64, size=batch).astype(np.float64)
        speed = rng.uniform(0.0, 100.0, size=batch)
        return np.stack([seg, speed], axis=1)

    def hist_source(batch, seed):
        rng = np.random.default_rng(seed)
        vid = rng.integers(0, LR_VEHICLES, size=batch).astype(np.float64)
        day = rng.integers(1, 70, size=batch).astype(np.float64)
        return np.stack([vid, day], axis=1)

    def k_dispatcher(batch, state):
        speed = batch[:, 1]
        keep = batch[speed >= np.quantile(speed, 0.1)] if len(batch) else batch
        acc = batch[speed < 10.0]      # ~0.1 of uniform(0,100) speeds —
        return [keep, keep, acc]       # matches the declared 0.1 selectivity

    def k_avg_speed(batch, state):
        if not len(batch):
            return [batch[:, :2] if batch.ndim == 2 else batch]
        seg = batch[:, 0].astype(np.int64) % 64
        sums = np.zeros(64)
        cnts = np.zeros(64)
        np.add.at(sums, seg, batch[:, 1])
        np.add.at(cnts, seg, 1)
        avg = sums[seg] / np.maximum(cnts[seg], 1)
        return [np.stack([seg.astype(np.float64), avg], axis=1)]

    def k_count_vehicles(batch, state):
        if not len(batch):
            return [batch[:, :2] if batch.ndim == 2 else batch]
        seg = batch[:, 0].astype(np.int64) % 64
        cnt = np.bincount(seg, minlength=64)
        return [np.stack([seg.astype(np.float64),
                          cnt[seg].astype(np.float64)], axis=1)]

    def k_accident(batch, state):
        return [batch[:, :2] if batch.ndim == 2 and len(batch) else
                np.zeros((0, 2))]

    def k_toll(batch, state):
        if not len(batch):
            return [np.zeros((0,))]
        base = 2.0
        return [base + 0.1 * np.maximum(batch[:, 1] - 50.0, 0.0)]

    def k_notification(batch, state):
        return [np.ones(len(batch), np.int8)]

    def k_toll_history(batch, state):
        if not len(batch):
            return [np.zeros((0,))]
        vid = batch[:, 0].astype(np.int64) % LR_VEHICLES
        acct = state.managed           # keyed account table, route-sharded
        acct.add(vid, 0.5)             # each query accrues an assessed toll
        state["queries"] = state.get("queries", 0) + len(batch)
        return [acct.get(vid)]

    def k_sink(batch, state):
        state["seen"] = state.get("seen", 0) + len(batch)
        return []

    return (
        Topology("lr")
        .spout("spout", source, exec_ns=450.0, tuple_bytes=96.0)
        .op("dispatcher", k_dispatcher, exec_ns=400.0, tuple_bytes=96.0)
        .op("avg_speed", k_avg_speed, inputs={"dispatcher": 0.9},
            exec_ns=1100.0, tuple_bytes=96.0, mem_bytes=288.0)
        .op("count_vehicles", k_count_vehicles, inputs={"dispatcher": 0.9},
            exec_ns=800.0, tuple_bytes=96.0, mem_bytes=192.0)
        .op("accident", k_accident, inputs={"dispatcher": 0.1},
            exec_ns=700.0, tuple_bytes=96.0)
        .op("toll", k_toll, inputs=["avg_speed", "count_vehicles"],
            exec_ns=950.0, tuple_bytes=48.0, mem_bytes=144.0)
        .op("notification", k_notification, inputs=["accident"],
            exec_ns=300.0, tuple_bytes=48.0)
        .spout("hist_spout", hist_source, exec_ns=350.0, tuple_bytes=64.0)
        .op("toll_history", k_toll_history, inputs=["hist_spout"],
            exec_ns=650.0, tuple_bytes=64.0,
            partition="key", key_by=0,
            state=StateSpec("keyed", item_bytes=32.0, reads_per_tuple=2,
                            writes_per_tuple=1, key_space=LR_VEHICLES))
        .sink("sink", k_sink, inputs=["toll", "notification",
                                      "toll_history"],
              exec_ns=100.0, tuple_bytes=16.0)
        .build())


# ---------------------------------------------------------------------------
# Spike Detection, event-time variant: an out-of-order sensor stream with
# configurable skew, watermark-fired sliding panes instead of arrival-count
# history — the first benchmark user of the event-time substrate.
#   spout (event_time=col 0) -> parser -> pane_stats (time window) -> sink
# ---------------------------------------------------------------------------

SD_ET_SIZE = 64.0       # pane span, event-time ticks (1 tick per reading)
SD_ET_SLIDE = 16.0      # sliding hop
SD_ET_SKEW = 8.0        # default max out-of-orderness of the sensor stream
SD_ET_WM_EVERY = "auto"  # watermark cadence: derived from the declared
# window grid at run time (runtime.derive_watermark_every targets
# WM_TARGET_PANES released panes per mark).  The derivation lands on the
# previously hand-calibrated value — 8 batches/mark for sd_et at the bench
# batch of 256 — and adapts when batch size or window grid change, where
# the constant silently went stale (16 measured *worse* on the CI
# container: fire bursts outgrew the pipeline's queue slack).  Explicit
# int declarations remain as overrides; bench_runtime's cadence A/B
# records auto vs fixed on sd_et.


def shuffle_within_skew(ets: np.ndarray, bound: float,
                        rng: np.random.Generator) -> np.ndarray:
    """Permutation that delays each tuple by at most ``bound`` event-time
    units: sort by ``et + U(0, bound)`` (stable).  In the permuted stream a
    tuple can be preceded by tuples up to ``bound`` ticks younger, so the
    running max event time never exceeds any pending tuple's by more than
    ``bound`` — the seeded out-of-order harness behind the determinism
    tests and the SD event-time source."""
    if bound <= 0 or len(ets) < 2:
        return np.arange(len(ets))
    return np.argsort(np.asarray(ets, np.float64)
                      + rng.uniform(0.0, bound, len(ets)), kind="stable")


def spike_detection_eventtime(skew: float = SD_ET_SKEW,
                              lateness: float = None,
                              watermark_every=SD_ET_WM_EVERY
                              ) -> StreamingApp:
    """SD over an out-of-order sensor stream (event-time windows).

    ``skew`` bounds the stream's out-of-orderness (tuples are permuted
    within it, seeded); ``lateness`` is the window's lateness allowance and
    defaults to ``skew`` — the bound under which pane contents are provably
    identical to an ordered run.  The permutation is intra-batch and the
    spout emits its watermark *at* batch boundaries, so this stream never
    produces late tuples regardless of ``lateness`` (which still delays
    firing and prices the buffer); the late-drop path needs disorder that
    crosses watermark emissions — see the cross-batch straggler source in
    ``tests/test_eventtime.py`` for that harness.

    ``watermark_every`` is the declared mark cadence (batches per mark):
    the segmented pane engine fires every released pane of a mark as one
    stacked kernel call, so a coarser cadence divides the per-mark
    flush/merge/fire overhead across more tuples at the cost of pane-
    firing latency — pane *contents* are cadence-independent.  The default
    ``"auto"`` derives the cadence from the declared window grid
    (:func:`~.runtime.derive_watermark_every`); pass an int to pin it.
    """
    lateness = skew if lateness is None else lateness

    def source(batch, seed):
        rng = np.random.default_rng(seed)
        # one reading per tick; the batch's ticks follow on from the seed so
        # event time is globally increasing before the skew permutation.
        # The value distribution matches count-window SD's source exactly —
        # the bench A/B then prices only what differs: the event-time
        # column, the skew permutation and the watermark/pane machinery
        ets = np.abs(seed) * batch + np.arange(batch, dtype=np.float64)
        vals = rng.normal(loc=10.0, scale=2.0, size=batch)
        rows = np.stack([ets, vals], axis=1)
        return rows[shuffle_within_skew(ets, skew, rng)]

    def k_parser(batch, state):
        return [batch]

    @segmented
    def k_pane_stats(stack, state):
        # segmented contract: one call over ALL panes a watermark released
        # — `stack` is the stacked buffer, state.segments the boundary
        # index; reduceat over segment starts gives per-pane aggregates,
        # emitted in segment order (canonical pane order, so the output
        # bytes match driving the kernel one pane at a time)
        seg = state.segments
        vals = stack[:, 1]
        avg = np.add.reduceat(vals, seg.starts) / seg.lengths
        mx = np.maximum.reduceat(vals, seg.starts)
        ends = seg.spans[:, 1]
        return [np.stack([ends, avg, mx,
                          (mx > 1.5 * avg).astype(np.float64)], axis=1)]

    def k_sink(batch, state):
        state["seen"] = state.get("seen", 0) + len(batch)
        state["spikes"] = state.get("spikes", 0) + int(batch[:, 3].sum())
        return []

    return (
        Topology("sd_et")
        .spout("spout", source, exec_ns=400.0, tuple_bytes=64.0,
               event_time=0, watermark_every=watermark_every)
        .op("parser", k_parser, exec_ns=250.0, tuple_bytes=64.0)
        .op("pane_stats", k_pane_stats, exec_ns=900.0, tuple_bytes=64.0,
            selectivity=1.0 / SD_ET_SLIDE,   # one aggregate per slide ticks
            state=StateSpec("value", item_bytes=16.0, reads_per_tuple=0,
                            writes_per_tuple=0,
                            window=WindowSpec.time_sliding(
                                SD_ET_SIZE, SD_ET_SLIDE, lateness=lateness,
                                time_by=0)))
        .sink("sink", k_sink, exec_ns=100.0, tuple_bytes=32.0)
        .build())


# ---------------------------------------------------------------------------
# Spike Detection, keyed event-time variant: per-device spike sessions.
#   spout (event_time=col 0) -> parser -> device_stats (KEYED time window,
#   partition="key" on the device column) -> sink
# The pane unit is (device, span): each device's readings aggregate into
# that device's own pane, fired by the one merged watermark — so replicating
# device_stats over the keyed route shards panes by device ownership and the
# union of the replica panes equals the single-replica run byte for byte.
# ---------------------------------------------------------------------------

SD_KEY_DEVICES = 8      # sensor fleet size
SD_KEY_SIZE = 32.0      # session pane span, event-time ticks


def spike_detection_keyed(devices: int = SD_KEY_DEVICES,
                          skew: float = SD_ET_SKEW,
                          lateness: float = None,
                          watermark_every=SD_ET_WM_EVERY
                          ) -> StreamingApp:
    """Per-device spike sessions over an out-of-order sensor fleet.

    Each reading is ``[tick, device, value]``; ``device_stats`` declares a
    *keyed* tumbling event-time window (``WindowSpec(keyed=True)`` sharded
    by the compiled keyed route on the device column), so every fired pane
    is one device's session — the first benchmark user of keyed pane
    groups and the replication-invariance they buy.
    """
    lateness = skew if lateness is None else lateness

    def source(batch, seed):
        rng = np.random.default_rng(seed)
        ets = np.abs(seed) * batch + np.arange(batch, dtype=np.float64)
        dev = rng.integers(0, devices, size=batch).astype(np.float64)
        vals = rng.normal(loc=10.0, scale=2.0, size=batch)
        rows = np.stack([ets, dev, vals], axis=1)
        return rows[shuffle_within_skew(ets, skew, rng)]

    def k_parser(batch, state):
        return [batch]

    @segmented
    def k_device_stats(stack, state):
        # one call per watermark over every (device, span) pane released;
        # state.segments.keys carries each pane's device
        seg = state.segments
        vals = stack[:, 2]
        avg = np.add.reduceat(vals, seg.starts) / seg.lengths
        mx = np.maximum.reduceat(vals, seg.starts)
        return [np.stack([seg.spans[:, 1], seg.keys.astype(np.float64),
                          avg, mx,
                          (mx > 1.5 * avg).astype(np.float64)], axis=1)]

    def k_sink(batch, state):
        state["seen"] = state.get("seen", 0) + len(batch)
        state["spikes"] = state.get("spikes", 0) + int(batch[:, 4].sum())
        return []

    return (
        Topology("sd_key")
        .spout("spout", source, exec_ns=400.0, tuple_bytes=64.0,
               event_time=0, watermark_every=watermark_every)
        .op("parser", k_parser, exec_ns=250.0, tuple_bytes=64.0)
        .op("device_stats", k_device_stats, exec_ns=900.0, tuple_bytes=64.0,
            selectivity=devices / SD_KEY_SIZE,   # ~one pane per device/span
            partition="key", key_by=1,
            state=StateSpec("value", item_bytes=16.0, reads_per_tuple=0,
                            writes_per_tuple=0,
                            window=WindowSpec.time_tumbling(
                                SD_KEY_SIZE, lateness=lateness,
                                time_by=0, keyed=True)))
        .sink("sink", k_sink, exec_ns=100.0, tuple_bytes=40.0)
        .build())


# ---------------------------------------------------------------------------
# Streaming ML inference (ROADMAP 5a): FD's model-sync broadcast pattern
# feeding a *device* predictor — a jitted repro.kernels model scored over
# sensor batches with async dispatch, so host ingest overlaps device compute.
#   spout -> parser -> predictor (device=True) -> sink
#   model_spout -> predictor      (broadcast model-version stream)
# The predictor runs exactly jax.jit(repro.kernels.ref.mlp_ref) with the
# current model version's weight stack resident on the device (device_put,
# cached per version — per-call host->device transfer of the weights would
# swamp the dispatch window and erase the overlap win; only the small
# sensor batch crosses per call).  The model-sync stream broadcasts version
# numbers; weights derive deterministically from the version
# (inf_model_weights), so every replica loads byte-identical tables in
# version order, and model_versions=1 pins the model for deterministic
# replay (the sync-vs-async parity harness — with live updates the
# sensor/sync interleaving at the predictor queue is scheduling-dependent,
# exactly like FD).
# ---------------------------------------------------------------------------

INF_FEATURES = 32       # sensor feature dim == model width
INF_LAYERS = 4          # tanh-MLP depth


def inf_model_weights(version: int) -> np.ndarray:
    """The version-``v`` weight stack (L, D, D), deterministic."""
    rng = np.random.default_rng(77_000 + version)
    w = rng.standard_normal((INF_LAYERS, INF_FEATURES, INF_FEATURES))
    return (w / np.sqrt(INF_FEATURES)).astype(np.float32)


_INF_JIT: list = []         # lazy singleton: [jax.jit(mlp_ref)]
_INF_DEVICE_W: dict = {}    # version -> device-resident weight stack


def _inf_device_model(version: int, weights):
    """Jitted predictor + device-resident weights for one model version.
    Lazy (first call imports jax) so the module stays importable — and the
    topology declarable/plannable — on hosts without jax."""
    import jax
    if not _INF_JIT:
        from repro.kernels.ref import mlp_ref
        _INF_JIT.append(jax.jit(mlp_ref))
    w_dev = _INF_DEVICE_W.get(version)
    if w_dev is None:
        if len(_INF_DEVICE_W) >= 8:      # bound the per-version cache
            _INF_DEVICE_W.pop(next(iter(_INF_DEVICE_W)))
        w_dev = _INF_DEVICE_W[version] = jax.device_put(weights)
    return _INF_JIT[0], w_dev


def streaming_inference(model_versions: int = 8,
                        model_interval: float = 0.002,
                        dispatch_depth: int = 2) -> StreamingApp:
    """Streaming ML inference with async device dispatch.

    ``model_versions`` cycles the broadcast model-sync stream through that
    many deterministic weight versions (1 pins version 0 — idempotent
    updates, deterministic replay); ``model_interval`` throttles it
    (retraining is slow); ``dispatch_depth`` is the predictor's declared
    in-flight window (``run_app(dispatch_depth=)`` overrides for A/Bs).

    The throughput win of depth > 1 on a single-core host is *dispatch
    pipelining*: every synchronous call pays a fixed scheduler bubble
    (result wake-up + Python re-dispatch) with the XLA queue empty; keeping
    ``depth`` results in flight hides that bubble behind device compute.
    The effect is per *call*, so small jumbo batches (16–32 rows) show the
    largest relative win — the bench runs this app at batch 16.
    """

    def source(batch, seed):
        rng = np.random.default_rng(seed)
        return rng.normal(size=(batch, INF_FEATURES)).astype(np.float32)

    def model_source(batch, seed):
        # model-sync stream: one [version, layers] row per emission,
        # throttled — the weights themselves derive from the version
        time.sleep(model_interval)
        return np.array([[float(seed % model_versions),
                          float(INF_LAYERS)]])

    def k_parser(batch, state):
        return [batch]

    def k_predictor(batch, state):
        table = state.managed            # broadcast-replicated weights
        if batch.ndim == 2 and batch.shape[1] == 2:
            # a model-sync batch: load that version's weights, emit nothing
            v = int(batch[-1, 0])
            table.load(inf_model_weights(v), version=v)
            return [np.zeros(0, np.float32)]
        fn, w_dev = _inf_device_model(table.version, table.data)
        # returns the *lazy* jax array: the Executor's in-flight window
        # materializes it on retirement (async dispatch, FIFO retire)
        return [fn(batch, w_dev)]

    def k_sink(batch, state):
        state["seen"] = state.get("seen", 0) + len(batch)
        state["score"] = state.get("score", 0.0) + float(np.asarray(batch,
                                                         np.float64).sum())
        return []

    return (
        Topology("inference")
        .spout("spout", source, exec_ns=400.0,
               tuple_bytes=4.0 * INF_FEATURES)
        .op("parser", k_parser, exec_ns=250.0,
            tuple_bytes=4.0 * INF_FEATURES)
        .spout("model_spout", model_source, exec_ns=50_000.0,
               tuple_bytes=16.0)
        .op("predictor", k_predictor, inputs=["parser", "model_spout"],
            exec_ns=600.0, tuple_bytes=4.0 * INF_FEATURES,
            device=True, device_ns=2500.0, dispatch_depth=dispatch_depth,
            partition={"model_spout": "broadcast"},
            state=StateSpec(
                "broadcast",
                item_bytes=4.0 * INF_LAYERS * INF_FEATURES * INF_FEATURES,
                reads_per_tuple=1.0, writes_per_tuple=0,
                init=lambda: inf_model_weights(0)))
        .sink("sink", k_sink, exec_ns=100.0, tuple_bytes=8.0)
        .build())


# ---------------------------------------------------------------------------
# Chain pipeline: spout -> f1 -> ... -> fN -> sink, every hop 1:1 shuffle.
# The worst case for per-hop runtime overhead (queue, fan-in poll, watermark
# merge, arena lease per stage) and therefore the showcase for operator
# fusion: with fuse="auto" the whole f1..fN+sink segment collapses into one
# executor.  Stage kernels are light affine arithmetic so the hop overhead
# dominates; the sink keeps a float fingerprint so fused and unfused runs
# can be compared byte-for-byte.
# ---------------------------------------------------------------------------


def chain_pipeline(stages: int = 4) -> StreamingApp:
    def source(batch, seed):
        rng = np.random.default_rng(seed)
        return rng.normal(loc=1.0, scale=0.5, size=batch)

    def make_stage(j):
        a = 1.0 + 0.01 * j
        b = 0.1 * j

        def k_stage(batch, state):
            return [batch * a + b]
        return k_stage

    def k_sink(batch, state):
        state["seen"] = state.get("seen", 0) + len(batch)
        state["total"] = state.get("total", 0.0) + float(
            np.asarray(batch, np.float64).sum())
        return []

    t = Topology("chain").spout("spout", source, exec_ns=400.0,
                                tuple_bytes=8.0)
    prev = "spout"
    for j in range(1, stages + 1):
        name = f"f{j}"
        t = t.op(name, make_stage(j), inputs=prev, exec_ns=300.0,
                 tuple_bytes=8.0)
        prev = name
    return t.sink("sink", k_sink, inputs=prev, exec_ns=100.0,
                  tuple_bytes=8.0).build()


ALL_APPS = {"wc": word_count, "fd": fraud_detection, "sd": spike_detection,
            "sd_et": spike_detection_eventtime,
            "sd_key": spike_detection_keyed, "lr": linear_road,
            "inference": streaming_inference, "chain": chain_pipeline}
