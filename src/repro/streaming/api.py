"""Unified Topology/Job API: one declarative surface from graph construction
to RLAS planning to execution.

The paper's value is the *pipeline* — profile a topology, jointly optimize
replication + placement (RLAS, Alg. 1+2), then run the plan — and this module
is its single entry point:

* :class:`Topology` — fluent dataflow builder.  Operators declare their
  profiled spec (T^e, N, M, selectivity), their compute kernel, their inputs
  (with optional per-stream selectivity overrides, paper Table 8) and their
  *input partitioning strategy* (``"shuffle"``, ``"key"`` with an optional
  ``key_by`` extractor, or ``"broadcast"``) in one place.  Declarations
  compile into the single routing substrate (:mod:`repro.streaming.routing`)
  consumed by planner, simulators and runtime alike.
  ``build()`` validates the graph (duplicate operators, unknown endpoints,
  edges into spouts, cycles, unreachable operators) before anything runs.
* :class:`Job` — wraps a built app (or a planning-only logical graph) and
  produces execution :class:`Plan`\\ s via ``plan(machine, optimizer=...)``
  where the optimizer is RLAS (joint scaling+placement), plain B&B placement,
  or one of the paper's §6.4 baselines (first-fit / round-robin / random).
* :class:`Plan` — one plan object flows through the Table 4 protocol:
  ``estimate()`` (analytical §3.1 model), ``simulate()`` (DES or fluid
  oracle) and ``execute()`` (real threaded runtime), all returning a common
  :class:`Metrics` record so estimated vs measured numbers compare directly.
"""
from __future__ import annotations

import dataclasses
import math
import os
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Union

import numpy as np

from repro.core import (ExecutionGraph, LogicalGraph, MachineSpec,
                        OperatorSpec, bnb_place, evaluate, rlas_optimize)
from repro.core.baselines import ff_place, random_plan, rr_place

from .routing import (KeyBy, PARTITION_STRATEGIES, PartitionDecl,
                      RoutingTable, compile_routes, declares_key,
                      validate_key_extractor, validate_operator_names,
                      validate_partition_decl, validate_time_extractor)
from .state import StateSpec, WindowSpec

_UNSET = object()


class TopologyError(ValueError):
    """A topology declaration is invalid (raised at build time)."""


@dataclasses.dataclass
class StreamingApp:
    """A built streaming application: logical graph + runtime artefacts.

    ``partition`` maps a consumer operator to its declared input-partitioning
    strategy ("shuffle" unless declared otherwise); ``sources`` maps each
    spout to its generator ``(batch, seed) -> np.ndarray``.  ``make_source``
    remains the default generator for spouts without a dedicated entry.
    """

    name: str
    graph: LogicalGraph
    kernels: Dict[str, Callable]
    make_source: Optional[Callable[[int, int], np.ndarray]] = None
    partition: Dict[str, PartitionDecl] = dataclasses.field(
        default_factory=dict)
    sources: Dict[str, Callable] = dataclasses.field(default_factory=dict)
    key_by: Dict[str, KeyBy] = dataclasses.field(default_factory=dict)
    state: Dict[str, StateSpec] = dataclasses.field(default_factory=dict)
    event_time: Dict[str, KeyBy] = dataclasses.field(default_factory=dict)
    watermark_every: Dict[str, int] = dataclasses.field(default_factory=dict)
    watermark_interval: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    checkpoint_every: Optional[int] = None   # declared barrier cadence
    #: operators that opted out of operator fusion (``op(fuse=False)``) —
    #: chain detection never fuses an edge touching one of these
    no_fuse: frozenset = frozenset()

    def time_windows(self) -> Dict[str, WindowSpec]:
        """Declared event-time windows (operator -> WindowSpec) — what
        ``Plan.simulate(backend='des')`` hands the DES for pane pacing."""
        return {op: sp.window for op, sp in self.state.items()
                if sp.window is not None and sp.window.time}

    def device_ops(self) -> Dict[str, int]:
        """Declared device operators -> their dispatch depth (the async
        in-flight window; 1 == synchronous)."""
        return {n: sp.dispatch_depth
                for n, sp in self.graph.operators.items() if sp.device}

    def source_for(self, spout: str) -> Callable[[int, int], np.ndarray]:
        fn = self.sources.get(spout, self.make_source)
        if fn is None:
            raise TopologyError(f"spout {spout!r} has no source generator")
        return fn

    def routes(self, partition: Optional[Dict[str, str]] = None
               ) -> RoutingTable:
        """Compile this app's routing table (see ``streaming.routing``)."""
        return compile_routes(self, partition=partition)


@dataclasses.dataclass
class _OpDecl:
    name: str
    kernel: Optional[Callable]
    spec: OperatorSpec
    inputs: List[str]
    edge_selectivity: Dict[str, float]      # producer -> override
    partition: PartitionDecl
    source: Optional[Callable]
    key_by: Optional[KeyBy] = None
    state: Optional[StateSpec] = None
    event_time: Optional[KeyBy] = None      # spouts: event-time extractor
    watermark_every: int = 1                # spouts: mark every N batches
    watermark_interval: Optional[float] = None   # ... or every T et units
    fuse: bool = True                       # eligible for operator fusion


class Topology:
    """Fluent dataflow builder (declare -> validate -> build).

    >>> app = (Topology("wc")
    ...        .spout("spout", source, exec_ns=500, tuple_bytes=120)
    ...        .op("parser", k_parser, exec_ns=350)
    ...        .op("counter", k_counter, exec_ns=612.3, partition="key")
    ...        .sink("sink", k_sink)
    ...        .build())

    ``inputs`` defaults to the previously declared operator (linear-chain
    convenience); pass a name, a list of names, or a ``{producer: selectivity}``
    mapping for multi-stream edges with per-stream selectivity overrides.
    Forward references are allowed — validation happens in ``build()``.
    """

    def __init__(self, name: str, *, checkpoint_every: Optional[int] = None):
        self.name = name
        if checkpoint_every is not None and (
                isinstance(checkpoint_every, bool)
                or not isinstance(checkpoint_every, int)
                or checkpoint_every < 1):
            raise TopologyError(
                f"topology {name!r}: checkpoint_every must be an int >= 1 "
                f"(batches between barriers), got {checkpoint_every!r}")
        self.checkpoint_every = checkpoint_every
        self._decls: Dict[str, _OpDecl] = {}
        self._last: Optional[str] = None

    # -- declaration ------------------------------------------------------
    def spout(self, name: str,
              source: Optional[Callable[[int, int], np.ndarray]] = None, *,
              exec_ns: float, tuple_bytes: float = 64.0,
              mem_bytes: Optional[float] = None,
              selectivity: float = 1.0,
              event_time: Optional[KeyBy] = None,
              watermark_every: int = 1,
              watermark_interval: Optional[float] = None) -> "Topology":
        """Declare a source operator.  ``source(batch, seed) -> array``.

        ``event_time`` names the event-time column of the spout's output
        batches (column index or callable, same shape rule as ``key_by``).
        A spout that declares it emits *low-watermarks*: the runtime
        forwards ``max(event time emitted so far)`` along every compiled
        route, which is what fires downstream event-time window panes
        (``WindowSpec(time=True)``).

        ``watermark_every=N`` emits the mark every N batches instead of
        every batch; ``watermark_interval=T`` emits whenever the spout's
        event clock advanced by at least T event-time units since the last
        mark (declare one or the other).  ``watermark_every="auto"``
        derives the cadence at run time from the declared window grid —
        panes released per batch vs the
        :data:`~repro.streaming.runtime.WM_TARGET_PANES` target (see
        :func:`~repro.streaming.runtime.derive_watermark_every`) — so
        apps need not hand-calibrate a constant per batch size.  Each
        mark flushes the spout's buffered jumbos — a watermark never
        overtakes its tuples — so a coarser cadence amortizes flushes
        against pane-firing latency.  The defaults preserve the per-batch
        behavior, and end of stream always emits a final ``+inf`` mark."""
        try:
            if event_time is not None:
                validate_time_extractor(name, event_time)
            if watermark_every != "auto" and (
                    isinstance(watermark_every, bool) or
                    not isinstance(watermark_every, int) or
                    watermark_every < 1):
                raise ValueError(
                    f"spout {name!r}: watermark_every must be an int >= 1 "
                    f"or 'auto', got {watermark_every!r}")
            if watermark_interval is not None and \
                    not watermark_interval > 0:
                raise ValueError(
                    f"spout {name!r}: watermark_interval must be > 0, "
                    f"got {watermark_interval!r}")
            if watermark_every != 1 and watermark_interval is not None:
                raise ValueError(
                    f"spout {name!r}: declare watermark_every or "
                    "watermark_interval, not both (batch-count and "
                    "event-time cadences would race)")
            if (watermark_every != 1 or watermark_interval is not None) \
                    and event_time is None:
                raise ValueError(
                    f"spout {name!r}: a watermark cadence requires "
                    "event_time= (no event clock, no watermarks)")
        except ValueError as e:
            raise TopologyError(str(e)) from None
        self._declare(_OpDecl(
            name, None,
            OperatorSpec(name, exec_ns, tuple_bytes,
                         tuple_bytes if mem_bytes is None else mem_bytes,
                         selectivity, is_spout=True),
            inputs=[], edge_selectivity={}, partition="shuffle",
            source=source, event_time=event_time,
            watermark_every=watermark_every,
            watermark_interval=watermark_interval))
        return self

    def op(self, name: str, kernel: Optional[Callable] = None, *,
           inputs: Union[None, str, Sequence[str],
                         Mapping[str, float]] = None,
           exec_ns: float, tuple_bytes: float = 64.0,
           mem_bytes: Optional[float] = None, selectivity: float = 1.0,
           partition: PartitionDecl = "shuffle",
           key_by: Optional[KeyBy] = None,
           state: Optional[StateSpec] = None,
           device: bool = False, device_ns: float = 0.0,
           dispatch_depth: int = 1, fuse: bool = True) -> "Topology":
        """Declare an operator.  ``kernel(batch, state) -> [out_batch, ...]``
        emits one array per declared *downstream* stream, in the order the
        consumers were declared.  ``partition`` is how *this* operator's
        input streams are split over its replicas ("shuffle", "key" or
        "broadcast", or a ``{producer: strategy}`` mapping for per-stream
        strategies, e.g. a shuffled data stream plus a broadcast model-sync
        stream); ``key_by`` names the key for keyed streams — a column index
        into 2-D batches or a callable ``batch -> keys`` (default: the
        historical hash-column-0 convention).

        ``state`` declares *managed operator state*
        (:class:`~repro.streaming.state.StateSpec`): the runtime builds the
        store sharded by this operator's compiled route, the planner derives
        ``mem_bytes = tuple_bytes + state.bytes_per_tuple()`` from it, and
        ``Plan.replan`` can migrate it to a new replica set.  Declaring both
        ``state`` and a hand-tuned ``mem_bytes`` is an error — the point of
        the declaration is that the constant is derived, not asserted.

        ``device=True`` marks the kernel as a jitted JAX computation the
        Executor dispatches asynchronously: up to ``dispatch_depth`` batches
        (default 1 == synchronous) are in flight on the device while the
        host continues ingesting, and results retire strictly FIFO so
        outputs and watermark order are byte-identical to the synchronous
        path.  ``device_ns`` is the profiled per-tuple *device* compute
        time; ``exec_ns`` keeps its host-side meaning, and the planner/DES
        charge ``max(exec_ns, device_ns/dispatch_depth)`` at depth >= 2
        (overlap) instead of the serial sum.  Device operators cannot also
        be windowed/segmented-pane kernels in v1 — pane firing happens
        inside the watermark path, which must retire the in-flight window
        first.

        ``fuse=False`` opts this operator out of operator fusion (see
        ``docs/API.md`` §3e): no chain detected by ``Job.plan(fuse="auto")``
        or the backends' ``fuse="auto"`` will include it."""
        try:
            validate_partition_decl(name, partition)
            if not isinstance(fuse, bool):
                raise ValueError(
                    f"operator {name!r}: fuse must be a bool, got {fuse!r}")
            if key_by is not None:
                if not declares_key(partition):
                    raise ValueError(
                        f"operator {name!r} declares key_by but partition="
                        f"{partition!r} (key extractors require "
                        "partition='key')")
                validate_key_extractor(name, key_by)
            if isinstance(partition, Mapping):
                unknown = sorted(set(partition) -
                                 set(self._normalize_inputs(name, inputs)[0]))
                if unknown:
                    raise ValueError(
                        f"operator {name!r}: partition mapping names "
                        f"{unknown}, which are not inputs of {name!r}")
            if state is not None:
                if mem_bytes is not None:
                    raise ValueError(
                        f"operator {name!r} declares both state= and "
                        "mem_bytes=; mem_bytes is derived from the state "
                        "declaration (tuple_bytes + state.bytes_per_tuple())")
                if state.kind == "keyed" and not declares_key(partition):
                    raise ValueError(
                        f"operator {name!r} declares keyed state but "
                        f"partition={partition!r}: a keyed store is sharded "
                        "by the operator's keyed route (partition='key')")
                if state.window is not None and state.window.keyed \
                        and not declares_key(partition):
                    raise ValueError(
                        f"operator {name!r} declares keyed event-time "
                        f"panes but partition={partition!r}: pane groups "
                        "shard by the operator's compiled keyed route "
                        "(partition='key')")
            if isinstance(dispatch_depth, bool) or \
                    not isinstance(dispatch_depth, int) or dispatch_depth < 1:
                raise ValueError(
                    f"operator {name!r}: dispatch_depth must be an int >= 1,"
                    f" got {dispatch_depth!r}")
            if not device:
                if device_ns:
                    raise ValueError(
                        f"operator {name!r} declares device_ns="
                        f"{device_ns!r} without device=True (host operators"
                        " have no device compute to price)")
                if dispatch_depth != 1:
                    raise ValueError(
                        f"operator {name!r} declares dispatch_depth="
                        f"{dispatch_depth!r} without device=True (only "
                        "device kernels dispatch asynchronously)")
            else:
                if device_ns < 0:
                    raise ValueError(
                        f"operator {name!r}: device_ns must be >= 0, got "
                        f"{device_ns!r}")
                if state is not None and state.window is not None:
                    raise ValueError(
                        f"operator {name!r} declares device=True with a "
                        "windowed state: device operators cannot be "
                        "segmented-pane kernels in v1 (panes fire inside "
                        "the watermark path, which must drain the "
                        "in-flight dispatch window first)")
                if kernel is not None and getattr(kernel, "segmented",
                                                  False):
                    raise ValueError(
                        f"operator {name!r} declares device=True with a "
                        "@segmented kernel: device operators cannot be "
                        "segmented-pane kernels in v1")
        except ValueError as e:
            raise TopologyError(str(e)) from None
        state_bytes = state.bytes_per_tuple() if state is not None else 0.0
        resident = state.resident_tuples() if state is not None else 0.0
        # event-time pane buffers shard the stream across replicas; count-
        # window history is per-replica arrival position and replicates
        shared = state is None or state.window is None or state.window.time
        if state is not None:
            mem = tuple_bytes + state_bytes
        else:
            mem = tuple_bytes if mem_bytes is None else mem_bytes
        names, esel = self._normalize_inputs(name, inputs)
        self._declare(_OpDecl(
            name, kernel,
            OperatorSpec(name, exec_ns, tuple_bytes, mem, selectivity,
                         state_bytes=state_bytes,
                         state_resident_tuples=resident,
                         state_resident_shared=shared,
                         device=device, device_ns=float(device_ns),
                         dispatch_depth=dispatch_depth),
            inputs=names, edge_selectivity=esel, partition=partition,
            source=None, key_by=key_by, state=state, fuse=fuse))
        return self

    def sink(self, name: str, kernel: Optional[Callable] = None,
             **kwargs) -> "Topology":
        """Convenience alias: a sink is an operator nothing consumes."""
        kwargs.setdefault("exec_ns", 100.0)
        return self.op(name, kernel, **kwargs)

    def _normalize_inputs(self, name, inputs):
        esel: Dict[str, float] = {}
        if inputs is None:
            if self._last is None:
                raise TopologyError(
                    f"operator {name!r} has no inputs and no upstream "
                    "operator to chain from (declare a spout first)")
            names = [self._last]
        elif isinstance(inputs, str):
            names = [inputs]
        elif isinstance(inputs, Mapping):
            names = list(inputs)
            esel = {u: float(s) for u, s in inputs.items()}
        else:
            names = list(inputs)
        if not names:
            raise TopologyError(f"operator {name!r} declares an empty "
                                "input list")
        if len(set(names)) != len(names):
            raise TopologyError(f"operator {name!r} lists a duplicate input")
        return names, esel

    def _declare(self, decl: _OpDecl) -> None:
        if decl.name in self._decls:
            raise TopologyError(f"duplicate operator {decl.name!r}")
        self._decls[decl.name] = decl
        self._last = decl.name

    # -- introspection ----------------------------------------------------
    @property
    def operators(self) -> List[str]:
        return list(self._decls)

    @property
    def partition(self) -> Dict[str, PartitionDecl]:
        """Declared non-default partition strategies (consumer -> strategy
        or per-producer mapping)."""
        return {n: d.partition for n, d in self._decls.items()
                if d.partition != "shuffle"}

    @property
    def key_by(self) -> Dict[str, KeyBy]:
        """Declared key extractors (consumer -> column index or callable)."""
        return {n: d.key_by for n, d in self._decls.items()
                if d.key_by is not None}

    @property
    def state(self) -> Dict[str, StateSpec]:
        """Declared managed state (operator -> StateSpec)."""
        return {n: d.state for n, d in self._decls.items()
                if d.state is not None}

    @property
    def event_time(self) -> Dict[str, KeyBy]:
        """Declared spout event-time extractors (spout -> column/callable)."""
        return {n: d.event_time for n, d in self._decls.items()
                if d.event_time is not None}

    @property
    def watermark_every(self) -> Dict[str, int]:
        """Declared non-default batch-count watermark cadences."""
        return {n: d.watermark_every for n, d in self._decls.items()
                if d.watermark_every != 1}

    @property
    def watermark_interval(self) -> Dict[str, float]:
        """Declared event-time watermark cadences (spout -> T units)."""
        return {n: d.watermark_interval for n, d in self._decls.items()
                if d.watermark_interval is not None}

    @property
    def no_fuse(self) -> frozenset:
        """Operators that opted out of fusion (``op(fuse=False)``)."""
        return frozenset(n for n, d in self._decls.items() if not d.fuse)

    @property
    def is_executable(self) -> bool:
        """True when every non-spout op has a kernel and every spout a
        source — i.e. ``build()`` would succeed where ``build_logical()``
        does."""
        return all((d.spec.is_spout and d.source is not None) or
                   (not d.spec.is_spout and d.kernel is not None)
                   for d in self._decls.values())

    # -- validation + build ----------------------------------------------
    def build_logical(self) -> LogicalGraph:
        """Validate the declarations and compile the logical DAG."""
        if not self._decls:
            raise TopologyError(f"topology {self.name!r} declares no "
                                "operators")
        spouts = [n for n, d in self._decls.items() if d.spec.is_spout]
        if not spouts:
            raise TopologyError(f"topology {self.name!r} has no spout")
        edges: List[tuple] = []
        esel: Dict[tuple, float] = {}
        for name, decl in self._decls.items():
            for u in decl.inputs:
                if u not in self._decls:
                    raise TopologyError(
                        f"operator {name!r} reads from unknown operator "
                        f"{u!r} (declared: {sorted(self._decls)})")
                edges.append((u, name))
                if u in decl.edge_selectivity:
                    esel[(u, name)] = decl.edge_selectivity[u]
        for u, v in edges:
            if self._decls[v].spec.is_spout:
                raise TopologyError(f"spout {v!r} cannot have inputs "
                                    f"(edge {u!r} -> {v!r})")
        self._check_acyclic(edges)
        self._check_watermark_coverage(edges)
        ops = {n: d.spec for n, d in self._decls.items()}
        return LogicalGraph(ops, edges, esel)

    def _check_watermark_coverage(self, edges) -> None:
        """Every spout upstream of an event-time window must declare
        ``event_time=``: the merged watermark is a *min* over input lanes,
        so one watermark-less ancestor pins it at -inf forever and no pane
        can ever fire — the classic stuck-watermark deadlock, rejected at
        build time instead of hanging at run time."""
        windowed = [n for n, d in self._decls.items()
                    if d.state is not None and d.state.window is not None
                    and d.state.window.time]
        if not windowed:
            return
        producers: Dict[str, List[str]] = {}
        for u, v in edges:
            producers.setdefault(v, []).append(u)
        for op in windowed:
            frontier, seen = [op], set()
            while frontier:
                n = frontier.pop()
                if n in seen:
                    continue
                seen.add(n)
                frontier.extend(producers.get(n, []))
            silent = sorted(
                n for n in seen
                if self._decls[n].spec.is_spout
                and self._decls[n].event_time is None)
            if silent:
                raise TopologyError(
                    f"operator {op!r} declares an event-time window but "
                    f"upstream spouts {silent} declare no event_time= — "
                    "their watermark lanes would stay at -inf and the "
                    "window could never fire")

    def _check_acyclic(self, edges) -> None:
        indeg = {n: 0 for n in self._decls}
        for _, v in edges:
            indeg[v] += 1
        frontier = [n for n, d in indeg.items() if d == 0]
        seen = 0
        while frontier:
            n = frontier.pop()
            seen += 1
            for u, v in edges:
                if u == n:
                    indeg[v] -= 1
                    if indeg[v] == 0:
                        frontier.append(v)
        if seen != len(self._decls):
            # every non-spout op declares >=1 input and spouts accept none,
            # so any operator unreachable from a spout is also on a cycle —
            # this check covers both
            cyclic = sorted(n for n, d in indeg.items() if d > 0)
            raise TopologyError(
                f"topology {self.name!r} has a cycle involving {cyclic}")

    def build(self) -> StreamingApp:
        """Compile to an executable :class:`StreamingApp` (graph + kernels +
        sources + partition declarations)."""
        graph = self.build_logical()
        missing = [n for n, d in self._decls.items()
                   if not d.spec.is_spout and d.kernel is None]
        if missing:
            raise TopologyError(
                f"operators without kernels cannot execute: {missing} "
                "(use build_logical() for planning-only topologies)")
        unsourced = [n for n, d in self._decls.items()
                     if d.spec.is_spout and d.source is None]
        if unsourced:
            raise TopologyError(
                f"spouts without source generators: {unsourced}")
        kernels = {n: d.kernel for n, d in self._decls.items()
                   if d.kernel is not None}
        sources = {n: d.source for n, d in self._decls.items()
                   if d.source is not None}
        return StreamingApp(self.name, graph, kernels,
                            make_source=next(iter(sources.values())),
                            partition=self.partition, sources=sources,
                            key_by=self.key_by, state=self.state,
                            event_time=self.event_time,
                            watermark_every=self.watermark_every,
                            watermark_interval=self.watermark_interval,
                            checkpoint_every=self.checkpoint_every,
                            no_fuse=self.no_fuse)


# ---------------------------------------------------------------------------
# Unified result record
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Metrics:
    """Common result shape for estimate / simulate / execute.

    ``source`` tags provenance: "estimate" (analytical model), "fluid" /
    "des" (simulators), "runtime" (real threads).  Latency percentiles are
    NaN where the backend does not model latency; ``raw`` keeps the
    backend-specific result (PlanEval / FluidResult / DesResult /
    RuntimeResult) for detailed inspection.
    """

    source: str
    throughput: float                  # R, sink tuples/s
    latency_p50: float = math.nan      # seconds, spout entry -> sink
    latency_p99: float = math.nan
    feasible: bool = True
    cpu_usage: Optional[np.ndarray] = None     # per-socket core-secs/sec
    mem_usage: Optional[np.ndarray] = None     # per-socket bytes/s
    violations: List[str] = dataclasses.field(default_factory=list)
    raw: object = None

    def summary(self) -> str:
        lat = ("" if math.isnan(self.latency_p50) else
               f" p50={self.latency_p50*1e6:.0f}us "
               f"p99={self.latency_p99*1e6:.0f}us")
        return (f"[{self.source}] R={self.throughput:,.0f} tuples/s "
                f"feasible={self.feasible}{lat}")


# ---------------------------------------------------------------------------
# Job facade: topology/app -> Plan -> estimate/simulate/execute
# ---------------------------------------------------------------------------

OPTIMIZERS = ("rlas", "bnb", "ff", "rr", "random", "manual")


class Job:
    """One streaming job: a topology plus everything you can do with it.

    The job compiles its :class:`~.routing.RoutingTable` once — the same
    tables the runtime executes and the DES measures — and every planner
    call reads edge selectivity/partition from it, so estimate, simulate and
    execute share one source of truth.  ``plan()`` results are cached per
    ``(machine, optimizer, settings)``; :meth:`Plan.replan` re-plans on a
    new machine through the same cache (the elastic path of
    ``launch/elastic.py``).
    """

    def __init__(self, source: Union[Topology, StreamingApp, LogicalGraph]):
        declared_partition: Dict[str, str] = {}
        declared_key_by: Dict[str, KeyBy] = {}
        declared_state: Dict[str, StateSpec] = {}
        declared_no_fuse: frozenset = frozenset()
        if isinstance(source, Topology):
            if source.is_executable:
                self.app: Optional[StreamingApp] = source.build()
                self.graph = self.app.graph
            else:
                # planning-only: the declaration's routing semantics must
                # still reach the planner
                self.app = None
                self.graph = source.build_logical()
                declared_partition = source.partition
                declared_key_by = source.key_by
                declared_state = source.state
                declared_no_fuse = source.no_fuse
            self.name = source.name
        elif isinstance(source, StreamingApp):
            self.app = source
            self.graph = source.graph
            self.name = source.name
        elif isinstance(source, LogicalGraph):
            self.app = None
            self.graph = source
            self.name = "job"
        else:
            raise TypeError(
                f"Job expects Topology, StreamingApp or LogicalGraph, "
                f"got {type(source).__name__}")
        self.routes = compile_routes(
            self.app if self.app is not None else self.graph,
            partition=declared_partition, key_by=declared_key_by)
        if self.app is not None:
            self.no_fuse = frozenset(getattr(self.app, "no_fuse", ()))
            self.time_windows = self.app.time_windows()
        else:
            self.no_fuse = declared_no_fuse
            self.time_windows = {
                op: sp.window for op, sp in declared_state.items()
                if sp.window is not None and sp.window.time}
        self._reprice_window_residency()
        self._plan_cache: Dict[tuple, "Plan"] = {}

    def _reprice_window_residency(self) -> None:
        """Price event-time pane occupancy from the *probed* event-time
        spacing instead of the declared grid alone.

        ``WindowSpec.resident_tuples`` defaults to the one-tick-per-tuple
        convention; a source whose event clock advances faster (sparse
        ticks) holds proportionally fewer rows resident, and one that
        advances slower (bursty readings per tick) holds more.  The probe
        (:func:`~.simulator.probe_et_spacing`, seeded source draws) feeds
        the planner's ``OperatorSpec.state_resident_tuples`` ->
        ``PlanEval.state_resident_bytes`` ledger here, at Job construction
        — only the planner-side graph is rewritten; the app's executable
        graph is untouched.  Sources at the default spacing (all benchmark
        apps) reprice to exactly the declared value."""
        if self.app is None or not self.time_windows:
            return
        from .runtime import upstream_spouts
        from .simulator import probe_et_spacing
        spacing = probe_et_spacing(self.app)
        ops = dict(self.graph.operators)
        changed = False
        for op, w in self.time_windows.items():
            sps = [spacing[s] for s in upstream_spouts(self.graph, op)
                   if s in spacing]
            if not sps:
                continue
            # the slowest-advancing ancestor clock bounds retention: the
            # merged watermark is a min over lanes
            resident = w.resident_tuples(min(sps))
            if resident != ops[op].state_resident_tuples:
                ops[op] = dataclasses.replace(
                    ops[op], state_resident_tuples=resident)
                changed = True
        if changed:
            self.graph = LogicalGraph(ops, list(self.graph.edges),
                                      dict(self.graph.edge_selectivity))

    def plan(self, machine: MachineSpec, optimizer: str = "rlas", *,
             input_rate: Optional[float] = None,
             parallelism: Optional[Dict[str, int]] = None,
             compress_ratio: int = 1, seed: int = 0,
             cache: bool = True, fuse: object = "off", **kw) -> "Plan":
        """Produce an execution plan (replication + placement).

        ``optimizer``: "rlas" (joint scaling + B&B placement, the paper),
        "bnb" (B&B placement at fixed ``parallelism``), "ff"/"rr" (§6.4
        baselines at fixed ``parallelism``), "random" (Fig. 14 sample;
        honours ``rng=`` for reproducible Monte-Carlo sweeps), or "manual"
        (caller-supplied ``placement=`` list, one socket per unit).

        ``fuse`` prices operator fusion (docs/API.md §3e): "off" (default)
        plans the graph as declared; "auto" detects maximal 1:1
        shuffle-routed chains and plans each as a single operator with
        summed service time and zero intra-chain comm cost — letting the
        optimizer trade fusion against replication; an explicit list of
        chains (e.g. ``[["parser", "filter"]]``) fuses exactly those,
        raising on ineligible edges.  The resulting plan's
        ``parallelism`` is expanded back to member names and its
        ``chains`` are handed to ``execute()`` so the runtime realizes
        the same fused pipeline the planner priced.

        Identical requests return the cached :class:`Plan` (pass
        ``cache=False`` to force a fresh search); "random" plans and
        requests with unhashable settings are never cached.
        """
        if parallelism:
            validate_operator_names(self.graph, parallelism, "parallelism")
        # snapshot mutable settings so later caller-side mutation cannot
        # change what replan() replays or what the cache key describes
        options = {k: dict(v) if isinstance(v, dict) else
                   list(v) if isinstance(v, list) else v
                   for k, v in dict(kw, input_rate=input_rate,
                                    parallelism=parallelism,
                                    compress_ratio=compress_ratio,
                                    seed=seed, fuse=fuse).items()}
        key = None if not cache or optimizer == "random" else \
            _plan_cache_key(machine, optimizer, options)
        if key is not None and key in self._plan_cache:
            return self._plan_cache[key]
        plan = self._plan(machine, optimizer, input_rate, parallelism,
                          compress_ratio, seed, fuse, kw)
        plan.options = options
        if key is not None:
            self._plan_cache[key] = plan
        return plan

    def _plan(self, machine, optimizer, input_rate, parallelism,
              compress_ratio, seed, fuse, kw) -> "Plan":
        chains: List[List[str]] = []
        graph_l, routes = self.graph, self.routes
        if fuse is not None and fuse != "off":
            from .fusion import (detect_chains, expand_parallelism,
                                 fuse_graph, fuse_parallelism,
                                 validate_chains)
            if fuse == "auto":
                chains = detect_chains(
                    graph_l, routes, no_fuse=self.no_fuse,
                    time_windows=set(self.time_windows),
                    parallelism=parallelism)
            else:
                chains = validate_chains(
                    graph_l, routes, fuse, no_fuse=self.no_fuse,
                    time_windows=set(self.time_windows))
                if parallelism:
                    # mismatched replica counts cannot fuse — drop, the
                    # same forgiveness prepare_app applies at run time
                    chains = [c for c in chains if len(
                        {parallelism.get(m, 1) for m in c}) == 1]
            if chains:
                graph_l, routes = fuse_graph(graph_l, routes, chains)
                if parallelism:
                    parallelism = fuse_parallelism(parallelism, chains)
        plan = self._plan_graph(graph_l, routes, machine, optimizer,
                                input_rate, parallelism, compress_ratio,
                                seed, kw)
        if chains:
            # callers (and execute()) speak member names; the fused unit
            # scales as one, so every member inherits its replica count
            plan.parallelism = expand_parallelism(plan.parallelism, chains)
            plan.chains = [list(c) for c in chains]
        return plan

    def _plan_graph(self, graph_l, routes, machine, optimizer, input_rate,
                    parallelism, compress_ratio, seed, kw) -> "Plan":
        if optimizer == "rlas":
            res = rlas_optimize(graph_l, machine, input_rate=input_rate,
                                compress_ratio=compress_ratio,
                                initial_parallelism=parallelism,
                                routes=routes, **kw)
            return Plan(self, machine, res.graph,
                        list(res.placement.placement),
                        dict(res.parallelism), "rlas", input_rate,
                        res.placement.eval, res)
        if optimizer == "random":
            rng = kw.pop("rng", None)
            if rng is None:
                rng = np.random.default_rng(seed)
            if parallelism is not None:
                raise TypeError(
                    "optimizer='random' draws its own replication "
                    "(paper Fig. 14 protocol) and would silently discard "
                    "the parallelism argument")
            if kw:
                raise TypeError(f"unexpected arguments for optimizer="
                                f"'random': {sorted(kw)}")
            graph, placement, ev = random_plan(
                graph_l, machine, rng, input_rate=input_rate,
                compress_ratio=compress_ratio, routes=routes)
            return Plan(self, machine, graph, list(placement),
                        dict(graph.parallelism), "random", input_rate,
                        ev, None)
        par = {name: 1 for name in graph_l.operators}
        par.update(parallelism or {})
        graph = ExecutionGraph(graph_l, par, compress_ratio,
                               routes=routes)
        if optimizer == "manual":
            if "placement" not in kw:
                raise TypeError("optimizer='manual' requires a placement= "
                                "list (one socket per execution unit)")
            placement = list(kw.pop("placement"))
            if kw:
                raise TypeError(f"unexpected arguments for optimizer="
                                f"'manual': {sorted(kw)}")
            if len(placement) != graph.n_units:
                raise ValueError(
                    f"manual placement has {len(placement)} entries for "
                    f"{graph.n_units} execution units")
            bad = sorted({s for s in placement
                          if s != -1 and not 0 <= s < machine.n_sockets})
            if bad:
                raise ValueError(
                    f"manual placement names sockets {bad} on a "
                    f"{machine.n_sockets}-socket machine (-1 = unplaced)")
            ev = evaluate(graph, machine, placement, input_rate)
            return Plan(self, machine, graph, placement, par, "manual",
                        input_rate, ev, None)
        if optimizer == "bnb":
            pres = bnb_place(graph, machine, input_rate, **kw)
        elif optimizer in ("ff", "rr"):
            if kw:
                raise TypeError(f"unexpected arguments for optimizer="
                                f"{optimizer!r}: {sorted(kw)}")
            place = ff_place if optimizer == "ff" else rr_place
            pres = place(graph, machine, input_rate)
        else:
            raise ValueError(f"unknown optimizer {optimizer!r} "
                             f"(choose from {OPTIMIZERS})")
        return Plan(self, machine, graph, list(pres.placement), par,
                    optimizer, input_rate, pres.eval, pres)


def _plan_cache_key(machine: MachineSpec, optimizer: str,
                    options: Dict) -> Optional[tuple]:
    """Hashable identity of a plan request, or None when uncacheable."""
    opts = []
    for k, v in sorted(options.items()):
        if isinstance(v, dict):
            v = tuple(sorted(v.items()))
        elif isinstance(v, list):
            v = tuple(v)
        opts.append((k, v))
    key = (machine.name, machine.n_sockets, machine.cores_per_socket,
           machine.local_bw, machine.cache_line, machine.ghz,
           machine.Q.tobytes(), machine.L.tobytes(),
           optimizer, tuple(opts))
    try:
        hash(key)
    except TypeError:
        return None
    return key


@dataclasses.dataclass
class Plan:
    """An execution plan: (replication, placement) on a concrete machine.

    The same object flows through the paper's Table 4 protocol:
    ``estimate()`` -> ``simulate()`` -> ``execute()``.
    """

    job: Job
    machine: MachineSpec
    graph: ExecutionGraph
    placement: List[int]
    parallelism: Dict[str, int]
    optimizer: str
    input_rate: Optional[float]
    eval: object                        # PlanEval from planning, if any
    result: object                      # optimizer-specific result
    options: Dict = dataclasses.field(default_factory=dict)
    #: fusion chains the plan was priced with (``plan(fuse=...)``); the
    #: fused names live in ``graph``/``placement`` while ``parallelism``
    #: is expanded back to member names, and ``execute()`` forwards the
    #: chains so the runtime realizes the same fused pipeline
    chains: List[List[str]] = dataclasses.field(default_factory=list)

    @property
    def feasible(self) -> bool:
        return bool(self.eval is not None and self.eval.feasible)

    def replan(self, machine: MachineSpec, **overrides) -> "Plan":
        """Re-plan this job for a different machine (elastic path).

        Mirrors ``launch/elastic.replan``: the same optimizer and search
        settings are re-run against the new topology — replication and
        placement are re-derived from the performance model, not hand-edited
        — and the result lands in the job's plan cache.
        """
        opts = dict(self.options)
        opts.update(overrides)
        if self.optimizer == "manual" and "placement" not in overrides:
            # the stored placement names THIS plan's sockets; replaying it
            # on a different machine is stale at best, out of range at worst
            raise ValueError(
                "manual plans carry a machine-specific placement; pass "
                "placement= for the new machine or replan with an "
                "optimizer")
        return self.job.plan(machine, self.optimizer, **opts)

    @property
    def R(self) -> float:
        """Planner's estimated throughput (0 when infeasible)."""
        return self.eval.R if self.feasible else 0.0

    @property
    def total_threads(self) -> int:
        return self.graph.total_threads()

    def describe(self) -> str:
        placed = {}
        for idx, rep in enumerate(self.graph.replicas):
            placed.setdefault(rep.op, []).append(self.placement[idx])
        rows = [f"  {op:<16} x{self.graph.parallelism.get(op, 1):<4} "
                f"sockets={sorted(set(s))}" for op, s in placed.items()]
        return (f"Plan[{self.optimizer}] for {self.job.name!r} on "
                f"{self.machine.name} ({self.total_threads} threads, "
                f"R={self.R:,.0f} tuples/s)\n" + "\n".join(rows))

    # -- the three measurement backends -----------------------------------
    def estimate(self, input_rate=_UNSET, tf_mode: str = "relative",
                 mix: str = "weighted") -> Metrics:
        """Analytical §3.1 rate model (instant, no simulation)."""
        rate = self.input_rate if input_rate is _UNSET else input_rate
        ev = evaluate(self.graph, self.machine, self.placement, rate,
                      mix=mix, tf_mode=tf_mode)
        return Metrics("estimate", ev.R, feasible=ev.feasible,
                       cpu_usage=ev.cpu_usage, mem_usage=ev.mem_usage,
                       violations=list(ev.violations), raw=ev)

    def simulate(self, backend: str = "des", *, input_rate=_UNSET,
                 batch: Optional[int] = None, horizon: Optional[float] = None,
                 seed: Optional[int] = None, **kw) -> Metrics:
        """Measurement oracle: "des" (jumbo-tuple discrete-event sim with
        latency percentiles) or "fluid" (fixed-point rate solver that
        degrades under contention).  ``input_rate=None`` measures saturation
        capacity (the paper's §6.1 protocol).  ``batch``/``horizon``/``seed``
        are DES-only (defaults 64 / 0.02 s / 0); the fluid solver rejects
        them rather than silently ignore a parameter sweep."""
        from .simulator import des_simulate, fluid_solve, measure_capacity
        rate = self.input_rate if input_rate is _UNSET else input_rate
        if backend == "fluid":
            stray = [n for n, v in [("batch", batch), ("horizon", horizon),
                                    ("seed", seed)] if v is not None]
            if stray:
                raise TypeError(
                    f"simulate(backend='fluid') does not take {stray} "
                    "(DES-only parameters)")
            fl = fluid_solve(self.graph, self.machine, self.placement,
                             input_rate=rate, **kw)
            return Metrics("fluid", fl.R, raw=fl)
        if backend != "des":
            raise ValueError(f"unknown simulate backend {backend!r} "
                             "(choose 'des' or 'fluid')")
        batch = 64 if batch is None else batch
        horizon = 0.02 if horizon is None else horizon
        seed = 0 if seed is None else seed
        # declared event-time windows ride along so the DES paces pane
        # firing and reports pane latency (DesResult.pane_latency_*)
        if self.job.time_windows and "time_windows" not in kw:
            kw["time_windows"] = self.job.time_windows
        # pace each spout's event clock at its *measured* increment (a
        # seeded source probe) instead of the one-tick-per-tuple constant,
        # so pane latency percentiles track bursty sources
        if kw.get("time_windows") and "et_spacing" not in kw \
                and self.job.app is not None:
            from .simulator import probe_et_spacing
            kw["et_spacing"] = probe_et_spacing(self.job.app, batch=batch,
                                                seed=seed)
        # keyed pane groups fire one pane per occupied key per span: probe
        # the per-span multiplicity so DES pane counts match the runtime's
        # sharded-pane union instead of the bare grid walk
        if kw.get("time_windows") and "pane_keys" not in kw \
                and self.job.app is not None \
                and any(w.keyed for w in kw["time_windows"].values()):
            from .simulator import probe_pane_keys
            kw["pane_keys"] = probe_pane_keys(self.job.app, batch=batch,
                                              seed=seed)
        if rate is None:
            des = measure_capacity(self.graph, self.machine, self.placement,
                                   batch=batch, horizon=horizon, seed=seed,
                                   **kw)
        else:
            des = des_simulate(self.graph, self.machine, self.placement,
                               input_rate=rate, batch=batch,
                               horizon=horizon, seed=seed, **kw)
        return Metrics("des", des.R, des.latency_p50, des.latency_p99,
                       raw=des)

    def execute(self, *, duration: float = 1.0, batch: int = 256,
                jumbo: bool = True, queue_cap: int = 32,
                partition: Optional[Dict[str, str]] = None,
                parallelism: Optional[Dict[str, int]] = None,
                max_threads: Optional[int] = None, seed: int = 0,
                vectorized: Optional[bool] = None,
                batches: Optional[int] = None,
                initial_states: Optional[Dict[str, list]] = None,
                backend: str = "threads", faithful: bool = True,
                env: Optional[Dict[str, str]] = None,
                timeout: Optional[float] = None,
                dispatch_depth: Optional[int] = None,
                initial_offsets: Optional[Dict[str, int]] = None,
                checkpoint_every: Optional[int] = None,
                checkpoint_dir: Optional[str] = None,
                from_checkpoint: Optional[object] = None,
                final_watermark: bool = True) -> Metrics:
        """Run the plan on this host's real runtime.

        ``backend`` selects the execution substrate from the
        :mod:`repro.streaming.procexec` registry: ``"threads"`` (default —
        one thread per replica in this process, unchanged semantics) or
        ``"processes"`` (one pinned worker process per plan-assigned core
        group, tuples crossing groups over shared-memory rings).  Both
        produce byte-identical outputs and state under deterministic
        replay — the backend parity contract ``tests/test_procexec.py``
        pins down.

        Under ``backend="processes"``, ``faithful=True`` (default) realizes
        the plan's *placement*: replicas grouped by their plan-assigned
        socket (one worker per socket, colocated replicas communicate
        in-process, cross-socket streams pay a real shared-memory
        serialize+copy), workers pinned to the socket's share of the host
        cores via ``os.sched_setaffinity``.  ``faithful=False`` gives every
        replica its own worker.  ``env`` seeds extra environment variables
        into each worker before kernels run (e.g.
        :func:`~repro.streaming.procexec.host_device_env` for the JAX
        host-device variant); ``timeout`` bounds the whole run — a wedged
        ring fails fast instead of hanging.

        The plan's replication levels target the *modelled* machine; by
        default they are scaled down to ``max_threads`` (2x host cores)
        respecting the plan evaluation's per-operator core demand —
        bottleneck operators keep their share instead of shrinking
        uniformly.  Pass ``parallelism`` to override entirely.

        ``batches`` runs each spout for exactly that many batches instead of
        ``duration`` seconds (deterministic input — the replay mode behind
        state-migration conservation checks); ``initial_states`` seeds
        per-replica operator state, typically from
        :func:`repro.streaming.state.migrate_states` after a ``replan``.

        ``dispatch_depth`` overrides every device operator's declared async
        in-flight window (1 = synchronous, the A/B flag);
        ``initial_offsets`` resumes spouts from a previous run's
        ``RuntimeResult.spout_offsets`` counters (prefix-continuation of
        duration-mode runs).

        ``checkpoint_every`` (or ``Topology(checkpoint_every=)``) turns on
        aligned-barrier checkpointing on either backend; completed
        snapshots land in ``Metrics.raw.checkpoints`` and, with
        ``checkpoint_dir``, on disk.  ``from_checkpoint`` resumes from a
        snapshot (byte-identical continuation — see ``docs/API.md`` §3d);
        note it pins parallelism to the checkpoint's, overriding the
        plan's scaling.  ``final_watermark=False`` suspends an event-time
        run instead of draining it, keeping pane buffers resident for
        ``migrate_states``.
        """
        from .procexec import get_backend
        run_backend = get_backend(backend)
        if self.job.app is None:
            raise TopologyError(
                f"job {self.job.name!r} is planning-only (no kernels); "
                "build the topology with kernels and sources to execute")
        if from_checkpoint is not None and parallelism is None:
            # snapshots are per-replica: the resumed run must re-create the
            # checkpoint's replica layout, not the plan's scaled one
            parallelism = dict(getattr(from_checkpoint, "parallelism", {}))
        if parallelism is None:
            budget = max_threads if max_threads is not None else \
                2 * (os.cpu_count() or 2)
            if self.chains:
                # scale on fused names (one demand share per fused unit,
                # matching the plan evaluation) and expand after, so every
                # chain member keeps an equal replica count — a mismatched
                # down-scaling would silently unfuse the chain at prepare
                from .fusion import expand_parallelism
                scaled = _scale_parallelism(dict(self.graph.parallelism),
                                            budget, self.eval, self.graph)
                parallelism = expand_parallelism(scaled, self.chains)
            else:
                parallelism = _scale_parallelism(self.parallelism, budget,
                                                 self.eval, self.graph)
            # auto-derived plans clamp non-keyed event-time windowed ops
            # to one replica (run_app rejects them outright): panes fire
            # per replica, so a shuffle split would shatter every pane.
            # Keyed routes keep their planned replication — with keyed
            # pane groups (WindowSpec(keyed=True)) the pane unit is
            # (key, span) and replication preserves pane bytes exactly
            for op in self.job.time_windows:
                prods = self.job.graph.producers(op)
                keyed = bool(prods) and all(
                    self.job.routes.strategy(u, op) == "key"
                    for u in prods)
                if not keyed:
                    parallelism[op] = 1
        kw: Dict[str, object] = {}
        if self.chains:
            # only forwarded when the plan priced fusion, so custom
            # registered backends without a fuse= parameter keep working
            kw["fuse"] = [list(c) for c in self.chains]
        if backend != "threads":
            kw.update(env=env, timeout=timeout)
            if faithful:
                from .procexec import plan_placement
                groups, pins = plan_placement(self, parallelism)
                kw.update(groups=groups, pin=pins)
        elif env is not None:
            raise ValueError(
                "env= requires backend='processes' (threads share this "
                "process's environment)")
        rt = run_backend(self.job.app, parallelism=parallelism, batch=batch,
                         duration=duration, jumbo=jumbo, queue_cap=queue_cap,
                         partition=partition, seed=seed,
                         vectorized=vectorized, max_batches=batches,
                         initial_states=initial_states,
                         dispatch_depth=dispatch_depth,
                         initial_offsets=initial_offsets,
                         checkpoint_every=checkpoint_every,
                         checkpoint_dir=checkpoint_dir,
                         from_checkpoint=from_checkpoint,
                         final_watermark=final_watermark, **kw)
        return Metrics("runtime", rt.throughput, rt.latency_p50,
                       rt.latency_p99, raw=rt)


def _scale_parallelism(parallelism: Dict[str, int], budget: int,
                       plan_eval: object = None,
                       graph: Optional[ExecutionGraph] = None
                       ) -> Dict[str, int]:
    """Shrink replication to fit ``budget`` threads (>=1 per operator).

    With a plan evaluation available, threads are allotted proportionally to
    each operator's modelled core demand (``PlanEval.utilization``) by
    largest remainder, capped at the planned replication — the bottleneck
    ratios the optimizer balanced survive the down-mapping instead of being
    flattened by uniform proportional scaling.  Without one (or with an
    all-idle evaluation) the old proportional rule applies.
    """
    total = sum(parallelism.values())
    if total <= budget:
        return dict(parallelism)
    demand: Optional[Dict[str, float]] = None
    util = getattr(plan_eval, "utilization", None)
    if util is not None and graph is not None \
            and len(util) == len(graph.replicas):
        demand = {}
        for idx, rep in enumerate(graph.replicas):
            demand[rep.op] = demand.get(rep.op, 0.0) + float(util[idx])
        if not all(op in demand for op in parallelism) or \
                sum(demand.values()) <= 0:
            demand = None
    if demand is None or budget < len(parallelism):
        scale = budget / total
        return {op: max(1, int(k * scale)) for op, k in parallelism.items()}
    tot = sum(demand.values())
    raw = {op: budget * demand[op] / tot for op in parallelism}
    # one thread each, then award the rest by largest unmet demand (capped
    # at the planned replication) — never exceeds the budget, unlike
    # rounding raw shares up per-operator
    alloc = {op: 1 for op in parallelism}
    for _ in range(budget - len(alloc)):
        candidates = [o for o in parallelism if alloc[o] < parallelism[o]]
        if not candidates:
            break
        best = max(candidates, key=lambda o: (raw[o] - alloc[o], o))
        alloc[best] += 1
    return alloc
