"""Process-parallel execution backend: shared-memory lanes that make RLAS
placement physically real.

The threaded runtime (:mod:`repro.streaming.runtime`) validates streaming
*semantics*, but every replica shares one GIL and one allocator arena — a
bad placement cannot hurt and RLAS cannot win.  This backend runs the same
executors in **worker processes**:

* one worker per *core group* — by default one per replica; in the
  placement-faithful mode (:func:`plan_placement`, what
  ``Plan.execute(backend="processes", faithful=True)`` uses) one per
  plan-assigned socket, pinned to that socket's share of the host cores via
  ``os.sched_setaffinity``;
* tuples that stay inside a group move by reference through ordinary
  in-process queues, exactly as in the threaded backend;
* tuples that cross groups move over fixed-slot **shared-memory SPSC jumbo
  rings** (:class:`ShmRing`, ``multiprocessing.shared_memory``) in a **raw
  zero-copy slot format**: a fixed header (tag, dtype id, shape, ``t0``)
  followed by the batch's raw bytes, written straight into the slot
  through a NumPy view (one vectorized copy, no pickle, no intermediate
  ``bytes``) and read back as a view over the slot that is copied exactly
  once on hand-off before the head advances — the minimum physical
  movement a cross-process edge can pay, the shared-memory analogue of
  the paper's remote-memory / QPI hop.  Batch dtypes resolve through a
  small table (:func:`register_ring_dtype`) negotiated at worker spawn
  (fork inherits the parent's table); anything unregistered falls back to
  a tagged pickle slot with byte-identical semantics
  (``ring_format="pickle"`` forces the fallback everywhere — the
  serialization A/B in ``benchmarks/bench_runtime.py``).  Watermarks and
  end-of-stream marks travel the same rings as in-band tagged slots, so
  the :class:`~.runtime.Executor` routing/merge/shutdown logic is reused
  *verbatim* — the ring endpoints implement the ``queue.Queue`` protocol
  the executor already speaks.

Because colocated replicas communicate by reference and cross-group edges
pay serialization, a plan's placement quality has a measurable physical
cost even on a small host: RLAS (which colocates heavy edges) beats a
worst-case placement (which alternates sockets along the chain, maximizing
ring crossings) by a real margin — the ``placement_sensitivity`` section of
``BENCH_streaming.json``.

Workers are **forked**, not spawned: app kernels, sources and
``StateSpec.init`` factories are closures and need not pickle — they are
inherited.  What crosses process boundaries explicitly is (a) ring slots —
raw-encoded ``numpy`` batches — and (b) the end-of-run **state payloads**:
each worker reduces its replicas' :class:`~.state.OperatorState` handles to
plain arrays (:func:`_state_payload`), ships them over a pipe, and the
parent restores them onto its own handles (:func:`_restore_state`) — so
``migrate_states`` and every downstream consumer of
``RuntimeResult.states`` work unchanged across process boundaries.

The optional JAX host-device variant: pass
``env=host_device_env(n)`` so each worker sees
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` *before* any lazy
JAX initialization — kernels that import JAX inside a worker then see N
host devices.  (tcmalloc, per the exemplar run scripts, must be
``LD_PRELOAD``-ed into the *parent* before Python starts: preloading
happens at exec time and forked workers inherit it — see docs/API.md.)
"""
from __future__ import annotations

import math
import os
import pickle
import queue
import struct
import sys
import threading
import time
import traceback
import multiprocessing as mp
from multiprocessing import shared_memory
from multiprocessing.connection import wait as conn_wait
from typing import Callable, Dict, List, Mapping, Optional, Tuple

import numpy as np

from .apps import StreamingApp
from .checkpoint import Checkpoint, CheckpointCoordinator
from .runtime import (RuntimeResult, _Barrier, _POISON, _Watermark,
                      build_executors, collect_result, install_checkpoint,
                      prepare_app, resolve_checkpoint_every,
                      validate_from_checkpoint)
from .state import (BroadcastTable, EventTimeWindowState, KeyedStore,
                    OperatorState, ValueStore, WindowState,
                    restore_state, state_payload)

__all__ = ["ShmRing", "register_ring_dtype", "run_app_processes",
           "plan_placement", "socket_core_map", "host_device_env",
           "get_backend", "register_backend", "BACKENDS"]

_SLOT_BYTES = 128 * 1024     # default ring slot: comfortably holds the
# largest benchmark jumbo (WC's splitter emits batch x 10 int64 words —
# 80 KiB at batch 1024) with headroom; oversize payloads raise with a
# pointer at slot_bytes= instead of splitting the batch (a split would
# change stateful kernels' running outputs and break byte parity)
_RING_SLOTS = 8              # slots per ring (jumbos in flight per lane)
_CTRL = 16                   # ring header: head int64 @0, tail int64 @8
_POLL = 50e-6                # idle poll quantum (grows to _POLL_MAX)
_POLL_MAX = 2e-3
_SPIN = 128                  # bounded busy-spin tries before the first
# sleep: a slot under load frees in O(µs), while even the shortest
# time.sleep costs a scheduler round-trip (~50µs wake latency) on every
# slot — the hybrid spins briefly, then falls back to the sleep ladder

# -- raw slot format --------------------------------------------------------
# slot := tag u8, then per tag:
#   RAW     @1 dtype-id u8, @2 ndim u8, @3 lane-length u8 (0 = untagged),
#           @8 t0 f64, @16 shape ndim*i64, @16+8*ndim raw row bytes
#           (8-aligned: slots start 8-aligned and the header is a multiple
#           of 8), then lane utf-8 after the rows
#   PICKLE  @1 blob-length u32, @5 pickled ("d", array, t0[, lane]) payload
#   WM      @1 lane-length u32, @5 lane utf-8, then value f64
#   POISON  tag only
#   BARRIER @1 lane-length u32, @5 lane utf-8, then ckpt_id i64
# Lane tags ride only under checkpointing — the runtime emits 4-tuple
# items then, and the consumer-side barrier aligner needs the producer
# lane to hold the right inputs back.
_TAG_RAW, _TAG_PICKLE, _TAG_WM, _TAG_POISON, _TAG_BARRIER = 0, 1, 2, 3, 4
_RAW_HDR = 16
_RAW_MAX_DIMS = 4

#: the dtype table: id <-> dtype, shared producer/consumer.  Negotiated at
#: worker spawn — forked workers inherit the parent's table, so structured
#: or otherwise app-specific dtypes must register *before*
#: ``run_app_processes`` forks (a registration after spawn stays local to
#: the registering process and the other side falls back to pickle).
_DTYPE_TABLE: List[np.dtype] = [np.dtype(s) for s in (
    "bool", "int8", "int16", "int32", "int64",
    "uint8", "uint16", "uint32", "uint64",
    "float16", "float32", "float64", "complex64", "complex128")]
_DTYPE_IDS: Dict[np.dtype, int] = {dt: i
                                   for i, dt in enumerate(_DTYPE_TABLE)}


def register_ring_dtype(dtype) -> int:
    """Register ``dtype`` (structured dtypes included) in the ring's raw
    slot dtype table and return its id.  Idempotent.  Must run before the
    worker fork to be visible on both ring endpoints; unregistered dtypes
    are not an error — they ride the tagged pickle fallback."""
    dt = np.dtype(dtype)
    did = _DTYPE_IDS.get(dt)
    if did is None:
        if len(_DTYPE_TABLE) >= 256:
            raise ValueError("ring dtype table is full (256 entries)")
        _DTYPE_TABLE.append(dt)
        did = _DTYPE_IDS[dt] = len(_DTYPE_TABLE) - 1
    return did


_seq_lock = threading.Lock()
_seq = [0]


def _ring_name() -> str:
    with _seq_lock:
        _seq[0] += 1
        return f"bsr{os.getpid()}x{_seq[0]}"


class ShmRing:
    """Fixed-slot SPSC ring over one shared-memory segment.

    Layout: ``head`` (int64, consumer-owned) at offset 0, ``tail`` (int64,
    producer-owned) at offset 8, then ``capacity`` slots of ``slot_bytes``
    in the tagged raw format (see the module header): data batches are a
    fixed header plus raw row bytes written through a NumPy view directly
    into the slot — no pickle, no intermediate ``bytes`` — with a tagged
    pickle fallback for dtypes outside the negotiated table (or everywhere
    under ``raw=False``, the A/B baseline).  Exactly one producer process
    writes ``tail`` and slots; exactly one consumer process writes
    ``head`` — no locks, just the two indices (single-writer per cache
    line; CPython's bytecode boundaries plus x86 store ordering make the
    payload-then-tail publication safe).

    The consumer materializes a batch as an ``ndarray`` view over the
    slot and copies it exactly once — *before* advancing ``head``, since
    the advance hands the slot back to the producer for reuse.  Waits are
    hybrid: a short bounded spin (:data:`_SPIN` tries) before the first
    ``time.sleep``, then an exponential sleep ladder — the immediate-sleep
    path paid one scheduler wake latency per slot under load.

    The endpoint speaks the ``queue.Queue`` protocol the
    :class:`~.runtime.Executor` uses: blocking ``put`` (backpressure),
    ``put(timeout=)`` raising ``queue.Full`` (the spout's interruptible
    path), blocking ``get`` and ``get_nowait`` raising ``queue.Empty``.
    Data tuples, watermarks and the poison sentinel are tagged in-band —
    consumers receive the exact runtime objects (poison by identity; data
    as ``(array, t0, None)`` items).  ``put_slots``/``put_tuples``/
    ``put_bytes`` and the ``get_*`` mirrors count slots, tuples and bytes
    actually copied per side — the bytes-copied-per-tuple instrumentation
    behind the ``serialization`` bench section.
    """

    #: rings copy payloads out of the producer's address space inside
    #: ``put`` — the emit path releases pooled-buffer leases immediately
    #: instead of expecting the (other-process) consumer to
    by_reference = False

    __slots__ = ("name", "capacity", "slot_bytes", "raw", "shm", "_buf",
                 "put_slots", "put_tuples", "put_bytes",
                 "get_slots", "get_tuples", "get_bytes")

    def __init__(self, name: Optional[str] = None, *,
                 capacity: int = _RING_SLOTS,
                 slot_bytes: int = _SLOT_BYTES, create: bool = True,
                 raw: bool = True):
        self.capacity = capacity
        self.slot_bytes = slot_bytes
        self.raw = raw
        size = _CTRL + capacity * slot_bytes
        if create:
            name = name or _ring_name()
            self.shm = shared_memory.SharedMemory(name=name, create=True,
                                                  size=size)
            self.shm.buf[:_CTRL] = b"\0" * _CTRL
        else:
            self.shm = shared_memory.SharedMemory(name=name)
        self.name = self.shm.name
        self._buf = self.shm.buf
        self.put_slots = self.put_tuples = self.put_bytes = 0
        self.get_slots = self.get_tuples = self.get_bytes = 0

    # -- the two indices ---------------------------------------------------
    def _head(self) -> int:
        return struct.unpack_from("<q", self._buf, 0)[0]

    def _tail(self) -> int:
        return struct.unpack_from("<q", self._buf, 8)[0]

    def _set_head(self, v: int) -> None:
        struct.pack_into("<q", self._buf, 0, v)

    def _set_tail(self, v: int) -> None:
        struct.pack_into("<q", self._buf, 8, v)

    def _oversize(self, nbytes: int) -> ValueError:
        return ValueError(
            f"ring payload of {nbytes} bytes exceeds the "
            f"{self.slot_bytes}-byte slot; raise slot_bytes= "
            "(run_app_processes / ShmRing) for jumbo batches this "
            "large — the ring never splits a batch, splitting would "
            "change stateful kernels' outputs")

    # -- producer side -----------------------------------------------------
    def put(self, item, timeout: Optional[float] = None) -> None:
        # classify + size the slot before claiming it (the oversize check
        # must fire even when the ring is full)
        arr = blob = lane = None
        if item is _POISON:
            tag, need = _TAG_POISON, 1
        elif isinstance(item, _Watermark):
            tag = _TAG_WM
            lane = item.lane.encode()
            need = 5 + len(lane) + 8
        elif isinstance(item, _Barrier):
            tag = _TAG_BARRIER
            lane = item.lane.encode()
            need = 5 + len(lane) + 8
        else:                   # (arr, t0[, lease[, lane]]) data jumbo
            arr, t0 = item[0], item[1]
            if len(item) >= 4 and item[3] is not None:
                lane = item[3].encode()
                if len(lane) > 255:
                    raise ValueError(f"operator name {item[3]!r} exceeds "
                                     "the 255-byte ring lane tag")
            did = _DTYPE_IDS.get(arr.dtype) if self.raw else None
            if did is not None and 1 <= arr.ndim <= _RAW_MAX_DIMS:
                tag = _TAG_RAW
                arr = np.ascontiguousarray(arr)
                need = (_RAW_HDR + 8 * arr.ndim + arr.nbytes
                        + (len(lane) if lane else 0))
            else:                       # unregistered dtype: tagged fallback
                tag = _TAG_PICKLE
                payload = (("d", np.ascontiguousarray(arr), t0) if lane is None
                           else ("d", np.ascontiguousarray(arr), t0, item[3]))
                blob = pickle.dumps(payload,
                                    protocol=pickle.HIGHEST_PROTOCOL)
                need = 5 + len(blob)
        if need > self.slot_bytes:
            raise self._oversize(need)
        deadline = None if timeout is None else time.monotonic() + timeout
        tail = self._tail()
        spins = _SPIN
        sleep = _POLL
        while tail - self._head() >= self.capacity:
            if spins:                    # bounded spin before first sleep
                spins -= 1
                continue
            if deadline is not None and time.monotonic() > deadline:
                raise queue.Full
            time.sleep(sleep)
            sleep = min(sleep * 2, _POLL_MAX)
        off = _CTRL + (tail % self.capacity) * self.slot_bytes
        if tag == _TAG_RAW:
            # lane-length is always written: slots are reused without
            # zeroing, so byte 3 would otherwise carry a stale tag
            struct.pack_into("<BBBB", self._buf, off, tag, did, arr.ndim,
                             len(lane) if lane else 0)
            struct.pack_into("<d", self._buf, off + 8, float(t0))
            struct.pack_into(f"<{arr.ndim}q", self._buf, off + _RAW_HDR,
                             *arr.shape)
            if arr.nbytes:
                dst = np.ndarray(arr.shape, arr.dtype, buffer=self._buf,
                                 offset=off + _RAW_HDR + 8 * arr.ndim)
                dst[...] = arr        # the one producer-side copy, into shm
            if lane:
                end = off + _RAW_HDR + 8 * arr.ndim + arr.nbytes
                self._buf[end:end + len(lane)] = lane
            self.put_tuples += len(arr)
            self.put_bytes += arr.nbytes
        elif tag == _TAG_PICKLE:
            struct.pack_into("<BI", self._buf, off, tag, len(blob))
            self._buf[off + 5:off + 5 + len(blob)] = blob
            self.put_tuples += len(arr)
            self.put_bytes += arr.nbytes + len(blob)   # dumps + slot write
        elif tag == _TAG_WM:
            struct.pack_into("<BI", self._buf, off, tag, len(lane))
            self._buf[off + 5:off + 5 + len(lane)] = lane
            struct.pack_into("<d", self._buf, off + 5 + len(lane),
                             item.value)
        elif tag == _TAG_BARRIER:
            struct.pack_into("<BI", self._buf, off, tag, len(lane))
            self._buf[off + 5:off + 5 + len(lane)] = lane
            struct.pack_into("<q", self._buf, off + 5 + len(lane),
                             item.ckpt_id)
        else:
            self._buf[off] = _TAG_POISON
        self.put_slots += 1
        self._set_tail(tail + 1)

    # -- consumer side -----------------------------------------------------
    def get_nowait(self):
        head = self._head()
        if self._tail() - head <= 0:
            raise queue.Empty
        off = _CTRL + (head % self.capacity) * self.slot_bytes
        tag = self._buf[off]
        if tag == _TAG_RAW:
            did, ndim = self._buf[off + 1], self._buf[off + 2]
            lane_len = self._buf[off + 3]
            (t0,) = struct.unpack_from("<d", self._buf, off + 8)
            shape = struct.unpack_from(f"<{ndim}q", self._buf,
                                       off + _RAW_HDR)
            dt = _DTYPE_TABLE[did]
            if math.prod(shape):
                src = np.ndarray(shape, dt, buffer=self._buf,
                                 offset=off + _RAW_HDR + 8 * ndim)
                arr = src.copy()   # the one hand-off copy, pre head-advance
            else:
                arr = np.empty(shape, dt)
            self.get_tuples += len(arr)
            self.get_bytes += arr.nbytes
            if lane_len:
                end = off + _RAW_HDR + 8 * ndim + arr.nbytes
                lane = bytes(self._buf[end:end + lane_len]).decode()
                item = (arr, t0, None, lane)
            else:
                item = (arr, t0, None)
        elif tag == _TAG_PICKLE:
            (length,) = struct.unpack_from("<I", self._buf, off + 1)
            payload = pickle.loads(self._buf[off + 5:off + 5 + length])
            arr = payload[1]
            self.get_tuples += len(arr)
            self.get_bytes += arr.nbytes
            item = ((arr, payload[2], None, payload[3])
                    if len(payload) >= 4 else (arr, payload[2], None))
        elif tag == _TAG_WM:
            (length,) = struct.unpack_from("<I", self._buf, off + 1)
            lane = bytes(self._buf[off + 5:off + 5 + length]).decode()
            (value,) = struct.unpack_from("<d", self._buf,
                                          off + 5 + length)
            item = _Watermark(lane, value)
        elif tag == _TAG_BARRIER:
            (length,) = struct.unpack_from("<I", self._buf, off + 1)
            lane = bytes(self._buf[off + 5:off + 5 + length]).decode()
            (ckpt_id,) = struct.unpack_from("<q", self._buf,
                                            off + 5 + length)
            item = _Barrier(lane, ckpt_id)
        else:
            item = _POISON
        self.get_slots += 1
        self._set_head(head + 1)
        return item

    def get(self):
        spins = _SPIN
        sleep = _POLL
        while True:
            try:
                return self.get_nowait()
            except queue.Empty:
                if spins:                # bounded spin before first sleep
                    spins -= 1
                    continue
                time.sleep(sleep)
                sleep = min(sleep * 2, _POLL_MAX)

    # -- lifecycle (parent-side) -------------------------------------------
    def close(self) -> None:
        try:
            self._buf = None
            self.shm.close()
        except (BufferError, OSError):
            pass

    def unlink(self) -> None:
        try:
            self.shm.unlink()
        except FileNotFoundError:
            pass


class _FanIn:
    """Consumer-side merge of one replica's input endpoints — shared-memory
    rings (one per cross-group producer replica) plus at most one local
    in-process queue.  Implements the blocking ``get()`` the executor's
    task loop calls, polling sources round-robin so no producer lane can
    starve another (the threaded backend's single shared queue has the
    same no-starvation property by FIFO interleaving)."""

    __slots__ = ("sources", "_i", "_solo")

    def __init__(self, sources: List[object]):
        self.sources = sources
        self._i = 0
        # single-lane fast path: one producer endpoint means no fan-in
        # bookkeeping at all — poll it directly
        self._solo = sources[0] if len(sources) == 1 else None

    def get(self):
        spins = _SPIN
        sleep = _POLL
        if self._solo is not None:
            src = self._solo
            while True:
                try:
                    return src.get_nowait()
                except queue.Empty:
                    pass
                if spins:
                    spins -= 1
                    continue
                time.sleep(sleep)
                sleep = min(sleep * 2, _POLL_MAX)
        while True:
            for _ in range(len(self.sources)):
                src = self.sources[self._i]
                self._i = (self._i + 1) % len(self.sources)
                try:
                    return src.get_nowait()
                except queue.Empty:
                    pass
            if spins:                    # bounded spin before first sleep
                spins -= 1
                continue
            time.sleep(sleep)
            sleep = min(sleep * 2, _POLL_MAX)


class _ShmEvent:
    """``threading.Event`` facade over one shared-memory byte — the spout
    stop flag, settable from the parent and visible in every worker."""

    __slots__ = ("shm", "_off")

    def __init__(self, shm: shared_memory.SharedMemory, offset: int = 0):
        self.shm = shm
        self._off = offset

    def is_set(self) -> bool:
        return self.shm.buf[self._off] != 0

    def set(self) -> None:
        self.shm.buf[self._off] = 1


class _CkptProxy:
    """Worker-side stand-in for the parent's
    :class:`~.checkpoint.CheckpointCoordinator`.

    Executors call the same ``deposit`` surface; the proxy forwards each
    snapshot over the worker's result pipe as an in-band ``("ckpt", ...)``
    message, so alignment bookkeeping and completed-round assembly live
    only in the parent — which persists finished checkpoints mid-run and
    therefore survives worker kills.  The pipe lock is shared with the
    end-of-run ``("ok", ...)`` send: several executor threads per worker
    deposit concurrently and ``Connection.send`` is not thread-safe."""

    __slots__ = ("every", "_conn", "_lock")

    def __init__(self, conn, lock: threading.Lock, every: int):
        self.every = every
        self._conn = conn
        self._lock = lock

    def deposit(self, ckpt_id: int, uid: str, *, payload: dict,
                aux: Optional[dict] = None,
                offset: Optional[int] = None) -> None:
        with self._lock:
            self._conn.send(("ckpt", ckpt_id, uid, payload, aux, offset))


# ---------------------------------------------------------------------------
# State payloads: what crosses the pipe back to the parent
# ---------------------------------------------------------------------------


# Grown into public repro.streaming.state.state_payload / restore_state
# when checkpointing needed the same reduction for live snapshots (with
# copy=True); the worker pipe hand-off keeps using them under the old
# names.
_state_payload = state_payload
_restore_state = restore_state


# ---------------------------------------------------------------------------
# Worker grouping and pinning
# ---------------------------------------------------------------------------

Replica = Tuple[str, int]


def _normalize_groups(groups, replicas: List[Replica]) -> Dict[Replica, object]:
    """Resolve the ``groups`` argument to replica -> group id.

    ``None`` gives every replica its own worker (maximum parallelism, every
    edge a ring).  A mapping may assign by replica ``(op, i)`` or by
    operator name; unassigned replicas get solo workers."""
    if groups is None:
        return {rep: idx for idx, rep in enumerate(replicas)}
    out: Dict[Replica, object] = {}
    for rep in replicas:
        name, _ = rep
        if rep in groups:
            out[rep] = groups[rep]
        elif name in groups:
            out[rep] = groups[name]
        else:
            out[rep] = ("solo",) + rep
    return out


def _numa_node_cpus(sysfs: str = "/sys/devices/system/node"
                    ) -> List[List[int]]:
    """Per-NUMA-node CPU lists from sysfs (``node*/cpulist``, the kernel's
    ``"0-3,8-11"`` range syntax), sorted by node id.  Empty when the tree
    is absent (non-Linux, containers masking /sys) — callers fall back to
    topology-blind round-robin."""
    try:
        nodes = sorted((d for d in os.listdir(sysfs)
                        if d.startswith("node") and d[4:].isdigit()),
                       key=lambda d: int(d[4:]))
    except OSError:
        return []
    out: List[List[int]] = []
    for node in nodes:
        try:
            with open(os.path.join(sysfs, node, "cpulist")) as fh:
                text = fh.read().strip()
        except OSError:
            continue
        cpus: List[int] = []
        for part in text.split(","):
            if not part:
                continue
            lo, _, hi = part.partition("-")
            cpus.extend(range(int(lo), int(hi or lo) + 1))
        if cpus:
            out.append(cpus)
    return out


def socket_core_map(n_sockets: int,
                    cores: Optional[List[int]] = None,
                    sysfs: str = "/sys/devices/system/node"
                    ) -> Dict[int, List[int]]:
    """Host cores bucketed into ``n_sockets`` pinning sets — the worker
    map for plan-faithful execution.

    When the host exposes more than one NUMA node (``sysfs``) and no
    explicit ``cores=`` override is given, modelled socket ``s`` gets the
    affinity-visible cores of host node ``s % n_nodes`` — so a plan
    socket's workers really share one physical memory domain and
    cross-socket rings really cross the interconnect, the topology the
    paper's remote-memory penalty models.  Single-node hosts (and
    explicit ``cores=``) keep the topology-blind round-robin.  Sockets
    left with no core on small hosts are simply unpinned (the scheduler
    places them)."""
    if cores is None:
        avail = os.sched_getaffinity(0)
        nodes = [[c for c in node if c in avail]
                 for node in _numa_node_cpus(sysfs)]
        nodes = [n for n in nodes if n]
        if len(nodes) > 1:
            buckets = {s: [] for s in range(n_sockets)}
            for s in range(n_sockets):
                buckets[s] = list(nodes[s % len(nodes)])
            return {s: cs for s, cs in buckets.items() if cs}
        cores = avail
    cores = sorted(cores)
    buckets: Dict[int, List[int]] = {s: [] for s in range(n_sockets)}
    for idx, c in enumerate(cores):
        buckets[idx % n_sockets].append(c)
    return {s: cs for s, cs in buckets.items() if cs}


def plan_placement(plan, parallelism: Dict[str, int]
                   ) -> Tuple[Dict[Replica, int], Dict[int, List[int]]]:
    """Derive (groups, pins) from a plan's socket map — the placement-
    faithful mode of ``Plan.execute(backend="processes")``.

    Runtime replica ``(op, j)`` inherits the socket of the plan's unit
    ``j % planned_units(op)`` (the runtime replica count may have been
    scaled down from the modelled machine), so colocated units share a
    worker and cross-socket streams pay the ring copy — placement cost
    becomes communication cost, measurable even on a single-core host.
    Pins round-robin the host cores over the plan's sockets."""
    socks: Dict[str, List[int]] = {}
    for idx, rep in enumerate(plan.graph.replicas):
        socks.setdefault(rep.op, []).append(plan.placement[idx])
    # fused plans place whole chains as single units: every member
    # inherits the fused unit's sockets (chain replicas share a worker)
    alias = {m: "+".join(c) for c in getattr(plan, "chains", []) for m in c}
    groups: Dict[Replica, int] = {}
    for op, k in parallelism.items():
        placed = socks.get(op)
        if placed is None and op in alias:
            placed = socks.get(alias[op])
        s = sorted(max(0, x) for x in (placed or [0]))  # UNPLACED -> 0
        for j in range(k):
            groups[(op, j)] = s[j % len(s)]
    pins = socket_core_map(plan.machine.n_sockets)
    return groups, pins


def host_device_env(n: int, base: Optional[Mapping[str, str]] = None
                    ) -> Dict[str, str]:
    """Worker environment for the JAX host-device variant.

    Sets ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (replacing
    any existing count flag) so a kernel that lazily imports JAX inside a
    worker sees N host devices — one per pinned core group.  Also sets the
    tcmalloc large-alloc report threshold the exemplar run scripts use;
    tcmalloc itself must be LD_PRELOAD-ed into the *parent* (preloading
    happens at exec, forked workers inherit it — see docs/API.md)."""
    env = dict(base or {})
    flags = [f for f in os.environ.get("XLA_FLAGS", "").split()
             if not f.startswith("--xla_force_host_platform_device_count")]
    flags.append(f"--xla_force_host_platform_device_count={int(n)}")
    env["XLA_FLAGS"] = " ".join(flags)
    env.setdefault("TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD", "60000000000")
    return env


# ---------------------------------------------------------------------------
# The process backend
# ---------------------------------------------------------------------------


def run_app_processes(app: StreamingApp,
                      parallelism: Optional[Dict[str, int]] = None,
                      batch: int = 256, duration: float = 1.0,
                      jumbo: bool = True, queue_cap: int = 32,
                      partition: Optional[Dict[str, str]] = None,
                      seed: int = 0, vectorized: Optional[bool] = None,
                      max_batches: Optional[int] = None,
                      initial_states: Optional[Dict[str, List[dict]]] = None,
                      groups: Optional[Mapping] = None,
                      pin: Optional[Mapping[object, List[int]]] = None,
                      env: Optional[Mapping[str, str]] = None,
                      slot_bytes: int = _SLOT_BYTES,
                      ring_slots: int = _RING_SLOTS,
                      ring_format: str = "raw",
                      timeout: Optional[float] = None,
                      dispatch_depth: Optional[int] = None,
                      initial_offsets: Optional[Dict[str, int]] = None,
                      checkpoint_every: Optional[int] = None,
                      checkpoint_dir: Optional[str] = None,
                      from_checkpoint: Optional[Checkpoint] = None,
                      final_watermark: bool = True,
                      fuse=None
                      ) -> RuntimeResult:
    """Execute ``app`` on forked worker processes (see module docstring).

    Accepts the full ``run_app`` surface plus: ``groups`` (replica/operator
    -> worker group id; default one worker per replica), ``pin`` (group id
    -> CPU cores, applied via ``sched_setaffinity``), ``env`` (extra
    worker environment), ``slot_bytes``/``ring_slots``/``ring_format``
    (ring geometry and slot encoding — ``"raw"`` is the zero-copy default,
    ``"pickle"`` forces the fallback path everywhere for serialization
    A/Bs) and ``timeout`` (whole-run deadline; on expiry workers are
    terminated, every shared-memory segment is unlinked and
    ``TimeoutError`` is raised — a wedged ring cannot orphan segments or
    hang the caller).

    Parity contract: under deterministic replay (``max_batches``) the
    result — sink counters, keyed state bytes, pane multisets, late
    drops — is byte-identical to ``run_app``'s for any grouping, because
    both backends run the same executors over the same compiled routes and
    only the transport differs.

    Checkpointing (``checkpoint_every`` / ``checkpoint_dir`` /
    ``from_checkpoint`` / ``final_watermark``) matches ``run_app``:
    barriers travel cross-process as in-band tagged ring slots, data
    slots carry their producer lane for the consumer-side aligner, and
    workers stream every aligned snapshot back over their result pipe —
    the parent assembles and persists completed checkpoints *mid-run*,
    so a SIGKILL-ed run restores from the last completed cut.
    """
    if ring_format not in ("raw", "pickle"):
        raise ValueError(f"ring_format must be 'raw' or 'pickle', "
                         f"got {ring_format!r}")
    every = resolve_checkpoint_every(app, checkpoint_every)
    if from_checkpoint is not None:
        parallelism, initial_offsets = validate_from_checkpoint(
            app, from_checkpoint, batch=batch, seed=seed,
            parallelism=parallelism, initial_states=initial_states,
            initial_offsets=initial_offsets)
        if every is None:
            every = from_checkpoint.checkpoint_every
    prep = prepare_app(app, parallelism, partition, initial_states,
                       batch=batch, fuse=fuse)
    # restore *before* the fork: workers inherit the restored states
    initial_aux = install_checkpoint(prep, from_checkpoint) \
        if from_checkpoint is not None else None
    coordinator = CheckpointCoordinator(
        app, prep.parallelism, batch=batch, seed=seed, every=every,
        directory=checkpoint_dir) if every else None
    lg, par = prep.lg, prep.parallelism
    replicas: List[Replica] = [(name, i) for name in lg.operators
                               for i in range(par[name])]
    group_of = _normalize_groups(groups, replicas)
    # a fused chain replica is one executor: every member replica lands in
    # the head replica's group (overriding any requested split — fusion
    # already collapsed those edges to function calls)
    for chain in prep.chains:
        head = chain[0]
        for m in chain[1:]:
            for i in range(par[head]):
                group_of[(m, i)] = group_of[(head, i)]
    gids = list(dict.fromkeys(group_of.values()))      # first-appearance order
    if getattr(app, "device_ops", None) and app.device_ops():
        # forking after the parent has initialized JAX/XLA deadlocks the
        # child's first jit call (multithreaded runtime + fork) — fail fast
        # with the workaround instead of hanging the run
        if "jax" in sys.modules:
            raise RuntimeError(
                "backend='processes' with device operators requires a "
                "JAX-clean parent: jax is already imported (forked workers "
                "inherit XLA's thread state and deadlock on first jit "
                "call). Run device apps from a fresh process, or use "
                "backend='threads'")
        # first real kernel user of the host-device plumbing: each worker
        # group gets an XLA host device unless the caller already set one
        if not any("--xla_force_host_platform_device_count" in v
                   for v in (env or {}).values()):
            env = host_device_env(max(1, len(gids)), base=env)
    members: Dict[object, List[Replica]] = {g: [] for g in gids}
    for rep in replicas:
        members[group_of[rep]].append(rep)

    # -- wiring: local queues inside a group, rings across groups ----------
    local_qs: Dict[Replica, queue.Queue] = {}
    rings: Dict[Tuple[Replica, Replica], ShmRing] = {}
    ring_cap = max(2, min(queue_cap, ring_slots))
    intra = {(u, v) for chain in prep.chains
             for u, v in zip(chain, chain[1:])}
    for v in lg.operators:
        if lg.operators[v].is_spout:
            continue
        for j in range(par[v]):
            for u in lg.producers(v):
                if (u, v) in intra:
                    continue       # fused away: no queue, no ring
                for i in range(par[u]):
                    pr, cr = (u, i), (v, j)
                    if group_of[pr] == group_of[cr]:
                        if cr not in local_qs:
                            local_qs[cr] = queue.Queue(maxsize=queue_cap)
                    else:
                        rings[(pr, cr)] = ShmRing(
                            capacity=ring_cap, slot_bytes=slot_bytes,
                            raw=ring_format == "raw")

    ctrl = shared_memory.SharedMemory(name=_ring_name(), create=True, size=16)
    ctrl.buf[:16] = b"\0" * 16
    stop = _ShmEvent(ctrl)

    def in_q_of(name: str, i: int):
        cr = (name, i)
        in_rings = [r for (pr, c), r in rings.items() if c == cr]
        local = local_qs.get(cr)
        if not in_rings:
            return local if local is not None else queue.Queue()
        if local is None and len(in_rings) == 1:
            return in_rings[0]
        return _FanIn(in_rings + ([local] if local is not None else []))

    def out_q_of(name: str, i: int, cop: str):
        pr = (name, i)
        return [rings[(pr, (cop, j))] if (pr, (cop, j)) in rings
                else local_qs[(cop, j)] for j in range(par[cop])]

    def _worker(gid, conn) -> None:
        send_lock = threading.Lock()
        try:
            if env:
                os.environ.update(env)
            if pin and gid in pin:
                try:
                    os.sched_setaffinity(0, set(pin[gid]))
                except (OSError, ValueError):
                    pass                     # cores absent on this host
            # a kernel crash happens on an executor *thread*; without this
            # hook the worker main thread would join the corpse and report
            # "ok" while downstream workers starve — record and fail fast
            errors: List[str] = []
            threading.excepthook = lambda a: errors.append("".join(
                traceback.format_exception(a.exc_type, a.exc_value,
                                           a.exc_traceback)))
            latencies: List[float] = []
            counts = [0]
            proxy = _CkptProxy(conn, send_lock, every) if every else None
            spouts, tasks = build_executors(
                app, prep, batch=batch, jumbo=jumbo, vectorized=vectorized,
                seed=seed, max_batches=max_batches, stop=stop,
                latencies=latencies,
                add_spout_count=lambda n: counts.__setitem__(
                    0, counts[0] + n),
                in_q_of=in_q_of, out_q_of=out_q_of,
                only=set(members[gid]), dispatch_depth=dispatch_depth,
                initial_offsets=initial_offsets,
                coordinator=proxy, final_watermark=final_watermark,
                initial_aux=initial_aux)
            for t in tasks:
                t.start()
            for s in spouts:
                s.start()
            join_timeout = 5.0 if max_batches is None else 60.0
            # Unlike run_app, do NOT set the stop flag when this worker's
            # spouts finish: the flag is shared across workers and another
            # group's spout may still be mid-replay.  The parent sets it
            # (duration cutoff / shutdown); tasks exit by poison counting.
            # Joins poll so a recorded crash aborts the wait immediately.
            local_deadline = time.monotonic() + join_timeout
            for x in spouts + tasks:
                while x.is_alive() and not errors \
                        and time.monotonic() < local_deadline:
                    x.join(timeout=0.1)
                if errors:
                    raise RuntimeError("executor crashed:\n"
                                       + "\n".join(errors))
            payload = {
                "states": {rep: _state_payload(prep.states[rep[0]][rep[1]])
                           for rep in members[gid]},
                "latencies": latencies,
                "spout_tuples": counts[0],
                "spout_offsets": {s.name: s.emitted_batches
                                  for s in spouts},
                "exec_stats": {uid: st for x in spouts + tasks
                               for uid, st in x.stats_payload().items()}}
            with send_lock:
                conn.send(("ok", payload))
            conn.close()
        except BaseException:
            try:
                with send_lock:
                    conn.send(("error", f"worker {gid!r}:\n"
                               + traceback.format_exc()))
                conn.close()
            finally:
                os._exit(1)

    ctx = mp.get_context("fork")
    procs: List[mp.Process] = []
    conns = []
    t_start = time.perf_counter()
    wall = 0.0
    spout_total = 0
    spout_offsets: Dict[str, int] = {}
    latencies: List[float] = []
    exec_stats: Dict[str, dict] = {}
    deadline = time.monotonic() + (
        timeout if timeout is not None
        else 120.0 + (duration if max_batches is None else 0.0))
    try:
        for gid in gids:
            parent_conn, child_conn = ctx.Pipe(duplex=False)
            p = ctx.Process(target=_worker, args=(gid, child_conn),
                            daemon=True, name=f"procexec-{gid}")
            p.start()
            child_conn.close()
            procs.append(p)
            conns.append(parent_conn)
        if max_batches is None:
            time.sleep(duration)
            stop.set()
        pending = {c: (g, p) for c, g, p in zip(conns, gids, procs)}
        while pending:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise TimeoutError(
                    f"process backend exceeded its deadline with "
                    f"{len(pending)} worker(s) still running "
                    f"({sorted(str(g) for _, (g, _) in pending.items())}); "
                    "workers terminated, shared memory unlinked")
            for c in conn_wait(list(pending), timeout=min(remaining, 0.25)):
                gid, p = pending[c]
                try:
                    msg = c.recv()
                except EOFError:
                    pending.pop(c)
                    raise RuntimeError(
                        f"worker {gid!r} died without reporting "
                        f"(exitcode {p.exitcode})") from None
                if msg[0] == "ckpt":
                    # in-band snapshot deposit: the conn stays pending —
                    # the worker keeps running, its "ok" comes later
                    if coordinator is not None:
                        coordinator.deposit(msg[1], msg[2], payload=msg[3],
                                            aux=msg[4], offset=msg[5])
                    continue
                pending.pop(c)
                status, payload = msg
                if status == "error":
                    raise RuntimeError(
                        "process backend worker failed — " + payload)
                for rep, sp in payload["states"].items():
                    _restore_state(prep.states[rep[0]][rep[1]], sp)
                latencies.extend(payload["latencies"])
                spout_total += payload["spout_tuples"]
                spout_offsets.update(payload.get("spout_offsets", {}))
                exec_stats.update(payload.get("exec_stats", {}))
            # a silent crash (SIGKILL, segfault) leaves no pipe message
            for c, (gid, p) in list(pending.items()):
                if not p.is_alive() and not c.poll():
                    raise RuntimeError(
                        f"worker {gid!r} died without reporting "
                        f"(exitcode {p.exitcode})")
        wall = time.perf_counter() - t_start
    finally:
        stop.set()
        for p in procs:
            if p.is_alive():
                p.terminate()
        for p in procs:
            p.join(timeout=5.0)
            if p.is_alive():
                p.kill()
                p.join(timeout=5.0)
        for r in rings.values():
            r.close()
            r.unlink()
        try:
            ctrl.close()
            ctrl.unlink()
        except FileNotFoundError:
            pass
    return collect_result(prep, spout_total, latencies, wall,
                          spout_offsets=spout_offsets,
                          checkpoints=coordinator.completed
                          if coordinator else None,
                          exec_stats=exec_stats)


def _run_app_threads(app: StreamingApp, **kw) -> RuntimeResult:
    """Registry adapter for the default threaded backend."""
    from .runtime import run_app
    return run_app(app, **kw)


BACKENDS: Dict[str, Callable[..., RuntimeResult]] = {
    "threads": _run_app_threads,
    "processes": run_app_processes,
}


def register_backend(name: str,
                     fn: Callable[..., RuntimeResult]) -> None:
    """Register an execution backend under ``name`` for
    ``Plan.execute(backend=name)``.  The callable must accept the
    ``run_app`` keyword surface and return a
    :class:`~.runtime.RuntimeResult`."""
    BACKENDS[name] = fn


def get_backend(name: str) -> Callable[..., RuntimeResult]:
    try:
        return BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown execution backend {name!r} "
            f"(registered: {sorted(BACKENDS)})") from None
