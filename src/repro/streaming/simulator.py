"""Execution-plan measurement oracle (no NUMA hardware in this container).

Two simulators stand in for the paper's bare-metal runs (DESIGN.md §6):

* :func:`fluid_solve` — a damped fixed-point solver over tuple rates that,
  unlike the analytical §3.1 model, *degrades* under contention instead of
  declaring plans infeasible: CPU oversubscription causes processor sharing,
  memory-bandwidth and channel saturation stretch service times.  This is the
  physical behaviour of the paper's relaxed FF/RR plans ("ends up with
  oversubscribing of a few CPU sockets").
* :func:`des_simulate` — a discrete-event simulation at *jumbo tuple*
  granularity: bounded queues, FCFS service, batching delay, CPU processor
  sharing.  Reports throughput and end-to-end latency percentiles (the
  paper's Fig. 7 protocol: event enters at the spout, leaves at the sink).

The analytical model (estimate) vs these simulators (measurement) gives the
Table 4 relative-error analysis.
"""
from __future__ import annotations

import bisect
import dataclasses
import heapq
import math
from typing import Dict, List, Mapping, Optional, Tuple, Union

import numpy as np

from repro.core import ExecutionGraph, MachineSpec
from repro.core.perfmodel import UNPLACED

from .routing import (RoutingTable, compile_routes, extract_event_times,
                      extract_keys, unit_delivery)
from .state import WindowSpec, grid_pane_ends, pane_range


@dataclasses.dataclass
class FluidResult:
    R: float
    processed: np.ndarray
    cpu_scale: np.ndarray          # per-socket processor-sharing factor
    iterations: int
    converged: bool


def _validate_spout_rates(graph: ExecutionGraph, input_rate) -> None:
    """Per-spout rate dicts must name spout operators only (spouts absent
    from the mapping are fed at rate 0) — one rule for DES and fluid."""
    spout_ops = set(graph.logical.spouts())
    unknown = sorted(set(input_rate) - spout_ops)
    if unknown:
        raise ValueError(
            f"input_rate names non-spout operators {unknown} "
            f"(spouts: {sorted(spout_ops)}); spouts absent from the "
            "mapping are fed at rate 0")


def fluid_solve(graph: ExecutionGraph, machine: MachineSpec,
                placement: List[int], input_rate=None,
                max_iters: int = 200, tol: float = 1e-6) -> FluidResult:
    """Damped fixed-point rate solver (see module docstring).

    ``input_rate`` is the external ingress: ``None`` (saturation), a float
    feeding every spout operator at that rate, or a ``{spout_op: rate}``
    mapping feeding each spout its own stream — the same contract
    :func:`des_simulate` honours, so under-fed multi-spout studies are
    uniform across backends.
    """
    if isinstance(input_rate, dict):
        _validate_spout_rates(graph, input_rate)
    n = graph.n_units
    order = graph.topo_unit_order()
    te = np.array([r.spec.exec_s for r in graph.replicas])
    group = np.array([float(r.group) for r in graph.replicas])
    nbytes = np.array([r.spec.tuple_bytes for r in graph.replicas])
    mbytes = np.array([r.spec.mem_bytes for r in graph.replicas])
    is_spout = np.array([r.spec.is_spout for r in graph.replicas])
    sock = np.array(placement)

    base_tf = np.zeros((n, n))
    for u, v, _ in graph.edges:
        su, sv = placement[u], placement[v]
        if su != UNPLACED and sv != UNPLACED and su != sv:
            base_tf[u, v] = machine.fetch_time(su, sv, nbytes[v])

    processed = np.zeros(n)
    cpu_scale = np.ones(machine.n_sockets)
    it = 0
    converged = False
    for it in range(1, max_iters + 1):
        # contention multipliers from current rates
        mem_demand = np.zeros(machine.n_sockets)
        chan_demand = np.zeros((machine.n_sockets, machine.n_sockets))
        for v in range(n):
            if sock[v] != UNPLACED:
                mem_demand[sock[v]] += processed[v] * mbytes[v]
        for u, v, w in graph.edges:
            su, sv = sock[u], sock[v]
            if su != UNPLACED and sv != UNPLACED and su != sv:
                chan_demand[su, sv] += processed[u] * w * nbytes[v]
        mem_mult = np.maximum(1.0, mem_demand / machine.local_bw)
        with np.errstate(divide="ignore", invalid="ignore"):
            chan_mult = np.where(machine.Q > 0,
                                 np.maximum(1.0, chan_demand / machine.Q), 1.0)
        # forward pass: desired rates under stretched service times
        desired = np.zeros(n)
        util = np.zeros(n)
        for v in order:
            if is_spout[v]:
                cap = group[v] / te[v] if te[v] > 0 else math.inf
                op = graph.replicas[v].op
                rate = input_rate.get(op, 0.0) \
                    if isinstance(input_rate, dict) else input_rate
                share = math.inf if rate is None else \
                    rate * group[v] / graph.parallelism[op]
                desired[v] = min(share, cap)
                util[v] = desired[v] * te[v]
                continue
            ins = graph.in_edges[v]
            rates = np.array([desired[u] * w for u, w in ins])
            tot = rates.sum()
            if tot <= 0:
                continue
            mm = mem_mult[sock[v]] if sock[v] != UNPLACED else 1.0
            svc = np.array([
                te[v] * mm + base_tf[u, v] *
                (chan_mult[sock[u], sock[v]]
                 if sock[u] != UNPLACED and sock[v] != UNPLACED else 1.0)
                for u, _ in ins])
            t_mix = float((rates * svc).sum() / tot)
            cap = group[v] / t_mix if t_mix > 0 else math.inf
            desired[v] = min(tot, cap)
            util[v] = desired[v] * t_mix
        # processor sharing: scale back oversubscribed sockets
        cpu_demand = np.zeros(machine.n_sockets)
        for v in range(n):
            if sock[v] != UNPLACED:
                cpu_demand[sock[v]] += util[v]
        cpu_scale = np.minimum(
            1.0, machine.cores_per_socket / np.maximum(cpu_demand, 1e-30))
        new = np.array([
            desired[v] * (cpu_scale[sock[v]] if sock[v] != UNPLACED else 1.0)
            for v in range(n)])
        if np.allclose(new, processed, rtol=tol, atol=1e-9):
            processed = new
            converged = True
            break
        processed = 0.5 * processed + 0.5 * new
    R = float(sum(processed[v] for v in graph.sink_units()))
    return FluidResult(R, processed, cpu_scale, it, converged)


# ---------------------------------------------------------------------------
# Discrete-event simulation at jumbo-tuple granularity
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DesResult:
    R: float                        # sink tuples/sec
    latency_p50: float              # seconds, spout entry -> sink
    latency_p99: float
    sim_time: float
    sink_tuples: float
    queue_drops: int                # jumbos dropped at full queues
    busy_s: Optional[np.ndarray] = None       # per-unit busy seconds
    unit_tuples: Optional[np.ndarray] = None  # per-unit processed tuples
    mem_rate: Optional[np.ndarray] = None     # per-socket bytes/s (M traffic)
    state_bytes: float = 0.0        # total declared-state bytes charged
    # (OperatorSpec.state_bytes x tuples — the DES-side ledger of the same
    #  StateSpec-derived traffic the §3.3 constraint and fluid solver charge)
    pane_latency_p50: float = math.nan  # seconds, pane-end event generated
    pane_latency_p99: float = math.nan  # at the spout -> pane fired
    panes_fired: int = 0            # event-time panes fired (post-warmup)
    pane_batches: int = 0           # watermark advances that released >=1
    # pane — the unit of work the segmented engine executes (one stacked
    # kernel call per batch), so panes_fired/pane_batches is the
    # amortization the runtime gets over a pane-at-a-time loop


def probe_et_spacing(app, batch: int = 256, batches: int = 3,
                     seed: int = 0) -> Dict[str, float]:
    """Empirical event-time increment per tuple, per spout.

    Draws ``batches`` seeded batches from each spout that declares
    ``event_time=`` and reports the mean increment —
    ``(max - min) / (count - 1)`` over the observed event times — so the
    DES paces watermarks (and therefore pane firing and pane latency) at
    the *app's* actual event-time density instead of the one-tick-per-
    tuple constant.  Bursty sources (many readings per tick, or sparse
    ticks) get correspondingly tighter ``pane_latency_p50/p99``.
    """
    out: Dict[str, float] = {}
    for spout, extractor in (getattr(app, "event_time", None) or {}).items():
        source = app.source_for(spout)
        ets = [extract_event_times(source(batch, seed + b), extractor)
               for b in range(batches)]
        allts = np.concatenate([e for e in ets if len(e)]) \
            if any(len(e) for e in ets) else np.zeros(0)
        if len(allts) > 1 and float(allts.max()) > float(allts.min()):
            out[spout] = (float(allts.max()) - float(allts.min())) \
                / (len(allts) - 1)
        else:
            out[spout] = 1.0
    return out


def _spout_rows(app, op: str, batch: int, batches: int,
                seed: int) -> List[np.ndarray]:
    """Seeded sample batches from every spout upstream of ``op`` (the probe
    convention: extractors are applied to *spout* rows, valid whenever the
    upstream path passes the probed columns through unchanged — true of
    every benchmark app and documented as the probes' contract)."""
    from .runtime import upstream_spouts
    rows = []
    for sp in upstream_spouts(app.graph, op):
        source = app.source_for(sp)
        rows.extend(source(batch, seed + b) for b in range(batches))
    return rows


def probe_pane_keys(app, batch: int = 256, batches: int = 3,
                    seed: int = 0) -> Dict[str, float]:
    """Empirical per-span pane multiplicity of keyed event-time windows.

    For each operator declaring keyed pane groups
    (``WindowSpec(keyed=True)``), draws seeded batches from its upstream
    spouts and counts distinct non-empty ``(key, span)`` pairs against
    distinct spans — the mean number of key panes one grid span fires.
    ``des_simulate(pane_keys=...)`` scales its grid-walk pane accounting by
    this factor (the DES tracks rates, not tuple contents, so it cannot see
    key occupancy itself); ``Plan.simulate`` plumbs the probe in
    automatically.  Unkeyed windows are multiplicity 1 and omitted.
    """
    out: Dict[str, float] = {}
    routes = compile_routes(app)
    for op, sspec in (getattr(app, "state", None) or {}).items():
        w = sspec.window
        if w is None or not w.time or not w.keyed:
            continue
        key_by = routes.key_extractor(op)
        pairs, spans = set(), set()
        for arr in _spout_rows(app, op, batch, batches, seed):
            if not len(arr):
                continue
            ets = extract_event_times(arr, w.time_by)
            keys = extract_keys(arr, key_by)
            k_lo, k_hi = pane_range(ets, w.size, w.slide)
            for lo, hi, key in zip(k_lo, k_hi, keys):
                for k in range(int(lo), int(hi) + 1):
                    pairs.add((k, int(key)))
                    spans.add(k)
        out[op] = len(pairs) / max(len(spans), 1)
    return out


def replay_pane_counts(app, *, batches: int, batch: int = 256,
                       seed: int = 0,
                       parallelism: Optional[Dict[str, int]] = None
                       ) -> Dict[str, int]:
    """Exact pane ledger for a deterministic replay (``max_batches`` mode).

    Replays every spout's seeded draws (replica ``i`` of a spout seeds
    ``seed + 7919*i + b``, exactly the runtime's enumeration) through the
    shared pane arithmetic and counts the non-empty panes each event-time
    windowed operator must fire by end of stream: distinct ``(key, span)``
    pairs for keyed pane groups, distinct spans otherwise.  Replication of
    the windowed operator shards panes without changing their union, so
    the ledger is the runtime's total ``panes_fired`` for any replica
    count — provided no tuple goes late (lateness >= the stream's skew;
    the benchmark sources guarantee it), since late rows never join a
    pane.  This is the DES-side ground truth the runtime==DES pane-count
    assertions compare against.
    """
    parallelism = parallelism or {}
    out: Dict[str, int] = {}
    routes = compile_routes(app)
    from .runtime import upstream_spouts
    for op, sspec in (getattr(app, "state", None) or {}).items():
        w = sspec.window
        if w is None or not w.time:
            continue
        key_by = routes.key_extractor(op) if w.keyed else None
        panes = set()
        for sp in upstream_spouts(app.graph, op):
            source = app.source_for(sp)
            for i in range(parallelism.get(sp, 1)):
                for b in range(batches):
                    arr = source(batch, seed + 7919 * i + b)
                    if not len(arr):
                        continue
                    ets = extract_event_times(arr, w.time_by)
                    keys = extract_keys(arr, key_by) if w.keyed \
                        else np.zeros(len(arr), np.int64)
                    k_lo, k_hi = pane_range(ets, w.size, w.slide)
                    for lo, hi, key in zip(k_lo, k_hi, keys):
                        for k in range(int(lo), int(hi) + 1):
                            panes.add((k, int(key)))
        out[op] = len(panes)
    return out


def des_simulate(graph: ExecutionGraph, machine: MachineSpec,
                 placement: List[int], input_rate,
                 batch: int = 64, horizon: float = 0.02,
                 queue_cap: int = 64, warmup_frac: float = 0.3,
                 seed: int = 0,
                 routes: Optional[RoutingTable] = None,
                 time_windows: Optional[Dict[str, WindowSpec]] = None,
                 et_spacing: Union[float, Mapping[str, float]] = 1.0,
                 pane_keys: Optional[Mapping[str, float]] = None
                 ) -> DesResult:
    """Simulate ``horizon`` seconds of plan execution.

    Jumbo tuples of ``batch`` tuples flow through bounded FCFS queues.  CPU
    contention is modelled as processor sharing sampled at service start:
    service stretches by (busy threads on socket / cores) when oversubscribed.
    Full queues drop the jumbo (a stand-in for backpressure; the reported R
    under drops equals the backpressured stable rate for these feed-forward
    graphs).

    Tuple delivery follows the compiled routing tables
    (:func:`repro.streaming.routing.unit_delivery` — selectivity x partition
    strategy x fan-out), the same substrate the planner and the threaded
    runtime consume; ``routes`` defaults to the table the graph was compiled
    with.  ``input_rate`` is the external ingress rate: a float feeds every
    spout operator at that rate; a ``{spout_op: rate}`` mapping feeds each
    spout its own stream (multi-spout apps, e.g. Linear Road's
    historical-query source).

    Memory traffic is charged per processed tuple from the operator specs
    (``mem_bytes``, which topologies with declared state derive from their
    ``StateSpec``): when a socket's cumulative byte rate exceeds its local
    bandwidth, service times on that socket stretch by the oversubscription
    factor — the DES-side analogue of the fluid solver's ``mem_mult`` and
    the §3.3 constraint.

    ``time_windows`` (``{operator: WindowSpec(time=True)}``, what
    ``Plan.simulate`` passes from the app's declarations) turns on
    *watermark delivery*: each spout unit's low-watermark advances with its
    emitted tuples (``et_spacing`` event-time units per tuple — a float for
    every spout, or a ``{spout_op: spacing}`` mapping; ``Plan.simulate``
    passes the per-spout empirical mean from :func:`probe_et_spacing`,
    and the 1.0 default is the SD convention of one tick per reading),
    rides the same
    ``unit_delivery`` edges as the jumbo tuples (one hop per service
    completion), and is min-merged per consumer unit exactly like the
    threaded runtime's :class:`~.routing.WatermarkMerger`.  Windowed units
    fire panes on the same grid arithmetic the runtime uses
    (:func:`repro.streaming.state.grid_pane_ends`), and
    ``DesResult.pane_latency_p50/p99`` report pane-end generation at the
    spout -> pane firing — the latency cost of waiting for completeness
    (batching + queueing + lateness wait), which no other layer models.
    Panes are paced on the dense grid (the DES tracks rates, not contents).

    ``pane_keys`` (``{operator: multiplicity}``, from
    :func:`probe_pane_keys`) corrects the grid walk for *keyed* pane
    groups: one grid span of a keyed window fires one pane per occupied
    key, so ``panes_fired`` and the ``pane_latency`` sample weights scale
    by the probed per-span multiplicity.  The multiplicity is divided
    across the operator's units — keys shard over replicas, the grid does
    not — so the op-level total matches the runtime's sharded-pane union
    instead of multiplying by the replica count.
    """
    rng = np.random.default_rng(seed)
    n = graph.n_units
    sock = list(placement)
    te = [r.spec.exec_s for r in graph.replicas]
    group = [r.group for r in graph.replicas]
    mbytes = [r.spec.mem_bytes for r in graph.replicas]
    sbytes = [r.spec.state_bytes for r in graph.replicas]
    delivery = unit_delivery(graph, routes)
    if isinstance(input_rate, dict):
        _validate_spout_rates(graph, input_rate)

    # -- event-time windows: watermark state (see docstring) ---------------
    win_units: Dict[int, WindowSpec] = {}
    if time_windows:
        unknown = sorted(set(time_windows) - set(graph.logical.operators))
        if unknown:
            raise ValueError(
                f"time_windows names unknown operators {unknown}")
        for op, wspec in time_windows.items():
            if not wspec.time:
                raise ValueError(
                    f"time_windows[{op!r}] is a count window; the DES "
                    "paces event-time panes only")
            for vi in graph.units_of(op):
                win_units[vi] = wspec
    unit_mult: Dict[int, float] = {}
    if pane_keys:
        unknown = sorted(set(pane_keys) - set(time_windows or {}))
        if unknown:
            raise ValueError(
                f"pane_keys names operators without a declared time "
                f"window: {unknown}")
        for op, mult in pane_keys.items():
            # keys shard over the op's units; the grid walk repeats per unit
            for vi in graph.units_of(op):
                unit_mult[vi] = float(mult) / graph.parallelism[op]
    track_wm = bool(win_units)
    unit_wm = [-math.inf] * n
    lane_wm: Dict[Tuple[int, int], float] = {}
    unit_producers = {v: sorted({u for u, _ in graph.in_edges[v]})
                      for v in range(n)}
    fired_bound = {v: -math.inf for v in win_units}
    spout_count = {v: 0 for v in graph.spout_units()}
    if isinstance(et_spacing, Mapping):
        unknown = sorted(set(et_spacing)
                         - set(graph.logical.spouts()))
        if unknown:
            raise ValueError(
                f"et_spacing names non-spout operators {unknown}")
        unit_spacing = {v: float(et_spacing.get(graph.replicas[v].op, 1.0))
                        for v in spout_count}
    else:
        unit_spacing = {v: float(et_spacing) for v in spout_count}
    et_log_e: Dict[int, List[float]] = {v: [] for v in spout_count}
    et_log_t: Dict[int, List[float]] = {v: [] for v in spout_count}
    pane_lat: List[float] = []
    panes_fired = 0
    pane_batches = 0
    anc: Dict[int, List[int]] = {}          # windowed unit -> spout units
    if track_wm:
        lg = graph.logical
        for vi in win_units:
            seen, frontier = set(), [graph.replicas[vi].op]
            while frontier:
                x = frontier.pop()
                if x in seen:
                    continue
                seen.add(x)
                frontier.extend(lg.producers(x))
            anc[vi] = [u for sp in lg.spouts() if sp in seen
                       for u in graph.units_of(sp)]

    def _complete_wall(vi: int, end: float, now: float) -> float:
        """Wall time the *slowest* ancestor source generated the pane-end
        event (the moment the pane was complete in the outside world)."""
        t = 0.0
        for s in anc[vi]:
            i = bisect.bisect_left(et_log_e[s], end - 1e-9)
            t = max(t, et_log_t[s][i] if i < len(et_log_t[s]) else now)
        return t

    def _propagate_wm(u: int, now: float) -> None:
        """One watermark hop along the same delivery edges as the jumbos:
        min-merge per consumer unit, fire pane *batches* the merged mark
        passed — every released pane of one advance is one unit of work
        (the segmented engine's stacked kernel call), which is what
        ``pane_batches`` counts against ``panes_fired``."""
        nonlocal panes_fired, pane_batches
        for cv, _ in delivery[u]:
            lane_wm[(u, cv)] = unit_wm[u]
            merged = min(lane_wm.get((p, cv), -math.inf)
                         for p in unit_producers[cv])
            if not merged > unit_wm[cv]:
                continue
            unit_wm[cv] = merged
            wspec = win_units.get(cv)
            if wspec is None:
                continue
            bound = merged - wspec.lateness
            ends = grid_pane_ends(fired_bound[cv], bound,
                                  wspec.size, wspec.slide)
            if len(ends) and now >= warm:
                # keyed pane groups: each grid span fires one pane per
                # occupied key (probed multiplicity), so counts and the
                # latency sample weights scale together
                mult = unit_mult.get(cv, 1.0)
                panes_fired += len(ends) * mult
                pane_batches += 1
                w = max(1, int(round(mult)))
                for e in ends:
                    pane_lat.extend([now - _complete_wall(cv, e, now)] * w)
            fired_bound[cv] = max(fired_bound[cv], bound)

    def spout_rate(v: int) -> float:
        op = graph.replicas[v].op
        rate = input_rate.get(op, 0.0) if isinstance(input_rate, dict) \
            else input_rate
        return rate * group[v] / graph.parallelism[op] / batch  # jumbos/sec

    tf = [[0.0] * n for _ in range(n)]
    for u, v, _ in graph.edges:
        su, sv = sock[u], sock[v]
        if su != UNPLACED and sv != UNPLACED and su != sv:
            tf[u][v] = machine.fetch_time(su, sv,
                                          graph.replicas[v].spec.tuple_bytes)

    queues: List[List[Tuple[float, int]]] = [[] for _ in range(n)]  # (t0, prod)
    busy = [0] * n                   # busy threads per unit (<= group)
    sock_busy = [0] * machine.n_sockets
    emit_acc: Dict[Tuple[int, int], float] = {}   # (u, v) -> fractional tuples
    emit_t0: Dict[Tuple[int, int], float] = {}
    lat: List[float] = []
    sink_count = 0.0
    drops = 0
    warm = horizon * warmup_frac

    heap: List[Tuple[float, int, str, int, float]] = []
    seq = 0

    def push(t, kind, unit, t0, prod=-1):
        nonlocal seq
        heapq.heappush(heap, (t, seq, kind, unit, t0, prod))
        seq += 1

    mem_acc = [0.0] * machine.n_sockets   # cumulative M bytes per socket
    state_total = 0.0

    def service_time(v: int, prod: int, now: float) -> float:
        s = sock[v]
        over = 1.0
        if s != UNPLACED:
            over = max(1.0, sock_busy[s] / machine.cores_per_socket)
            if now > 1e-6:
                # bandwidth contention: stretch by the socket's cumulative
                # memory-rate oversubscription (state + tuple traffic per
                # the specs), mirroring fluid_solve's mem_mult
                over *= max(1.0, mem_acc[s] / now / machine.local_bw)
        base = te[v] + (tf[prod][v] if prod >= 0 else 0.0)
        return batch * base * over

    busy_s = [0.0] * n
    unit_tuples = [0.0] * n

    def try_start(v: int, now: float):
        nonlocal state_total
        while busy[v] < group[v] and queues[v]:
            t0, prod = queues[v].pop(0)
            busy[v] += 1
            if sock[v] != UNPLACED:
                sock_busy[sock[v]] += 1
                mem_acc[sock[v]] += batch * mbytes[v]
            svc = service_time(v, prod, now)
            if now >= warm:
                busy_s[v] += svc
                unit_tuples[v] += batch
                state_total += batch * sbytes[v]
            push(now + svc, "done", v, t0, prod)

    def deliver(u: int, v: int, amount: float, t0: float, now: float):
        nonlocal drops
        key = (u, v)
        acc = emit_acc.get(key, 0.0) + amount
        if key not in emit_t0:
            emit_t0[key] = t0
        while acc >= batch:
            acc -= batch
            if len(queues[v]) >= queue_cap:
                drops += 1
            else:
                queues[v].append((emit_t0[key], u))
                try_start(v, now)
            emit_t0[key] = t0
        emit_acc[key] = acc

    # spout arrivals: deterministic at the per-spout ingress rate
    for v in graph.spout_units():
        rate = spout_rate(v)
        if rate > 0:
            push(rng.uniform(0, 1.0 / rate), "arrive", v, 0.0)

    while heap:
        now, _, kind, v, t0, prod = heapq.heappop(heap)
        if now > horizon:
            break
        if kind == "arrive":
            push(now + 1.0 / spout_rate(v), "arrive", v, 0.0)
            if track_wm:
                # the source generated `batch` more tuples: its event clock
                # (and low-watermark) advances whether or not the jumbo fits
                spout_count[v] += batch
                unit_wm[v] = spout_count[v] * unit_spacing[v]
                et_log_e[v].append(unit_wm[v])
                et_log_t[v].append(now)
            if len(queues[v]) >= queue_cap:
                drops += 1
            else:
                queues[v].append((now, v))
                try_start(v, now)
        else:                                         # done
            busy[v] -= 1
            if sock[v] != UNPLACED:
                sock_busy[sock[v]] -= 1
            if not delivery[v]:                       # sink
                if now >= warm:
                    sink_count += batch
                    lat.append(now - t0)
            for cv, w in delivery[v]:
                deliver(v, cv, batch * w, t0, now)
            if track_wm:
                _propagate_wm(v, now)
            try_start(v, now)

    span = max(horizon - warm, 1e-9)
    lat_arr = np.array(lat) if lat else np.array([0.0])
    pane_arr = np.array(pane_lat) if pane_lat else None
    return DesResult(
        R=sink_count / span,
        latency_p50=float(np.percentile(lat_arr, 50)),
        latency_p99=float(np.percentile(lat_arr, 99)),
        sim_time=horizon, sink_tuples=sink_count, queue_drops=drops,
        busy_s=np.array(busy_s), unit_tuples=np.array(unit_tuples),
        mem_rate=np.array(mem_acc) / horizon, state_bytes=state_total,
        pane_latency_p50=(math.nan if pane_arr is None else
                          float(np.percentile(pane_arr, 50))),
        pane_latency_p99=(math.nan if pane_arr is None else
                          float(np.percentile(pane_arr, 99))),
        panes_fired=int(round(panes_fired)), pane_batches=int(pane_batches))


def measure_capacity(graph: ExecutionGraph, machine: MachineSpec,
                     placement: List[int], batch: int = 64,
                     horizon: float = 0.02, seed: int = 0,
                     routes: Optional[RoutingTable] = None,
                     **des_kw) -> DesResult:
    """Paper §6.1 protocol: raise I to saturation and report the stable rate.

    The fluid solver gives the saturation estimate; the DES is then driven at
    1.05x that rate (slightly over-feeding, as the paper does) and the
    observed sink rate is the measured capacity.  Each spout operator is fed
    its *own* fluid saturation rate, so multi-spout apps (e.g. Linear Road's
    historical-query stream) are not cross-over-fed.
    """
    sat = fluid_solve(graph, machine, placement, input_rate=None)
    # convert sink rate back to required ingress via the fluid spout rates
    rates: Dict[str, float] = {}
    for v in graph.spout_units():
        op = graph.replicas[v].op
        rates[op] = rates.get(op, 0.0) + sat.processed[v] * 1.05
    if sum(rates.values()) <= 0:
        return des_simulate(graph, machine, placement, 1.0, batch, horizon,
                            seed=seed, routes=routes, **des_kw)
    return des_simulate(graph, machine, placement, rates, batch, horizon,
                        seed=seed, routes=routes, **des_kw)
