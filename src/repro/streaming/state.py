"""Managed keyed operator state: declared, partitioned, migratable.

BriskStream's benchmark operators are stateful (WC's counter, LR's account
balances) and the paper's memory-bandwidth constraint (§3.3, ``mem_bytes``)
exists precisely because state access dominates NUMA cost — yet ad-hoc
per-kernel dicts are invisible to the planner, duplicated per replica and
silently discarded on replan.  This module makes operator state a *declared*
artefact that every layer shares:

* :class:`StateSpec` — the declaration, attached to an operator via
  ``Topology.op(state=...)``.  Three kinds:

  - ``"keyed"``  — a dense table sharded **by the operator's compiled keyed
    route**: replica ``j`` of ``k`` owns exactly the keys ``key % k == j``
    that the router delivers to it, so the keyed tuple-conservation contract
    extends to state (the ownership-union of the replica stores equals the
    single-replica store, byte for byte).
  - ``"value"``  — a private per-replica value (running aggregates, window
    history); not merged across replicas.
  - ``"broadcast"`` — a read-mostly table replicated to every replica and
    kept in sync by a broadcast-partitioned update stream (every replica
    applies the same updates in lane-FIFO order), e.g. FD's model weights.

  The declaration also *prices* the state: ``bytes_per_tuple()`` feeds the
  operator's ``mem_bytes`` (paper Table 1 ``M``) so the §3.3 bandwidth
  constraint, the fluid solver and the DES all charge state traffic from the
  declaration instead of a hand-tuned constant.

* :class:`WindowSpec` / :class:`WindowState` — declarative tumbling/sliding
  count windows (``moving_avg``-style history without hand-rolled buffers).

* :class:`KeyedStore` / :class:`ValueStore` / :class:`BroadcastTable` — the
  runtime stores.  Kernels receive them through the dict-compatible
  :class:`OperatorState` handle (``state.managed`` / ``state.window``), so
  undeclared scratch keys keep working as plain dict entries.

* :func:`merge_keyed` / :func:`repartition_keyed` / :func:`migrate_states` —
  elastic state migration: merge the old shards by key ownership, repartition
  onto the new replica set (``Plan.replan`` then ``Plan.execute(
  initial_states=...)``), and a WC/LR run interrupted mid-stream resumes with
  byte-identical keyed state.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

STATE_KINDS = ("keyed", "value", "broadcast")


@dataclasses.dataclass(frozen=True)
class WindowSpec:
    """Count-based window declaration.

    ``size`` tuples per window; ``slide`` is the hop between emitted windows
    (``1`` = per-tuple sliding, the default; ``slide == size`` = tumbling).
    """

    size: int
    slide: int = 1

    def __post_init__(self):
        if self.size < 1:
            raise ValueError(f"window size must be >= 1, got {self.size}")
        if not 1 <= self.slide <= self.size:
            raise ValueError(
                f"window slide must be in [1, size={self.size}], "
                f"got {self.slide}")

    @classmethod
    def tumbling(cls, size: int) -> "WindowSpec":
        return cls(size, slide=size)

    @property
    def is_tumbling(self) -> bool:
        return self.slide == self.size

    def bytes_per_tuple(self, item_bytes: float) -> float:
        """Window-history bytes scanned per input tuple: each emitted window
        touches ``size`` items and one window is emitted every ``slide``
        tuples."""
        return item_bytes * self.size / self.slide


@dataclasses.dataclass(frozen=True)
class StateSpec:
    """Declared operator state (see module docstring for the three kinds).

    ``item_bytes``  — bytes charged per state access, as profiled (cache-line
                      -fraction granularity, the paper's ``M`` provenance).
    ``reads_per_tuple`` / ``writes_per_tuple`` — average state touches per
                      processed tuple.
    ``key_space``   — dense table size (required for "keyed", optional
                      sizing hint for "broadcast").
    ``dtype``/``fill`` — table element type and initial value.
    ``init``        — factory for the initial table/value (overrides
                      ``fill``; required shape ``(key_space,)`` for keyed).
    ``window``      — optional :class:`WindowSpec`; its history scan is
                      added to ``bytes_per_tuple``.
    """

    kind: str
    item_bytes: float = 8.0
    reads_per_tuple: float = 1.0
    writes_per_tuple: float = 1.0
    key_space: Optional[int] = None
    dtype: object = np.float64
    fill: float = 0.0
    init: Optional[Callable[[], np.ndarray]] = None
    window: Optional[WindowSpec] = None

    def __post_init__(self):
        if self.kind not in STATE_KINDS:
            raise ValueError(
                f"unknown state kind {self.kind!r} "
                f"(choose from {STATE_KINDS})")
        if self.item_bytes <= 0:
            raise ValueError("state item_bytes must be positive")
        if self.reads_per_tuple < 0 or self.writes_per_tuple < 0:
            raise ValueError("state reads/writes per tuple must be >= 0")
        if self.kind == "keyed" and (self.key_space is None
                                     or self.key_space < 1):
            raise ValueError(
                "keyed state requires key_space= (the dense table size the "
                "compiled route's keys index into)")

    def bytes_per_tuple(self) -> float:
        """State traffic per processed tuple, charged into ``mem_bytes``."""
        b = self.item_bytes * (self.reads_per_tuple + self.writes_per_tuple)
        if self.window is not None:
            b += self.window.bytes_per_tuple(self.item_bytes)
        return b

    def initial_table(self) -> np.ndarray:
        if self.init is not None:
            return np.asarray(self.init()).copy()
        assert self.key_space is not None
        return np.full(self.key_space, self.fill, dtype=self.dtype)


# ---------------------------------------------------------------------------
# Runtime stores
# ---------------------------------------------------------------------------


class KeyedStore:
    """Dense keyed table sharded exactly like the operator's keyed route.

    Shard ``shard`` of ``n_shards`` owns keys ``key % n_shards == shard`` —
    the same assignment :func:`repro.streaming.routing.split_by_key` makes —
    so under keyed routing each key is only ever touched by its owner and
    :func:`merge_keyed` reconstructs the single-replica store exactly.
    """

    __slots__ = ("spec", "n_shards", "shard", "table")

    def __init__(self, spec: StateSpec, n_shards: int = 1, shard: int = 0,
                 table: Optional[np.ndarray] = None):
        assert spec.kind == "keyed"
        assert 0 <= shard < n_shards
        self.spec = spec
        self.n_shards = n_shards
        self.shard = shard
        self.table = spec.initial_table() if table is None else table
        if len(self.table) != spec.key_space:
            raise ValueError(
                f"keyed table has {len(self.table)} entries for "
                f"key_space={spec.key_space}")

    def owned_mask(self) -> np.ndarray:
        return np.arange(len(self.table)) % self.n_shards == self.shard

    def get(self, keys: np.ndarray) -> np.ndarray:
        return self.table[keys]

    def add(self, keys: np.ndarray, amounts=1) -> None:
        np.add.at(self.table, keys, amounts)

    def put(self, keys: np.ndarray, values) -> None:
        self.table[keys] = values

    def snapshot(self) -> np.ndarray:
        return self.table.copy()

    def __repr__(self) -> str:
        return (f"KeyedStore(shard {self.shard}/{self.n_shards}, "
                f"{len(self.table)} keys)")


class ValueStore:
    """Private per-replica value (running aggregate, model residuals, ...)."""

    __slots__ = ("spec", "value")

    def __init__(self, spec: StateSpec):
        assert spec.kind == "value"
        self.spec = spec
        self.value = spec.init() if spec.init is not None else None


class BroadcastTable:
    """Read-replicated table, synced by a broadcast update stream.

    Every replica receives every update (broadcast partitioning), and
    ``load`` applies them *last-writer-wins by version*: an update older
    than the installed one is ignored.  Since all replicas eventually see
    the same update set, they converge to the same (data, version) no
    matter how updates from concurrent producers interleave — replicas may
    differ transiently mid-stream, but drained runs end identical, which is
    what ``migrate_states`` relies on when it copies one replica's table.
    """

    __slots__ = ("spec", "data", "version")

    def __init__(self, spec: StateSpec,
                 data: Optional[np.ndarray] = None, version: int = 0):
        assert spec.kind == "broadcast"
        self.spec = spec
        if data is not None:
            self.data = data
        elif spec.init is not None:
            self.data = np.asarray(spec.init()).copy()
        elif spec.key_space is not None:
            self.data = np.full(spec.key_space, spec.fill, dtype=spec.dtype)
        else:
            self.data = None
        self.version = version

    def load(self, data: np.ndarray, version: Optional[int] = None) -> None:
        """Install an update.  ``version=None`` bumps the local counter
        (single-producer streams); versioned updates below the installed
        version are stale and dropped."""
        if version is not None and int(version) < self.version:
            return
        self.data = np.asarray(data).copy()
        self.version = self.version + 1 if version is None else int(version)


class WindowState:
    """Runtime buffer behind a :class:`WindowSpec`.

    ``slide(batch)`` is the vectorized per-tuple sliding path (slide == 1):
    returns ``concat(history, batch)`` — one aggregate per input tuple over
    the trailing ``size`` values — and retains the last ``size`` values,
    exactly the seed ``moving_avg`` convention (history starts as zeros).

    ``tumble(batch)`` is the general hop path: buffers tuples and returns
    every complete window (``size`` rows, advancing by ``slide``).
    """

    __slots__ = ("spec", "_hist", "_buf")

    def __init__(self, spec: WindowSpec, dtype=np.float64):
        self.spec = spec
        self._hist = np.zeros(spec.size, dtype=dtype)
        self._buf: Optional[np.ndarray] = None

    def slide(self, batch: np.ndarray) -> np.ndarray:
        if self.spec.slide != 1:
            raise ValueError(
                f"slide() is the per-tuple sliding path (slide=1); this "
                f"window hops by {self.spec.slide} — use tumble()")
        vals = np.concatenate([self._hist, batch])
        self._hist = vals[-self.spec.size:]
        return vals

    def tumble(self, batch: np.ndarray) -> List[np.ndarray]:
        buf = batch if self._buf is None else \
            np.concatenate([self._buf, batch])
        size, hop = self.spec.size, self.spec.slide
        out = []
        while len(buf) >= size:
            out.append(buf[:size].copy())
            buf = buf[hop:]
        self._buf = buf
        return out


class OperatorState(dict):
    """Per-replica state handle a kernel receives.

    A plain ``dict`` for undeclared scratch keys (the seed convention keeps
    working), plus the declared artefacts:

    ``managed`` — :class:`KeyedStore` / :class:`ValueStore` /
    :class:`BroadcastTable` per the operator's :class:`StateSpec`;
    ``window`` — :class:`WindowState` when the spec declares one;
    ``replica`` / ``fanout`` — this replica's position in the operator.
    """

    managed: Optional[object]
    window: Optional[WindowState]

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self.managed = None
        self.window = None
        self.replica = 0
        self.fanout = 1


def make_operator_state(spec: Optional[StateSpec], fanout: int = 1,
                        replica: int = 0) -> OperatorState:
    """Build one replica's state handle from its declaration (or a bare
    dict-compatible handle when no state is declared)."""
    st = OperatorState()
    st.replica, st.fanout = replica, fanout
    if spec is None:
        return st
    if spec.window is not None:
        st.window = WindowState(spec.window, dtype=spec.dtype)
    if spec.kind == "keyed":
        st.managed = KeyedStore(spec, n_shards=fanout, shard=replica)
    elif spec.kind == "broadcast":
        st.managed = BroadcastTable(spec)
    else:
        st.managed = ValueStore(spec)
    return st


# ---------------------------------------------------------------------------
# Elastic migration: merge by ownership, repartition onto the new replica set
# ---------------------------------------------------------------------------


def merge_keyed(stores: Sequence[KeyedStore]) -> np.ndarray:
    """Union of keyed shards by ownership: entry ``key`` comes from the shard
    with ``key % n_shards == shard``.  Under route-aligned keyed execution
    this equals the single-replica table byte for byte."""
    if not stores:
        raise ValueError("merge_keyed needs at least one shard")
    spec = stores[0].spec
    merged = spec.initial_table()
    for s in stores:
        if s.spec.key_space != spec.key_space:
            raise ValueError("cannot merge keyed stores of different "
                             "key spaces")
        mask = s.owned_mask()
        merged[mask] = s.table[mask]
    return merged


def repartition_keyed(spec: StateSpec, merged: np.ndarray,
                      n_shards: int) -> List[KeyedStore]:
    """Split a merged table onto ``n_shards`` new owners; entries outside a
    shard's residue class reset to the initial value (they are unreachable
    under the new route and must not leak into a later merge)."""
    fresh = spec.initial_table()
    out = []
    for j in range(n_shards):
        table = fresh.copy()
        mask = np.arange(len(merged)) % n_shards == j
        table[mask] = merged[mask]
        out.append(KeyedStore(spec, n_shards=n_shards, shard=j, table=table))
    return out


def migrate_states(app, states: Dict[str, List[OperatorState]],
                   parallelism: Dict[str, int]
                   ) -> Dict[str, List[OperatorState]]:
    """Repartition a finished run's states onto a new replica set.

    The elastic half of ``Plan.replan``: ``keyed`` stores are merged by key
    ownership and re-sharded to the new fan-out; ``broadcast`` tables are
    copied to every new replica (replicas are identical by construction);
    ``value`` states are per-replica by definition — the first
    ``min(k_old, k_new)`` replicas carry over, the rest start fresh.
    Undeclared dict scratch state does not migrate (declare it if it must
    survive a replan).  Feed the result to ``run_app(initial_states=...)`` /
    ``Plan.execute(initial_states=...)``.
    """
    specs: Dict[str, StateSpec] = getattr(app, "state", {}) or {}
    out: Dict[str, List[OperatorState]] = {}
    for name in app.graph.operators:
        k_new = parallelism.get(name, 1)
        spec = specs.get(name)
        old = states.get(name, [])
        fresh = [make_operator_state(spec, k_new, j) for j in range(k_new)]
        if spec is None or not old:
            out[name] = fresh
            continue
        if spec.kind == "keyed":
            merged = merge_keyed([st.managed for st in old
                                  if st.managed is not None])
            shards = repartition_keyed(spec, merged, k_new)
            for st, shard in zip(fresh, shards):
                st.managed = shard
        elif spec.kind == "broadcast":
            src = old[0].managed
            for st in fresh:
                st.managed = BroadcastTable(
                    spec,
                    data=None if src.data is None else src.data.copy(),
                    version=src.version)
        else:                                   # value: best-effort carry
            for j in range(min(len(old), k_new)):
                fresh[j].managed = old[j].managed
                fresh[j].window = old[j].window
        out[name] = fresh
    return out
