"""Managed keyed operator state: declared, partitioned, migratable.

BriskStream's benchmark operators are stateful (WC's counter, LR's account
balances) and the paper's memory-bandwidth constraint (§3.3, ``mem_bytes``)
exists precisely because state access dominates NUMA cost — yet ad-hoc
per-kernel dicts are invisible to the planner, duplicated per replica and
silently discarded on replan.  This module makes operator state a *declared*
artefact that every layer shares:

* :class:`StateSpec` — the declaration, attached to an operator via
  ``Topology.op(state=...)``.  Three kinds:

  - ``"keyed"``  — a dense table sharded **by the operator's compiled keyed
    route**: replica ``j`` of ``k`` owns exactly the keys ``key % k == j``
    that the router delivers to it, so the keyed tuple-conservation contract
    extends to state (the ownership-union of the replica stores equals the
    single-replica store, byte for byte).
  - ``"value"``  — a private per-replica value (running aggregates, window
    history); not merged across replicas.
  - ``"broadcast"`` — a read-mostly table replicated to every replica and
    kept in sync by a broadcast-partitioned update stream (every replica
    applies the same updates in lane-FIFO order), e.g. FD's model weights.

  The declaration also *prices* the state: ``bytes_per_tuple()`` feeds the
  operator's ``mem_bytes`` (paper Table 1 ``M``) so the §3.3 bandwidth
  constraint, the fluid solver and the DES all charge state traffic from the
  declaration instead of a hand-tuned constant.

* :class:`WindowSpec` / :class:`WindowState` — declarative tumbling/sliding
  count windows (``moving_avg``-style history without hand-rolled buffers).

* Event-time windows (``WindowSpec(..., time=True)`` or the
  ``WindowSpec.time_tumbling`` / ``time_sliding`` constructors) — panes over
  an *event-time column* rather than arrival counts, fired by low-watermark
  passage (see :mod:`repro.streaming.routing` for merge semantics).  The
  runtime buffer is :class:`EventTimeWindowState`: out-of-order tuples are
  held until the merged watermark passes ``pane_end + lateness``, pane
  contents are emitted in a *canonical order* (event time, then row bytes)
  so they are byte-identical no matter how arrivals were shuffled, and
  tuples arriving after their last pane fired are **counted**
  (``late_drops``), never silently discarded.  The pane-frontier arithmetic
  (:func:`pane_range`, :func:`fired_bound`) is shared with the DES so both
  layers assign tuples to panes identically.

* **Segmented pane execution** — the one firing path for every window kind.
  When a watermark (or count boundary) releases N panes, the engine builds
  *one* stacked buffer plus a segment-boundary index
  (:class:`PaneSegments`, ``reduceat``-style offsets) via a single
  vectorized gather (:func:`gather_segments`) and hands the whole
  :class:`PaneBatch` to the kernel **once**; per-pane outputs are emitted
  in canonical segment order, byte-identical to driving the kernel one
  pane at a time.  Kernels opt in with the :func:`segmented` decorator and
  read ``state.segments``; unmarked kernels keep the single-span contract
  (``state.pane``) — the runtime drives them one *segment slice* at a time
  over the same stacked buffer, so there is exactly one pane-assembly path.
  Count windows (:meth:`WindowState.tumble`) are the degenerate segmented
  case: complete windows are contiguous segments of the arrival buffer.

* **Keyed event-time panes** (``WindowSpec(..., keyed=True)``) — one pane
  group per routing key: the pane unit becomes ``(key, span)`` and the
  buffer groups rows by the *compiled keyed route's* extractor, so
  replicated keyed windowed operators fire sharded panes whose union
  equals the single-replica run's panes exactly (the PR 3 store-union
  invariant extended to panes).

* :class:`KeyedStore` / :class:`ValueStore` / :class:`BroadcastTable` — the
  runtime stores.  Kernels receive them through the dict-compatible
  :class:`OperatorState` handle (``state.managed`` / ``state.window``), so
  undeclared scratch keys keep working as plain dict entries.

* :func:`merge_keyed` / :func:`repartition_keyed` / :func:`migrate_states` —
  elastic state migration: merge the old shards by key ownership, repartition
  onto the new replica set (``Plan.replan`` then ``Plan.execute(
  initial_states=...)``), and a WC/LR run interrupted mid-stream resumes with
  byte-identical keyed state.
"""
from __future__ import annotations

import copy as _copylib
import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .routing import extract_event_times, extract_keys

STATE_KINDS = ("keyed", "value", "broadcast")


@dataclasses.dataclass(frozen=True)
class WindowSpec:
    """Count- or event-time-based window declaration.

    Count windows (the default): ``size`` tuples per window; ``slide`` is
    the hop between emitted windows (``1`` = per-tuple sliding;
    ``slide == size`` = tumbling).

    Event-time windows (``time=True``, or the :meth:`time_tumbling` /
    :meth:`time_sliding` constructors): ``size`` and ``slide`` are spans of
    the *event-time column* over the pane grid ``[k*slide, k*slide + size)``
    anchored at event time 0.  A pane fires when the operator's merged
    low-watermark (see :class:`repro.streaming.routing.WatermarkMerger`)
    passes ``pane_end + lateness`` — so any arrival skew up to ``lateness``
    cannot change pane contents — and tuples whose every pane has already
    fired are *counted* (:attr:`EventTimeWindowState.late_drops`), never
    silently dropped.  ``time_by`` names the event-time column of the
    operator's input batches (column index or callable; default: column 0
    of 2-D batches, the tuple value itself for 1-D).

    Keyed event-time panes (``keyed=True``, time windows only): the pane
    unit becomes ``(key, span)`` — one pane group per routing key, fired by
    the same merged watermark.  The key extractor is the operator's
    *compiled keyed route* (the ``key_by`` declaration), so the shard that
    owns a key fires exactly the panes a single-replica run would fire for
    that key — replication preserves pane bytes, not just pane unions.
    """

    size: float
    slide: float = 1
    time: bool = False
    lateness: float = 0.0
    time_by: object = None
    keyed: bool = False

    def __post_init__(self):
        if self.keyed and not self.time:
            raise ValueError("keyed panes are an event-time concept; "
                             "declare the window with time=True")
        if self.time:
            if not self.size > 0:
                raise ValueError(
                    f"time window size must be > 0, got {self.size}")
            if not 0 < self.slide <= self.size:
                raise ValueError(
                    f"time window slide must be in (0, size={self.size}], "
                    f"got {self.slide}")
            if self.lateness < 0:
                raise ValueError(
                    f"window lateness must be >= 0, got {self.lateness}")
            return
        if self.lateness:
            raise ValueError("lateness is an event-time concept; declare "
                             "the window with time=True")
        if self.time_by is not None:
            raise ValueError("time_by is an event-time concept; declare "
                             "the window with time=True")
        if self.size < 1:
            raise ValueError(f"window size must be >= 1, got {self.size}")
        if not 1 <= self.slide <= self.size:
            raise ValueError(
                f"window slide must be in [1, size={self.size}], "
                f"got {self.slide}")

    @classmethod
    def tumbling(cls, size: int) -> "WindowSpec":
        return cls(size, slide=size)

    @classmethod
    def time_tumbling(cls, size: float, *, lateness: float = 0.0,
                      time_by: object = None,
                      keyed: bool = False) -> "WindowSpec":
        return cls(size, slide=size, time=True, lateness=lateness,
                   time_by=time_by, keyed=keyed)

    @classmethod
    def time_sliding(cls, size: float, slide: float, *,
                     lateness: float = 0.0,
                     time_by: object = None,
                     keyed: bool = False) -> "WindowSpec":
        return cls(size, slide=slide, time=True, lateness=lateness,
                   time_by=time_by, keyed=keyed)

    @property
    def is_tumbling(self) -> bool:
        return self.slide == self.size

    def bytes_per_tuple(self, item_bytes: float) -> float:
        """Window bytes charged per input tuple.

        Count windows: each emitted window touches ``size`` items and one
        window is emitted every ``slide`` tuples.  Event-time windows: one
        buffered write plus one *gathered* read per pane the tuple joins
        (``size/slide`` panes on the grid).  The segmented pane engine
        sorts the buffer once per watermark and slices every released pane
        out of the one canonical order, so lateness-held stragglers no
        longer add a per-pane re-scan share — this is how the in-flight
        pane buffer reaches the planner's ``OperatorSpec.state_bytes`` /
        ``PlanEval.state_usage`` without over-pricing the pane *batch*.
        """
        if self.time:
            return item_bytes * (1.0 + self.size / self.slide)
        return item_bytes * self.size / self.slide

    def resident_tuples(self, et_spacing: float = 1.0) -> float:
        """Buffer occupancy in *tuples* — how many rows the window holds
        resident at once, the planner-side capacity view of in-flight
        pane batches (``OperatorSpec.state_resident_tuples`` ->
        ``PlanEval.state_resident_bytes``).

        Event-time windows hold a tuple until the watermark passes its
        last pane end plus the lateness allowance: ``(size + lateness)``
        event-time units of stream, i.e. ``(size + lateness)/et_spacing``
        tuples at ``et_spacing`` event-time units per tuple (default: the
        one-tick-per-reading convention).  Count windows are the
        degenerate segmented case and hold ``size`` arrivals of history.
        Occupancy is rate-independent — pricing it per wall-second was the
        over-charge the segmented engine retires (a 64-tick pane is
        microseconds of buffering at realistic rates, not 64 seconds).
        """
        if self.time:
            return (self.size + self.lateness) / max(et_spacing, _GRID_EPS)
        return float(self.size)


# ---------------------------------------------------------------------------
# Event-time pane arithmetic — shared by the runtime window state and the DES
# ---------------------------------------------------------------------------

_GRID_EPS = 1e-9


def pane_range(ets: np.ndarray, size: float,
               slide: float) -> Tuple[np.ndarray, np.ndarray]:
    """Inclusive pane-index range ``[k_lo, k_hi]`` containing each event
    time: pane ``k`` spans ``[k*slide, k*slide + size)`` on the grid
    anchored at 0.  The same arithmetic assigns tuples to panes in the
    threaded runtime and paces pane firing in the DES, which is what the
    runtime==DES pane-assignment equivalence tests pin down."""
    ets = np.asarray(ets, dtype=np.float64)
    k_hi = np.floor(ets / slide + _GRID_EPS).astype(np.int64)
    k_lo = np.floor((ets - size) / slide + _GRID_EPS).astype(np.int64) + 1
    return np.maximum(k_lo, 0), k_hi


def grid_pane_ends(lo: float, hi: float, size: float,
                   slide: float) -> np.ndarray:
    """Grid pane ends ``e = k*slide + size`` with ``lo < e <= hi`` (k >= 0).
    The DES walks this grid to fire panes as unit watermarks advance."""
    if not hi > lo or math.isinf(hi):
        return np.zeros(0)
    k1 = math.floor((hi - size) / slide + _GRID_EPS)
    k0 = max(0, math.floor((lo - size) / slide + _GRID_EPS) + 1) \
        if math.isfinite(lo) else 0
    if k1 < k0:
        return np.zeros(0)
    return np.arange(k0, k1 + 1, dtype=np.float64) * slide + size


# ---------------------------------------------------------------------------
# Segmented pane execution — the one firing path for every window kind
# ---------------------------------------------------------------------------


def segmented(kernel):
    """Mark a kernel as *segment-aware*.

    When a watermark releases N panes, the runtime invokes a segmented
    kernel **once** over the stacked buffer of all N panes with
    ``state.segments`` (:class:`PaneSegments`) set — ``reduceat`` over
    ``state.segments.starts`` is the idiomatic per-pane aggregate — and the
    kernel must emit its per-pane outputs in segment order (the engine's
    canonical pane order), which makes the one call byte-identical to the
    pane-at-a-time contract.  Unmarked kernels keep the single-span
    contract: the runtime drives them one segment slice at a time with
    ``state.pane`` set (the compat shim over the same stacked buffer).
    """
    kernel.segmented = True
    return kernel


def gather_segments(rows: np.ndarray, los: np.ndarray, his: np.ndarray
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Build one stacked buffer from segment ranges ``[los[i], his[i])`` of
    ``rows`` — the single vectorized gather behind every pane flush.

    Returns ``(stacked, offsets)`` where segment ``i`` is
    ``stacked[offsets[i]:offsets[i+1]]``.  Adjacent-contiguous ranges
    (tumbling panes, count windows) are returned as one zero-copy slice;
    overlapping ranges (sliding panes share rows) gather through a single
    fancy index built arithmetically — no per-pane python loop either way.
    """
    los = np.asarray(los, dtype=np.int64)
    his = np.asarray(his, dtype=np.int64)
    lens = his - los
    offsets = np.zeros(len(los) + 1, dtype=np.int64)
    np.cumsum(lens, out=offsets[1:])
    if len(los) and np.array_equal(los[1:], his[:-1]):
        return rows[los[0]:his[-1]], offsets          # contiguous: no copy
    total = int(offsets[-1])
    idx = np.arange(total, dtype=np.int64) + np.repeat(los - offsets[:-1],
                                                       lens)
    return rows[idx], offsets


class PaneSegments:
    """Segment-boundary index over one stacked pane buffer.

    ``offsets`` — ``(n+1,)`` int64 boundaries: segment ``i`` spans rows
    ``[offsets[i], offsets[i+1])`` of the stacked buffer (``reduceat``
    convention: ``starts`` is the argument ``np.<op>.reduceat`` wants).
    ``spans``   — ``(n, 2)`` float64 ``(start, end)`` pane span per segment
    (event-time units for time windows, arrival indices for count windows).
    ``keys``    — ``(n,)`` int64 pane-group key per segment for keyed
    event-time windows, else ``None``.
    """

    __slots__ = ("offsets", "spans", "keys")

    def __init__(self, offsets: np.ndarray, spans: np.ndarray,
                 keys: Optional[np.ndarray] = None):
        self.offsets = np.asarray(offsets, dtype=np.int64)
        self.spans = np.asarray(spans, dtype=np.float64).reshape(-1, 2)
        self.keys = None if keys is None else np.asarray(keys, np.int64)

    @property
    def n(self) -> int:
        return len(self.offsets) - 1

    @property
    def starts(self) -> np.ndarray:
        """Segment start offsets — feed straight into ``np.add.reduceat``
        and friends for one-call per-pane aggregates."""
        return self.offsets[:-1]

    @property
    def lengths(self) -> np.ndarray:
        return np.diff(self.offsets)

    def span(self, i: int) -> Tuple[float, float]:
        return (float(self.spans[i, 0]), float(self.spans[i, 1]))


class PaneBatch:
    """Every pane one watermark (or count boundary) released, stacked.

    ``rows`` is the one gathered buffer, ``segments`` the boundary index,
    ``t0s`` the per-pane oldest wall arrival (latency accounting).
    Iterating yields the classic pane-at-a-time view ``(rows_i, t0_i,
    (start, end))`` in canonical order — segment slices of the same
    buffer, so the compat contract and the segmented contract cannot
    drift apart.
    """

    __slots__ = ("rows", "segments", "t0s")

    def __init__(self, rows: np.ndarray, segments: PaneSegments,
                 t0s: np.ndarray):
        self.rows = rows
        self.segments = segments
        self.t0s = np.asarray(t0s, dtype=np.float64)

    @classmethod
    def empty(cls) -> "PaneBatch":
        return cls(np.zeros(0), PaneSegments(np.zeros(1, np.int64),
                                             np.zeros((0, 2))), np.zeros(0))

    @property
    def n(self) -> int:
        return self.segments.n

    @property
    def t0(self) -> float:
        """Oldest wall arrival over the batch — the flush timestamp."""
        return float(self.t0s.min()) if len(self.t0s) else 0.0

    def __len__(self) -> int:
        return self.n

    def __iter__(self):
        off = self.segments.offsets
        for i in range(self.n):
            yield (self.rows[off[i]:off[i + 1]], float(self.t0s[i]),
                   self.segments.span(i))


@dataclasses.dataclass(frozen=True)
class StateSpec:
    """Declared operator state (see module docstring for the three kinds).

    ``item_bytes``  — bytes charged per state access, as profiled (cache-line
                      -fraction granularity, the paper's ``M`` provenance).
    ``reads_per_tuple`` / ``writes_per_tuple`` — average state touches per
                      processed tuple.
    ``key_space``   — dense table size (required for "keyed", optional
                      sizing hint for "broadcast").
    ``dtype``/``fill`` — table element type and initial value.
    ``init``        — factory for the initial table/value (overrides
                      ``fill``; required shape ``(key_space,)`` for keyed).
    ``window``      — optional :class:`WindowSpec`; its history scan is
                      added to ``bytes_per_tuple``.
    """

    kind: str
    item_bytes: float = 8.0
    reads_per_tuple: float = 1.0
    writes_per_tuple: float = 1.0
    key_space: Optional[int] = None
    dtype: object = np.float64
    fill: float = 0.0
    init: Optional[Callable[[], np.ndarray]] = None
    window: Optional[WindowSpec] = None

    def __post_init__(self):
        if self.kind not in STATE_KINDS:
            raise ValueError(
                f"unknown state kind {self.kind!r} "
                f"(choose from {STATE_KINDS})")
        if self.item_bytes <= 0:
            raise ValueError("state item_bytes must be positive")
        if self.reads_per_tuple < 0 or self.writes_per_tuple < 0:
            raise ValueError("state reads/writes per tuple must be >= 0")
        if self.kind == "keyed" and (self.key_space is None
                                     or self.key_space < 1):
            raise ValueError(
                "keyed state requires key_space= (the dense table size the "
                "compiled route's keys index into)")

    def bytes_per_tuple(self) -> float:
        """State traffic per processed tuple, charged into ``mem_bytes``."""
        b = self.item_bytes * (self.reads_per_tuple + self.writes_per_tuple)
        if self.window is not None:
            b += self.window.bytes_per_tuple(self.item_bytes)
        return b

    def resident_tuples(self) -> float:
        """Tuples held resident in declared window buffers — the
        planner-side occupancy of in-flight pane batches
        (``OperatorSpec.state_resident_tuples`` /
        ``PlanEval.state_resident_bytes``)."""
        return self.window.resident_tuples() if self.window is not None \
            else 0.0

    def initial_table(self) -> np.ndarray:
        if self.init is not None:
            return np.asarray(self.init()).copy()
        assert self.key_space is not None
        return np.full(self.key_space, self.fill, dtype=self.dtype)


# ---------------------------------------------------------------------------
# Runtime stores
# ---------------------------------------------------------------------------


class KeyedStore:
    """Dense keyed table sharded exactly like the operator's keyed route.

    Shard ``shard`` of ``n_shards`` owns keys ``key % n_shards == shard`` —
    the same assignment :func:`repro.streaming.routing.split_by_key` makes —
    so under keyed routing each key is only ever touched by its owner and
    :func:`merge_keyed` reconstructs the single-replica store exactly.
    """

    __slots__ = ("spec", "n_shards", "shard", "table")

    def __init__(self, spec: StateSpec, n_shards: int = 1, shard: int = 0,
                 table: Optional[np.ndarray] = None):
        assert spec.kind == "keyed"
        assert 0 <= shard < n_shards
        self.spec = spec
        self.n_shards = n_shards
        self.shard = shard
        self.table = spec.initial_table() if table is None else table
        if len(self.table) != spec.key_space:
            raise ValueError(
                f"keyed table has {len(self.table)} entries for "
                f"key_space={spec.key_space}")

    def owned_mask(self) -> np.ndarray:
        return np.arange(len(self.table)) % self.n_shards == self.shard

    def get(self, keys: np.ndarray) -> np.ndarray:
        return self.table[keys]

    def add(self, keys: np.ndarray, amounts=1) -> None:
        np.add.at(self.table, keys, amounts)

    def put(self, keys: np.ndarray, values) -> None:
        self.table[keys] = values

    def snapshot(self) -> np.ndarray:
        return self.table.copy()

    def __repr__(self) -> str:
        return (f"KeyedStore(shard {self.shard}/{self.n_shards}, "
                f"{len(self.table)} keys)")


class ValueStore:
    """Private per-replica value (running aggregate, model residuals, ...)."""

    __slots__ = ("spec", "value")

    def __init__(self, spec: StateSpec):
        assert spec.kind == "value"
        self.spec = spec
        self.value = spec.init() if spec.init is not None else None


class BroadcastTable:
    """Read-replicated table, synced by a broadcast update stream.

    Every replica receives every update (broadcast partitioning), and
    ``load`` applies them *last-writer-wins by version*: an update older
    than the installed one is ignored.  Since all replicas eventually see
    the same update set, they converge to the same (data, version) no
    matter how updates from concurrent producers interleave — replicas may
    differ transiently mid-stream, but drained runs end identical, which is
    what ``migrate_states`` relies on when it copies one replica's table.
    """

    __slots__ = ("spec", "data", "version")

    def __init__(self, spec: StateSpec,
                 data: Optional[np.ndarray] = None, version: int = 0):
        assert spec.kind == "broadcast"
        self.spec = spec
        if data is not None:
            self.data = data
        elif spec.init is not None:
            self.data = np.asarray(spec.init()).copy()
        elif spec.key_space is not None:
            self.data = np.full(spec.key_space, spec.fill, dtype=spec.dtype)
        else:
            self.data = None
        self.version = version

    def load(self, data: np.ndarray, version: Optional[int] = None) -> None:
        """Install an update.  ``version=None`` bumps the local counter
        (single-producer streams); versioned updates below the installed
        version are stale and dropped."""
        if version is not None and int(version) < self.version:
            return
        self.data = np.asarray(data).copy()
        self.version = self.version + 1 if version is None else int(version)


class WindowState:
    """Runtime buffer behind a :class:`WindowSpec`.

    ``slide(batch)`` is the vectorized per-tuple sliding path (slide == 1):
    returns ``concat(history, batch)`` — one aggregate per input tuple over
    the trailing ``size`` values — and retains the last ``size`` values,
    exactly the seed ``moving_avg`` convention (history starts as zeros).

    ``tumble(batch)`` is the general hop path: buffers tuples and returns
    every complete window (``size`` rows, advancing by ``slide``).  It is
    the degenerate segmented case — :meth:`tumble_segments` builds the
    stacked buffer + boundary index through the same
    :func:`gather_segments` path event-time panes use, and ``tumble``
    merely splits it back out.
    """

    __slots__ = ("spec", "_hist", "_buf", "_base")

    def __init__(self, spec: WindowSpec, dtype=np.float64):
        self.spec = spec
        self._hist = np.zeros(spec.size, dtype=dtype)
        self._buf: Optional[np.ndarray] = None
        self._base = 0                      # arrival index of _buf[0]

    def slide(self, batch: np.ndarray) -> np.ndarray:
        if self.spec.slide != 1:
            raise ValueError(
                f"slide() is the per-tuple sliding path (slide=1); this "
                f"window hops by {self.spec.slide} — use tumble()")
        vals = np.concatenate([self._hist, batch])
        self._hist = vals[-self.spec.size:]
        return vals

    def tumble_segments(self, batch: np.ndarray
                        ) -> Tuple[np.ndarray, PaneSegments]:
        """Segmented count-window flush: every complete window as one
        stacked buffer + boundary index (spans are arrival-index ranges).
        Segment-aware kernels consume this directly; :meth:`tumble` is the
        pane-at-a-time view of the same result."""
        buf = batch if self._buf is None else \
            np.concatenate([self._buf, batch])
        size, hop = int(self.spec.size), int(self.spec.slide)
        m = max(0, (len(buf) - size) // hop + 1) if len(buf) >= size else 0
        los = np.arange(m, dtype=np.int64) * hop
        stacked, offsets = gather_segments(buf, los, los + size)
        spans = np.stack([los + self._base, los + self._base + size],
                         axis=1).astype(np.float64) if m else \
            np.zeros((0, 2))
        self._buf = buf[m * hop:]
        self._base += m * hop
        return stacked, PaneSegments(offsets, spans)

    def tumble(self, batch: np.ndarray) -> List[np.ndarray]:
        stacked, seg = self.tumble_segments(batch)
        return [stacked[a:b].copy()
                for a, b in zip(seg.offsets[:-1], seg.offsets[1:])]


class EventTimeWindowState:
    """Runtime buffer behind an event-time :class:`WindowSpec`.

    Out-of-order tuples are buffered with their event times and wall-clock
    arrival stamps; :meth:`on_watermark` fires every non-empty pane whose
    end the merged watermark has passed by ``lateness`` — as **one**
    :class:`PaneBatch`: a stacked buffer plus segment boundaries, built by
    a single canonical sort and one vectorized gather, never a per-pane
    loop.  Fired pane rows sit in a *canonical order* — ascending event
    time, ties broken by the full row contents; panes ordered by
    ``(end, key)`` — so pane bytes are identical no matter how arrivals
    were permuted within the lateness bound.  Tuples whose every pane has
    already fired are counted in :attr:`late_drops` and never silently
    discarded.  Event times must be >= 0 (the pane grid anchors at 0).

    Keyed pane groups (``spec.keyed``): :attr:`key_by` holds the compiled
    keyed route's extractor (the runtime attaches it, column 0 by the
    historical convention when ``None``); the buffer groups rows by key and
    each ``(key, span)`` pair is its own segment, so a key's pane bytes
    depend only on that key's rows — replication by the keyed route cannot
    change them.
    """

    __slots__ = ("spec", "key_by", "_pending", "_ets", "_rows", "_t0s",
                 "_keys", "_fired_bound", "late_drops", "panes_fired")

    def __init__(self, spec: WindowSpec, key_by=None):
        # (no dtype parameter: pane rows keep the arriving batches' dtype,
        # unlike the count WindowState whose history buffer needs one)
        assert spec.time, "EventTimeWindowState requires a time window"
        self.spec = spec
        self.key_by = key_by
        self._pending: List[tuple] = []
        self._ets: Optional[np.ndarray] = None
        self._rows: Optional[np.ndarray] = None
        self._t0s: Optional[np.ndarray] = None
        self._keys: Optional[np.ndarray] = None
        self._fired_bound = -math.inf     # every pane end <= this has fired
        self.late_drops = 0
        self.panes_fired = 0

    def insert(self, arr: np.ndarray, t0: float = 0.0) -> int:
        """Buffer a batch (``t0`` = wall arrival, for pane latency
        accounting downstream).  Returns the number of late tuples —
        counted in :attr:`late_drops`, excluded from the buffer."""
        ets = extract_event_times(arr, self.spec.time_by)
        if len(ets) and float(ets.min()) < 0:
            raise ValueError("event times must be >= 0 (the pane grid "
                             "anchors at event time 0)")
        _, k_hi = pane_range(ets, self.spec.size, self.spec.slide)
        last_end = k_hi * self.spec.slide + self.spec.size
        late = last_end <= self._fired_bound
        n_late = int(late.sum())
        if n_late:
            self.late_drops += n_late
            keep = ~late
            arr, ets = arr[keep], ets[keep]
        if len(arr):
            keys = extract_keys(arr, self.key_by) if self.spec.keyed \
                else None
            self._pending.append((ets, arr, np.full(len(arr), float(t0)),
                                  keys))
        return n_late

    def _compact(self) -> None:
        if not self._pending:
            return
        chunks = self._pending
        self._pending = []
        if self._ets is not None and len(self._ets):
            chunks.insert(0, (self._ets, self._rows, self._t0s, self._keys))
        self._ets = np.concatenate([c[0] for c in chunks])
        self._rows = np.concatenate([c[1] for c in chunks])
        self._t0s = np.concatenate([c[2] for c in chunks])
        self._keys = np.concatenate([c[3] for c in chunks]) \
            if self.spec.keyed else None

    def _canonical_order(self) -> np.ndarray:
        """Deterministic buffer order: (key,) event time, then row
        contents — one stable sort from which every pane is a contiguous
        slice."""
        rows = self._rows
        if rows.ndim == 1:
            keys: Tuple[np.ndarray, ...] = (rows, self._ets)
        else:
            keys = tuple(rows[:, c] for c in range(rows.shape[1] - 1, -1, -1)
                         ) + (self._ets,)
        if self._keys is not None:
            keys = keys + (self._keys,)
        return np.lexsort(keys)

    def _group_bounds(self) -> List[Tuple[int, int, int]]:
        """Key-group slices ``(key, lo, hi)`` of the canonically sorted
        buffer (one pseudo-group spanning everything when unkeyed)."""
        if self._keys is None:
            return [(0, 0, len(self._ets))]
        cuts = np.flatnonzero(self._keys[1:] != self._keys[:-1]) + 1
        bounds = np.concatenate([[0], cuts, [len(self._keys)]])
        return [(int(self._keys[lo]), int(lo), int(hi))
                for lo, hi in zip(bounds[:-1], bounds[1:])]

    def on_watermark(self, wm: float) -> PaneBatch:
        """Fire every pane the watermark has passed, as one
        :class:`PaneBatch`.

        Segments arrive in canonical pane order — ascending ``(end, key)``
        — each with the earliest wall arrival among its rows
        (``PaneBatch.t0s``), so downstream latency includes the time spent
        waiting for completeness.  A ``+inf`` watermark (end of stream)
        flushes every buffered pane.  Iterating the batch recovers the
        pane-at-a-time view; there is no other firing path.
        """
        size, slide = self.spec.size, self.spec.slide
        bound = wm - self.spec.lateness
        if not bound > self._fired_bound:
            return PaneBatch.empty()
        if not math.isinf(bound):
            # grid early-out: no pane end lies in (fired_bound, bound] —
            # advance the frontier without touching the buffer (identical
            # late/retention classification: both compare against grid
            # ends, and none sits between the two bounds)
            k_last_q = math.floor((bound - size) / slide + _GRID_EPS)
            k_base_q = 0 if math.isinf(self._fired_bound) else max(
                0, math.floor((self._fired_bound - size) / slide
                              + _GRID_EPS) + 1)
            if k_last_q < k_base_q:
                self._fired_bound = bound
                return PaneBatch.empty()
        self._compact()
        if self._ets is None or not len(self._ets):
            self._fired_bound = bound
            return PaneBatch.empty()
        # one canonical sort; panes are then contiguous (key-group, et)
        # ranges, sliced by searchsorted instead of a mask per pane
        order = self._canonical_order()
        ets = self._ets = self._ets[order]
        rows = self._rows = self._rows[order]
        t0s = self._t0s = self._t0s[order]
        if self._keys is not None:
            self._keys = self._keys[order]
        _, k_hi = pane_range(ets, size, slide)
        if math.isinf(bound):
            k_last = int(k_hi.max())
        else:
            k_last = math.floor((bound - size) / slide + _GRID_EPS)
        k_base = 0 if math.isinf(self._fired_bound) else max(
            0, math.floor((self._fired_bound - size) / slide + _GRID_EPS) + 1)
        seg_lo: List[np.ndarray] = []
        seg_hi: List[np.ndarray] = []
        seg_end: List[np.ndarray] = []
        seg_key: List[np.ndarray] = []
        for key, glo, ghi in self._group_bounds():
            g_ets = ets[glo:ghi]
            k_first = max(k_base, int(pane_range(g_ets[:1], size,
                                                 slide)[0][0]))
            if k_last < k_first:
                continue
            ends = np.arange(k_first, k_last + 1) * slide + size
            los = glo + np.searchsorted(g_ets, ends - size, side="left")
            his = glo + np.searchsorted(g_ets, ends, side="left")
            mask = his > los                           # no empty panes
            if mask.any():
                seg_lo.append(los[mask])
                seg_hi.append(his[mask])
                seg_end.append(ends[mask])
                seg_key.append(np.full(int(mask.sum()), key, np.int64))
        self._fired_bound = bound
        if seg_lo:
            los = np.concatenate(seg_lo)
            his = np.concatenate(seg_hi)
            ends = np.concatenate(seg_end)
            skeys = np.concatenate(seg_key)
            # per-pane oldest arrival without a second gather: reduceat
            # over (lo, hi) index pairs reduces [lo, hi) at even slots —
            # odd slots (inter-pane gaps, possibly reversed for sliding
            # overlaps) are discarded.  A sentinel element keeps hi ==
            # len(t0s) a legal reduceat index (several trailing panes can
            # share it); even-slot slices never read it
            pairs = np.empty(2 * len(los), np.int64)
            pairs[0::2] = los
            pairs[1::2] = his
            t0s_ext = np.concatenate([t0s, t0s[-1:]])
            pane_t0s = np.minimum.reduceat(t0s_ext, pairs)[0::2]
            # canonical pane order across key groups: (end, key)
            order = np.lexsort((skeys, ends))
            los, his, ends, skeys, pane_t0s = (
                los[order], his[order], ends[order], skeys[order],
                pane_t0s[order])
            stacked, offsets = gather_segments(rows, los, his)
            batch = PaneBatch(
                stacked,
                PaneSegments(offsets,
                             np.stack([ends - size, ends], axis=1),
                             skeys if self.spec.keyed else None),
                pane_t0s)
        else:
            batch = PaneBatch.empty()
        self.panes_fired += batch.n
        keep = (k_hi * slide + size) > self._fired_bound
        self._ets = ets[keep].copy()
        self._rows = rows[keep].copy()
        self._t0s = t0s[keep].copy()
        if self._keys is not None:
            self._keys = self._keys[keep].copy()
        return batch


class OperatorState(dict):
    """Per-replica state handle a kernel receives.

    A plain ``dict`` for undeclared scratch keys (the seed convention keeps
    working), plus the declared artefacts:

    ``managed`` — :class:`KeyedStore` / :class:`ValueStore` /
    :class:`BroadcastTable` per the operator's :class:`StateSpec`;
    ``window`` — :class:`WindowState` (count) or
    :class:`EventTimeWindowState` (time) when the spec declares one;
    ``segments`` — the :class:`PaneSegments` index of the stacked pane
    buffer a :func:`segmented` kernel is invoked on (None outside a
    segmented firing);
    ``pane`` — the ``(start, end)`` event-time span of the pane a
    single-span kernel is currently invoked on (the compat shim; None for
    segmented invocations with more than one segment);
    ``replica`` / ``fanout`` — this replica's position in the operator.
    """

    managed: Optional[object]
    window: Optional[object]

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self.managed = None
        self.window = None
        self.pane = None
        self.segments = None
        self.replica = 0
        self.fanout = 1


def make_operator_state(spec: Optional[StateSpec], fanout: int = 1,
                        replica: int = 0, key_by=None) -> OperatorState:
    """Build one replica's state handle from its declaration (or a bare
    dict-compatible handle when no state is declared).  ``key_by`` is the
    operator's compiled keyed-route extractor — keyed pane groups
    (``WindowSpec(keyed=True)``) shard by exactly the key the router
    splits on."""
    st = OperatorState()
    st.replica, st.fanout = replica, fanout
    if spec is None:
        return st
    if spec.window is not None:
        st.window = EventTimeWindowState(spec.window, key_by=key_by) \
            if spec.window.time \
            else WindowState(spec.window, dtype=spec.dtype)
    if spec.kind == "keyed":
        st.managed = KeyedStore(spec, n_shards=fanout, shard=replica)
    elif spec.kind == "broadcast":
        st.managed = BroadcastTable(spec)
    else:
        st.managed = ValueStore(spec)
    return st


# ---------------------------------------------------------------------------
# Elastic migration: merge by ownership, repartition onto the new replica set
# ---------------------------------------------------------------------------


def merge_keyed(stores: Sequence[KeyedStore]) -> np.ndarray:
    """Union of keyed shards by ownership: entry ``key`` comes from the shard
    with ``key % n_shards == shard``.  Under route-aligned keyed execution
    this equals the single-replica table byte for byte."""
    if not stores:
        raise ValueError("merge_keyed needs at least one shard")
    spec = stores[0].spec
    merged = spec.initial_table()
    for s in stores:
        if s.spec.key_space != spec.key_space:
            raise ValueError("cannot merge keyed stores of different "
                             "key spaces")
        mask = s.owned_mask()
        merged[mask] = s.table[mask]
    return merged


def repartition_keyed(spec: StateSpec, merged: np.ndarray,
                      n_shards: int) -> List[KeyedStore]:
    """Split a merged table onto ``n_shards`` new owners; entries outside a
    shard's residue class reset to the initial value (they are unreachable
    under the new route and must not leak into a later merge)."""
    fresh = spec.initial_table()
    out = []
    for j in range(n_shards):
        table = fresh.copy()
        mask = np.arange(len(merged)) % n_shards == j
        table[mask] = merged[mask]
        out.append(KeyedStore(spec, n_shards=n_shards, shard=j, table=table))
    return out


# ---------------------------------------------------------------------------
# Snapshot payloads: one replica's state as plain picklable data
# ---------------------------------------------------------------------------


def state_payload(st: OperatorState, *, copy: bool = False) -> dict:
    """Reduce one replica's state handle to plain picklable data.

    Ships arrays and scalars only — managed store tables, window buffers
    (compacted), scratch dict entries, the late/pane counters — never the
    stores themselves (their specs can hold closure ``init`` factories,
    which fork inherits but pickle rejects).

    ``copy=True`` deep-copies every array and scratch value: required for
    *live* snapshots (checkpoint barriers), where the run keeps mutating
    tables and buffers after the payload is taken.  The process backend's
    end-of-run hand-off keeps ``copy=False`` — the worker is done with the
    state, so aliasing is safe and cheaper.
    """
    scratch = dict(st)
    if copy:
        scratch = _copylib.deepcopy(scratch)
    p: dict = {"scratch": scratch}

    def _arr(a):
        if copy and isinstance(a, np.ndarray):
            return a.copy()
        return a

    m = st.managed
    if isinstance(m, KeyedStore):
        p["managed"] = ("keyed", _arr(m.table))
    elif isinstance(m, BroadcastTable):
        p["managed"] = ("broadcast", _arr(m.data), m.version)
    elif isinstance(m, ValueStore):
        p["managed"] = ("value",
                        _copylib.deepcopy(m.value) if copy else m.value)
    w = st.window
    if isinstance(w, EventTimeWindowState):
        w._compact()
        p["window"] = ("et", _arr(w._ets), _arr(w._rows), _arr(w._t0s),
                       _arr(w._keys), w._fired_bound, w.late_drops,
                       w.panes_fired)
    elif isinstance(w, WindowState):
        p["window"] = ("count", _arr(w._hist), _arr(w._buf), w._base)
    return p


def restore_state(st: OperatorState, payload: dict) -> None:
    """Install a payload onto a matching handle, in place — the handle
    keeps its spec, shard identity and key extractor, so
    ``migrate_states`` and the result assembly read it exactly as if the
    snapshot had never crossed a process (or checkpoint) boundary."""
    st.clear()
    st.update(payload["scratch"])
    m = payload.get("managed")
    if m is not None:
        kind = m[0]
        if kind == "keyed":
            st.managed.table = m[1]
        elif kind == "broadcast":
            st.managed.data = m[1]
            st.managed.version = m[2]
        else:
            st.managed.value = m[1]
    w = payload.get("window")
    if w is not None:
        if w[0] == "et":
            win = st.window
            win._pending = []
            (win._ets, win._rows, win._t0s, win._keys,
             win._fired_bound, win.late_drops, win.panes_fired) = w[1:]
        else:
            win = st.window
            win._hist, win._buf, win._base = w[1:]


class UndeclaredStateError(RuntimeError):
    """``migrate_states(audit=True)`` found non-empty undeclared scratch
    state that would be silently left behind by the migration."""


def _has_content(value) -> bool:
    if value is None:
        return False
    if isinstance(value, np.ndarray):
        return value.size > 0 and bool(np.any(value))
    try:
        return bool(value)
    except Exception:
        return True


def migrate_states(app, states: Dict[str, List[OperatorState]],
                   parallelism: Dict[str, int], *, audit: bool = False
                   ) -> Dict[str, List[OperatorState]]:
    """Repartition a finished run's states onto a new replica set.

    The elastic half of ``Plan.replan``: ``keyed`` stores are merged by key
    ownership and re-sharded to the new fan-out; ``broadcast`` tables are
    copied to every new replica (replicas are identical by construction);
    ``value`` states are per-replica by definition — the first
    ``min(k_old, k_new)`` replicas carry over, the rest start fresh.
    Undeclared dict scratch state does not migrate (declare it if it must
    survive a replan).  Feed the result to ``run_app(initial_states=...)`` /
    ``Plan.execute(initial_states=...)``.

    ``audit=True`` raises :class:`UndeclaredStateError` when any replica
    holds non-empty undeclared dict scratch entries — the ROADMAP's audit
    mode for apps that forgot to declare.  Metric counters ("seen" tallies
    and the like) count too: they are state the migration loses, and the
    audit's job is to make that loss explicit, not to guess which keys were
    disposable.
    """
    specs: Dict[str, StateSpec] = getattr(app, "state", {}) or {}
    if audit:
        leftover = []
        for name in app.graph.operators:
            for j, st in enumerate(states.get(name, [])):
                keys = sorted(k for k, v in dict(st).items()
                              if _has_content(v))
                if keys:
                    leftover.append(f"{name}#{j}: {keys}")
        if leftover:
            raise UndeclaredStateError(
                "non-empty undeclared scratch state would not survive this "
                "migration (declare it via Topology.op(state=StateSpec(...))"
                " or drop it before migrating): " + "; ".join(leftover))
    out: Dict[str, List[OperatorState]] = {}
    for name in app.graph.operators:
        k_new = parallelism.get(name, 1)
        spec = specs.get(name)
        old = states.get(name, [])
        fresh = [make_operator_state(spec, k_new, j) for j in range(k_new)]
        if spec is None or not old:
            out[name] = fresh
            continue
        if spec.kind == "keyed":
            merged = merge_keyed([st.managed for st in old
                                  if st.managed is not None])
            shards = repartition_keyed(spec, merged, k_new)
            for st, shard in zip(fresh, shards):
                st.managed = shard
        elif spec.kind == "broadcast":
            src = old[0].managed
            for st in fresh:
                st.managed = BroadcastTable(
                    spec,
                    data=None if src.data is None else src.data.copy(),
                    version=src.version)
        else:                                   # value: best-effort carry
            for j in range(min(len(old), k_new)):
                fresh[j].managed = old[j].managed
                if not isinstance(old[j].window, EventTimeWindowState):
                    fresh[j].window = old[j].window
        if spec.window is not None and spec.window.time:
            _carry_event_windows(old, fresh)
        out[name] = fresh
    return out


def _carry_event_windows(old: List[OperatorState],
                         fresh: List[OperatorState]) -> None:
    """Carry event-time pane buffers and the watermark frontier across a
    migration.

    Buffered (not-yet-fired) rows, the fired frontier and the late/pane
    counters are state exactly like a keyed table: dropping them loses
    every out-of-order tuple still waiting inside its lateness bound, so a
    migrated run would fire a different pane multiset than an
    uninterrupted one.  Keyed pane groups merge all old replicas' buffers
    and reshard rows by ``key % k_new`` (the compiled keyed route's
    ownership); unkeyed windows carry index-wise at equal fan-out and
    collapse onto replica 0 otherwise.  The frontier carries as the max
    over replicas — under quiesced migration every replica saw the same
    merged watermark, so the max equals each.  Suspend the old run with
    ``final_watermark=False`` (otherwise the end-of-stream ``+inf`` mark
    has already fired every pane and there is nothing left to carry).
    """
    wins = [st.window for st in old
            if isinstance(st.window, EventTimeWindowState)]
    if not wins:
        return
    for w in wins:
        w._compact()
    fired = max(w._fired_bound for w in wins)
    if fired == math.inf:
        # fully drained run: the end-of-stream +inf mark already fired
        # every pane and emptied the buffers — nothing to carry, and a
        # carried +inf frontier would classify the entire next stream as
        # late.  Migrated windows start fresh (the pre-suspend contract).
        return
    total_late = sum(w.late_drops for w in wins)
    total_panes = sum(w.panes_fired for w in wins)
    keyed = wins[0].spec.keyed
    chunks = [(w._ets, w._rows, w._t0s, w._keys) for w in wins
              if w._ets is not None and len(w._ets)]
    if chunks:
        ets = np.concatenate([c[0] for c in chunks])
        rows = np.concatenate([c[1] for c in chunks])
        t0s = np.concatenate([c[2] for c in chunks])
        keys = np.concatenate([c[3] for c in chunks]) if keyed else None
    else:
        ets = rows = t0s = keys = None
    k_new = len(fresh)
    index_wise = not keyed and k_new == len(old) and len(wins) == len(old)
    for j, st in enumerate(fresh):
        win = st.window
        if not isinstance(win, EventTimeWindowState):
            continue
        win._fired_bound = fired
        if index_wise:
            src = old[j].window
            win._fired_bound = src._fired_bound
            if src._ets is not None and len(src._ets):
                win._ets = src._ets.copy()
                win._rows = src._rows.copy()
                win._t0s = src._t0s.copy()
                win._keys = src._keys.copy() if src._keys is not None \
                    else None
            win.late_drops = src.late_drops
            win.panes_fired = src.panes_fired
            continue
        if ets is None:
            continue
        if keyed and k_new > 1:
            mask = keys % k_new == j
            win._ets = ets[mask].copy()
            win._rows = rows[mask].copy()
            win._t0s = t0s[mask].copy()
            win._keys = keys[mask].copy()
        elif j == 0:
            win._ets = ets.copy()
            win._rows = rows.copy()
            win._t0s = t0s.copy()
            win._keys = keys.copy() if keys is not None else None
    if not index_wise:
        # counters live on replica 0: RuntimeResult sums over replicas
        w0 = fresh[0].window
        if isinstance(w0, EventTimeWindowState):
            w0.late_drops = total_late
            w0.panes_fired = total_panes
