"""Aligned-barrier checkpoints: consistent snapshots + offset replay.

The recovery contract (ROADMAP item 2, TStream 1904.03800's
transactional-state framing): a run killed mid-stream restores from its
latest completed checkpoint to **byte-identical** output versus an
uninterrupted run.  Exactly-once comes from two halves glued at one
consistent cut:

* **State snapshot** — every executor deposits a deep-copied
  :func:`repro.streaming.state.state_payload` of its
  :class:`~repro.streaming.state.OperatorState` (keyed/value/broadcast
  stores, count-window buffers, event-time pane buffers *and* the
  watermark frontier) the moment checkpoint barrier *n* has arrived on
  every producer lane — the Chandy-Lamport aligned cut.
* **Offset replay** — every spout deposits its retired batch offset for
  the same barrier, so a resumed run replays exactly the batches whose
  effects are *not* in the snapshot.  Deterministic sources
  (``source(batch, seed + b)``) make the replayed prefix byte-identical.

This module owns the bookkeeping around the cut, not the cut itself (the
runtime's barrier alignment does that): :class:`Checkpoint` is the
completed snapshot, :class:`CheckpointCoordinator` assembles per-replica
deposits into completed checkpoints (thread-safe — executors deposit from
their own threads; the process backend's parent deposits on behalf of
workers as pipe messages stream in), and :func:`save_checkpoint` /
:func:`restore_checkpoint` persist completed checkpoints atomically
(write-tmp-then-rename, so a kill mid-write never leaves a torn file) and
load the latest one back.
"""
from __future__ import annotations

import dataclasses
import os
import pickle
import re
import threading
from typing import Dict, List, Optional, Set

__all__ = [
    "Checkpoint", "CheckpointCoordinator", "checkpoint_uids",
    "save_checkpoint", "restore_checkpoint", "list_checkpoints",
]

_CKPT_RE = re.compile(r"^ckpt-(\d+)\.pkl$")


@dataclasses.dataclass
class Checkpoint:
    """One completed aligned snapshot.

    ``spout_offsets`` maps per-replica uids (``"spout#0"``) to the number
    of batches *retired into the snapshot* — the resume start offset.
    ``states`` maps every executor uid to its
    :func:`~repro.streaming.state.state_payload`; ``aux`` carries the
    executor's watermark bookkeeping (merged-lane map, forwarded frontier,
    spout cadence counters) so a resumed run emits the exact mark sequence
    an uninterrupted run would have.
    """

    ckpt_id: int
    app: str
    parallelism: Dict[str, int]
    batch: int
    seed: int
    checkpoint_every: int
    spout_offsets: Dict[str, int] = dataclasses.field(default_factory=dict)
    states: Dict[str, dict] = dataclasses.field(default_factory=dict)
    aux: Dict[str, dict] = dataclasses.field(default_factory=dict)

    def describe(self) -> str:
        return (f"ckpt {self.ckpt_id} of {self.app!r} "
                f"(offsets {self.spout_offsets}, "
                f"{len(self.states)} state payloads)")


def checkpoint_uids(app, parallelism: Dict[str, int]) -> Set[str]:
    """The set of per-replica uids that must deposit for a checkpoint to
    be complete: every spout replica and every task replica."""
    return {f"{name}#{i}"
            for name in app.graph.operators
            for i in range(parallelism.get(name, 1))}


class CheckpointCoordinator:
    """Assembles per-replica deposits into completed checkpoints.

    A checkpoint is *complete* when every expected uid has deposited for
    its id; incomplete rounds at shutdown (the stream drained first, or
    the run was killed) are simply discarded — recovery only ever reads
    completed checkpoints.  Completion is detected under one lock, so
    exactly one depositor observes it and triggers persistence.
    """

    def __init__(self, app, parallelism: Dict[str, int], *, batch: int,
                 seed: int, every: int, directory: Optional[str] = None):
        self.app_name = app.name
        self.parallelism = dict(parallelism)
        self.batch = int(batch)
        self.seed = int(seed)
        self.every = int(every)
        self.directory = directory
        self.expected = checkpoint_uids(app, parallelism)
        self.completed: List[Checkpoint] = []
        self._open: Dict[int, Checkpoint] = {}
        self._lock = threading.Lock()

    def deposit(self, ckpt_id: int, uid: str, *, payload: dict,
                aux: Optional[dict] = None,
                offset: Optional[int] = None) -> Optional[Checkpoint]:
        """Record one replica's snapshot for checkpoint ``ckpt_id``.

        Returns the completed :class:`Checkpoint` when this deposit was
        the last one expected (having also persisted it when a directory
        is configured), else ``None``.
        """
        if uid not in self.expected:
            raise ValueError(f"unexpected checkpoint depositor {uid!r}")
        with self._lock:
            ck = self._open.get(ckpt_id)
            if ck is None:
                ck = self._open[ckpt_id] = Checkpoint(
                    ckpt_id=ckpt_id, app=self.app_name,
                    parallelism=dict(self.parallelism), batch=self.batch,
                    seed=self.seed, checkpoint_every=self.every)
            ck.states[uid] = payload
            if aux:
                ck.aux[uid] = aux
            if offset is not None:
                ck.spout_offsets[uid] = int(offset)
            if set(ck.states) != self.expected:
                return None
            del self._open[ckpt_id]
            self.completed.append(ck)
        if self.directory is not None:
            save_checkpoint(ck, self.directory)
        return ck

    @property
    def latest(self) -> Optional[Checkpoint]:
        with self._lock:
            return self.completed[-1] if self.completed else None


def save_checkpoint(ckpt: Checkpoint, directory: str) -> str:
    """Persist one completed checkpoint atomically.

    Pickles to ``ckpt-<id>.pkl.tmp.<pid>`` then ``os.replace``-renames
    into place: a reader (or a restore after a kill) either sees the
    complete file or no file — never a torn one.
    """
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"ckpt-{ckpt.ckpt_id}.pkl")
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "wb") as f:
        pickle.dump(ckpt, f, protocol=pickle.HIGHEST_PROTOCOL)
    os.replace(tmp, path)
    return path


def list_checkpoints(directory: str) -> List[int]:
    """Completed checkpoint ids present in ``directory``, ascending."""
    try:
        names = os.listdir(directory)
    except FileNotFoundError:
        return []
    ids = []
    for n in names:
        m = _CKPT_RE.match(n)
        if m:
            ids.append(int(m.group(1)))
    return sorted(ids)


def restore_checkpoint(directory: str,
                       ckpt_id: Optional[int] = None) -> Checkpoint:
    """Load a completed checkpoint from ``directory`` — the latest
    (highest id) by default, or a specific ``ckpt_id``.  Feed the result
    to ``run_app(from_checkpoint=...)`` / ``Plan.execute(
    from_checkpoint=...)`` to resume."""
    ids = list_checkpoints(directory)
    if not ids:
        raise FileNotFoundError(
            f"no completed checkpoints under {directory!r}")
    if ckpt_id is None:
        ckpt_id = ids[-1]
    elif ckpt_id not in ids:
        raise FileNotFoundError(
            f"checkpoint {ckpt_id} not found under {directory!r} "
            f"(have {ids})")
    path = os.path.join(directory, f"ckpt-{ckpt_id}.pkl")
    with open(path, "rb") as f:
        ck = pickle.load(f)
    if not isinstance(ck, Checkpoint):
        raise ValueError(f"{path!r} does not contain a Checkpoint")
    return ck
