"""Real threaded mini-runtime (paper §5 / Appendix A, shared-memory design).

Executes a :class:`StreamingApp` for real on the host CPU.  Every replica —
spout or task — is one :class:`Executor` thread sharing a single emit path:
tuples are numpy batches passed *by reference* through bounded queues
(backpressure via blocking put) and accumulated into **jumbo tuples** — one
queue insertion per ``batch`` tuples with a single shared header (timestamp),
amortising queue overhead exactly as §5.2 describes.  ``jumbo=False``
degrades to per-tuple insertion for the Fig. 16 factor analysis.

All partitioning decisions go through compiled :class:`~.routing.Route`
objects (see :mod:`repro.streaming.routing`) — the same tables the planner
and the DES consume — so there is no strategy branching here.  The hot path
is batch-vectorized: keyed splits are one argsort/bincount per batch and
jumbo accumulation copies rows into preallocated buffers instead of
list-append-then-concatenate.

This runtime validates streaming *semantics* (WC really counts words); the
NUMA placement effects are exercised through the simulator instead (this
container has a single socket — see DESIGN.md §6).
"""
from __future__ import annotations

import collections
import dataclasses
import math
import queue
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from .apps import StreamingApp
from .checkpoint import Checkpoint, CheckpointCoordinator
from .routing import (BarrierAligner, Route, WatermarkMerger, compile_routes,
                      extract_event_times, validate_operator_names)
from .state import (EventTimeWindowState, OperatorState, make_operator_state,
                    restore_state, state_payload)

_POISON = object()


class _Watermark:
    """In-band low-watermark message: ``lane`` is the producer executor's
    unique name (one merge lane per producer replica)."""

    __slots__ = ("lane", "value")

    def __init__(self, lane: str, value: float):
        self.lane = lane
        self.value = value


class _Barrier:
    """In-band checkpoint barrier: the second kind of mark.

    Rides exactly the lanes a watermark rides (``Route.watermark_lanes``,
    in-band tagged ring slots across processes), but consumers *align*
    instead of min-merging: state snapshots only once barrier ``ckpt_id``
    has arrived on every producer lane — see
    :class:`~.routing.BarrierAligner`.
    """

    __slots__ = ("lane", "ckpt_id")

    def __init__(self, lane: str, ckpt_id: int):
        self.lane = lane
        self.ckpt_id = ckpt_id


@dataclasses.dataclass
class RuntimeResult:
    duration: float
    sink_tuples: int
    spout_tuples: int
    throughput: float               # sink tuples/sec
    latency_p50: float
    latency_p99: float
    states: Dict[str, List[dict]]   # per-operator replica OperatorStates
    # (dict-compatible; .managed holds declared KeyedStore/BroadcastTable/
    #  ValueStore instances — see repro.streaming.state)
    late_drops: int = 0             # event-time tuples past their last pane
    panes_fired: int = 0            # event-time panes emitted
    #: per-spout-replica emitted batch counters ("spout#0" -> batches ever
    #: emitted, including any initial_offsets base).  Feed them back as
    #: ``run_app(initial_offsets=)`` and the resumed run continues the
    #: deterministic source sequence exactly where this one stopped.
    spout_offsets: Dict[str, int] = dataclasses.field(default_factory=dict)
    #: completed aligned checkpoints, in id order (empty unless the run had
    #: ``checkpoint_every`` set).  Each is a
    #: :class:`repro.streaming.checkpoint.Checkpoint` — feed one back as
    #: ``run_app(from_checkpoint=)`` to resume from that cut.
    checkpoints: List[Checkpoint] = dataclasses.field(default_factory=list)
    #: per-replica runtime counters, keyed by executor uid ("op#i"):
    #: ``batches`` / ``tuples_in`` processed, ``tuples_out`` enqueued
    #: (summed over output streams), ``queue_wait_s`` blocked on the input
    #: queue, ``kernel_s`` inside the operator kernel.  Fused chain members
    #: report under their own uids (queue wait lands on the chain head).
    #: Fusion wins — and placement decisions — are measurable from a run
    #: instead of only from the bench harness.
    exec_stats: Dict[str, dict] = dataclasses.field(default_factory=dict)


class _Lease:
    """Reference count over one pooled arena buffer.

    Every queue item built from a pooled buffer carries the lease with a
    reference already counted for it; the consumer releases after fully
    processing the item, and the buffer returns to its arena's free list
    when the last reference drops.  ``retain``/``release`` are cross-thread
    (producer flushes, consumers release), hence the lock — one lock
    operation per *jumbo*, not per tuple.
    """

    __slots__ = ("buf", "_arena", "_rc", "_lock")

    def __init__(self, buf: np.ndarray, arena: "_Arena"):
        self.buf = buf
        self._arena = arena
        self._rc = 1
        self._lock = threading.Lock()

    def retain(self, n: int = 1) -> None:
        with self._lock:
            self._rc += n

    def release(self) -> None:
        with self._lock:
            self._rc -= 1
            free = self._rc == 0
        if free:
            self._arena.recycle(self.buf)


class _Arena:
    """Pool of fixed-cap jumbo row buffers, shared by one output port.

    ``acquire`` hands out a ``(cap, *row_shape)`` buffer plus its
    :class:`_Lease`; ``recycle`` (called by the last ``release``) returns
    it to the free list, so steady-state flushing reuses a small warm set
    of buffers instead of allocating one per flush and copying on every
    hand-off.  Buffers whose shape/dtype no longer match, or beyond the
    pool bound, are simply dropped to the garbage collector.

    ``outstanding_total()`` counts leased-out buffers across every arena
    (acquire +1, last release -1): a drained run — even one that died on a
    kernel exception — must return it to its pre-run baseline, or a lease
    leaked (the regression the per-item release guards exist to prevent).
    """

    __slots__ = ("cap", "max_pooled", "_free", "_lock")

    _outstanding = 0                       # leased-out buffers, all arenas
    _class_lock = threading.Lock()

    def __init__(self, cap: int, max_pooled: int = 8):
        self.cap = cap
        self.max_pooled = max_pooled
        self._free: List[np.ndarray] = []
        self._lock = threading.Lock()

    @classmethod
    def outstanding_total(cls) -> int:
        with cls._class_lock:
            return cls._outstanding

    def acquire(self, row_shape: Tuple[int, ...],
                dtype: np.dtype) -> Tuple[np.ndarray, _Lease]:
        with _Arena._class_lock:
            _Arena._outstanding += 1
        with self._lock:
            for i in range(len(self._free) - 1, -1, -1):
                buf = self._free[i]
                if buf.shape[1:] == row_shape and buf.dtype == dtype:
                    del self._free[i]
                    return buf, _Lease(buf, self)
        buf = np.empty((self.cap,) + tuple(row_shape), dtype)
        return buf, _Lease(buf, self)

    def recycle(self, buf: np.ndarray) -> None:
        with _Arena._class_lock:
            _Arena._outstanding -= 1
        with self._lock:
            if len(self._free) < self.max_pooled:
                self._free.append(buf)


#: a flushed jumbo: (rows, oldest-buffered t0, lease or None).  A non-None
#: lease already counts the reference this item hands its consumer.
_Flush = Tuple[np.ndarray, float, Optional[_Lease]]


class _JumboBuffer:
    """Pooled jumbo accumulator for one (stream, consumer-replica) lane.

    Rows are copied in place into an arena-acquired ``cap``-row store — no
    per-emit list append + concatenate — and ``add`` hands back full
    jumbos.  Flushes are **views** into the pooled store (read-only, with
    the store's refcount lease attached) instead of the former
    copy-on-flush: the consumer reads the view and releases the lease, at
    which point the buffer recycles.  The flush timestamp is the *oldest*
    buffered tuple's, so end-to-end latency accounting matches the seed
    runtime.  A whole batch that already fills a jumbo passes through
    untouched (zero copies), which keeps the common selectivity-one
    shuffle path as cheap as before.  Flush boundaries are byte-identical
    to the copying implementation (the overflow case still concatenates,
    preserving jumbo sizes exactly — boundary changes would alter stateful
    kernels' running outputs).
    """

    __slots__ = ("cap", "arena", "_store", "_lease", "_n", "_t0")

    def __init__(self, cap: int, arena: Optional[_Arena] = None):
        self.cap = cap
        self.arena = arena if arena is not None else _Arena(cap)
        self._store: Optional[np.ndarray] = None
        self._lease: Optional[_Lease] = None
        self._n = 0
        self._t0 = 0.0

    def _flush(self) -> _Flush:
        """Hand the filled prefix to a consumer: a read-only view carrying
        the store's lease (ownership transfers — the filler stops using
        this buffer and acquires a fresh one on the next partial add)."""
        view = self._store[: self._n]
        view.flags.writeable = False
        lease, self._lease = self._lease, None
        self._store = None
        self._n = 0
        return view, self._t0, lease

    def add(self, arr: np.ndarray, t0: float) -> List[_Flush]:
        """Buffer ``arr``; return the jumbos (if any) now ready to flush."""
        out: List[_Flush] = []
        store = self._store
        if self._n and (store.shape[1:] != arr.shape[1:]
                        or store.dtype != arr.dtype):
            # the stream changed row shape mid-lane: flush what we have
            out.append(self._flush())
            store = None
        if self._n == 0 and len(arr) >= self.cap:
            out.append((arr, t0, None))                # zero-copy fast path
            return out
        if store is None or store.shape[1:] != arr.shape[1:] \
                or store.dtype != arr.dtype:
            if self._lease is not None:    # empty store of the wrong shape
                self._lease.release()
            self._store, self._lease = self.arena.acquire(arr.shape[1:],
                                                          arr.dtype)
            store = self._store
        if self._n == 0:
            self._t0 = t0
        end = self._n + len(arr)
        if end > self.cap:
            # rare overflow: concatenate so the jumbo boundary lands where
            # it always did (a fresh array — no lease)
            out.append((np.concatenate([store[: self._n], arr]),
                        self._t0, None))
            self._n = 0
        elif end == self.cap:
            store[self._n:end] = arr
            self._n = end
            out.append(self._flush())
        else:
            store[self._n:end] = arr
            self._n = end
        return out

    def drain(self) -> Optional[_Flush]:
        if self._n == 0:
            return None
        return self._flush()


class _OutPort:
    """One output stream of an executor: a bound route plus the consumer
    replica queues and their jumbo lanes.

    All lanes share one :class:`_Arena` (their rows have one shape/dtype
    per stream, so recycled buffers rotate across lanes).  A broadcast
    route collapses to a **single shared lane buffer**: every consumer
    replica receives every tuple, so the lanes fill in lockstep and one
    flush view — refcounted once per lane — replaces the former
    one-accumulated-copy-per-consumer."""

    __slots__ = ("route", "queues", "buffers", "delivered", "shared_flush")

    def __init__(self, route: Route, queues: List[queue.Queue], batch: int):
        self.route = route
        self.queues = queues
        self.shared_flush = route.is_broadcast and len(queues) > 1
        arena = _Arena(batch)
        n_buffers = 1 if self.shared_flush else len(queues)
        self.buffers = [_JumboBuffer(batch, arena) for _ in range(n_buffers)]
        self.delivered = [0] * len(queues)   # tuples enqueued, per lane

    def tuples_entered(self) -> int:
        return self.route.tuples_entered(self.delivered)


class Executor(threading.Thread):
    """One replica of any operator — spout or task (the paper's "executor").

    Spouts generate input with ``source``; tasks pull jumbos from ``in_q``.
    Both emit through the same path: ``Route.split`` assigns tuples to
    consumer replicas and per-lane jumbo buffers amortise queue insertions,
    for per-tuple (``jumbo=False``) and jumbo modes alike.
    """

    def __init__(self, name: str, ports: List[_OutPort], batch: int,
                 jumbo: bool, state: dict, *,
                 kernel: Optional[Callable] = None,
                 in_q: Optional[queue.Queue] = None,
                 expected_poisons: int = 0,
                 source: Optional[Callable] = None,
                 stop: Optional[threading.Event] = None,
                 seed: int = 0,
                 lat_sink: Optional[List[float]] = None,
                 on_delivered: Optional[Callable[[int], None]] = None,
                 max_batches: Optional[int] = None,
                 event_time=None,
                 wm_every: int = 1,
                 wm_interval: Optional[float] = None,
                 device_depth: int = 0,
                 start_batch: int = 0,
                 ckpt: Optional[CheckpointCoordinator] = None,
                 final_watermark: bool = True,
                 initial_aux: Optional[dict] = None):
        super().__init__(daemon=True, name=name)
        # the merge-lane identity this executor stamps on everything it
        # emits (marks, barriers, checkpoint-tagged data items).  Equal to
        # the executor name except for fused chains, which emit as their
        # *tail* member — downstream lane bookkeeping is identical to the
        # unfused plan's.
        self.lane = name
        self.ports = ports
        self.batch = batch
        self.jumbo = jumbo
        self.state = state
        self.kernel = kernel
        self.in_q = in_q
        self.expected_poisons = expected_poisons
        self.source = source
        self.stop_event = stop
        self.seed = seed
        self.lat_sink = lat_sink
        self.on_delivered = on_delivered
        self.max_batches = max_batches
        # event-time plumbing: spouts with a declared extractor emit
        # low-watermarks; tasks min-merge them per producer lane and fire
        # event-time window panes on passage.  wm_every / wm_interval are
        # the spout's declared cadence (every N batches / every T event-
        # time units of advance) — marks amortize jumbo flushes, the
        # end-of-stream +inf mark still flushes everything
        self.event_time = event_time
        self.wm_every = wm_every
        self.wm_interval = wm_interval
        self._wm = -math.inf
        self._wm_sent = -math.inf
        self._wm_batches = 0
        self._wm_merge = WatermarkMerger(max(expected_poisons, 1))
        self._wm_fwd = -math.inf
        # single-lane fast path: with exactly one producer lane the merged
        # watermark IS the lane's value — skip the min-merge bookkeeping
        # (LR's dispatcher edge and every fused chain's inbound edge)
        self._single_lane = source is None and max(expected_poisons, 1) == 1
        self._wm_lane: Optional[str] = None
        self._stats = {"batches": 0, "tuples_in": 0, "tuples_out": 0,
                       "queue_wait_s": 0.0, "kernel_s": 0.0}
        win = getattr(state, "window", None)
        self._et_win = win if isinstance(win, EventTimeWindowState) else None
        # device operator: the kernel is an async (jitted) computation and
        # up to device_depth results stay in flight before the oldest is
        # materialized + dispatched (0 = host op, 1 = device but synchronous)
        self.device_depth = device_depth
        if device_depth and self._et_win is not None:
            raise ValueError(
                f"{name}: device operators cannot drive event-time window "
                "panes (v1 exclusion — see Topology.op(device=))")
        self._inflight: collections.deque = collections.deque()
        # spout resume point: the source sequence continues at this batch
        # index (seeds seed+start_batch ..), making a resumed duration run
        # a prefix-continuation of the original
        self.start_batch = start_batch
        self.emitted_batches = start_batch
        # checkpointing: spouts inject numbered barriers every
        # ckpt.every batches; tasks align them per producer lane and
        # snapshot state at the aligned cut.  While a lane has aligned the
        # active round, its subsequent items are *held* (the Chandy-
        # Lamport discipline) — data items therefore carry their producer
        # lane as a 4th tuple element whenever checkpointing is on.
        self.ckpt = ckpt
        self.final_watermark = final_watermark
        self._aligner = BarrierAligner(max(expected_poisons, 1)) \
            if ckpt is not None else None
        self._held: List[object] = []
        if initial_aux:
            self._apply_aux(initial_aux)

    def _apply_aux(self, aux: dict) -> None:
        """Install checkpointed watermark bookkeeping: spout mark cadence
        counters and the task-side merged-lane map + forwarded frontier.
        Without these a resumed run would re-merge lanes from -inf and
        advance the fired frontier on a different schedule than the
        uninterrupted run — same panes eventually, but a *different* late
        classification for tuples racing the frontier."""
        if "wm" in aux:
            self._wm = aux["wm"]
            self._wm_sent = aux["wm_sent"]
            self._wm_batches = aux["wm_batches"]
        if "wm_lanes" in aux:
            for lane, value in aux["wm_lanes"].items():
                self._wm_merge.update(lane, value)
                self._wm_lane = lane
            self._wm_fwd = aux["wm_fwd"]

    def _aux_payload(self) -> dict:
        if self.is_spout:
            return {"wm": self._wm, "wm_sent": self._wm_sent,
                    "wm_batches": self._wm_batches}
        if self._single_lane:
            # the lane frontier equals the forwarded frontier (one lane,
            # monotone) — synthesize the map the merger would have held
            lanes = {} if self._wm_lane is None \
                else {self._wm_lane: self._wm_fwd}
            return {"wm_lanes": lanes, "wm_fwd": self._wm_fwd}
        return {"wm_lanes": dict(self._wm_merge._lanes),
                "wm_fwd": self._wm_fwd}

    def stats_payload(self) -> Dict[str, dict]:
        """Per-uid runtime counters for :attr:`RuntimeResult.exec_stats`.
        ``tuples_out`` counts tuples entering each output stream, summed
        over streams (fan-out counts once per stream, like the routes)."""
        s = dict(self._stats)
        s["tuples_out"] = int(sum(p.tuples_entered() for p in self.ports))
        return {self.name: s}

    @property
    def is_spout(self) -> bool:
        return self.source is not None

    def run(self):
        if self.is_spout:
            self._run_spout()
        else:
            self._run_task()

    def _run_spout(self):
        b = self.start_batch
        while not self.stop_event.is_set() and \
                (self.max_batches is None or
                 b - self.start_batch < self.max_batches):
            tk = time.perf_counter()
            arr = self.source(self.batch, self.seed + b)
            t0 = time.perf_counter()
            self._stats["kernel_s"] += t0 - tk
            self._stats["batches"] += 1
            b += 1
            self.emitted_batches = b
            # logical fan-out: every output stream carries the same batch
            self._dispatch([arr] * len(self.ports), t0)
            if self.event_time is not None and len(arr):
                ets = extract_event_times(arr, self.event_time)
                self._wm = max(self._wm, float(ets.max()))
                self._wm_batches += 1
                if self.wm_interval is not None:
                    due = self._wm - self._wm_sent >= self.wm_interval \
                        or math.isinf(self._wm_sent)
                else:
                    due = self._wm_batches >= self.wm_every
                if due and self._wm > self._wm_sent:
                    self._wm_sent = self._wm
                    self._wm_batches = 0
                    self._emit_watermark(self._wm)
            if self.ckpt is not None and b % self.ckpt.every == 0:
                self._emit_barrier(b)
        self._drain()
        if self.event_time is not None and self.final_watermark:
            # end of stream: +inf flushes every buffered pane downstream.
            # final_watermark=False suspends instead: pane buffers stay
            # resident for migrate_states / a later resume (the +inf mark
            # would close the frontier and leave nothing to carry)
            self._emit_watermark(math.inf)
        if self.on_delivered is not None:
            # tuples that entered the dataflow: max over streams — fan-out
            # duplicates tuples, it does not multiply them — and only what
            # was actually enqueued (stop can interrupt a keyed delivery
            # between partitions).  Counted before the blocking poison puts
            # so a stalled consumer cannot zero the tally.
            self.on_delivered(max((p.tuples_entered() for p in self.ports),
                                  default=0))
        self._poison()

    def _run_task(self):
        try:
            self._task_loop()
        except BaseException:
            # the executor is dying (a kernel raised mid-batch): release
            # every in-flight device lease so the pooled buffers recycle —
            # the exception path must not strand arena buffers
            self._release_inflight()
            raise

    def _release_inflight(self) -> None:
        while self._inflight:
            _, _, lease = self._inflight.popleft()
            if lease is not None:
                lease.release()

    def _lane_of(self, item) -> Optional[str]:
        """Producer lane of an in-band item, when it carries one: marks
        and barriers always do; data items only when checkpointing tagged
        them (4-tuples).  Poisons never — they are not held back (FIFO per
        lane puts a lane's barrier before its poison, so alignment cannot
        be waiting on a poisoned lane's barrier)."""
        if isinstance(item, (_Watermark, _Barrier)):
            return item.lane
        if type(item) is tuple and len(item) == 4:
            return item[3]
        return None

    def _run_task_loop_item(self, item) -> None:
        lane = self._lane_of(item)
        if self._aligner is not None and lane is not None \
                and self._aligner.holding(lane):
            self._held.append(item)      # post-barrier: wait for the cut
            return
        if isinstance(item, _Barrier):
            self._on_barrier(item)
            return
        self._handle(item)

    def _task_loop(self):
        poisons = 0
        while True:
            tw = time.perf_counter()
            item = self.in_q.get()
            self._stats["queue_wait_s"] += time.perf_counter() - tw
            if item is _POISON:
                poisons += 1
                if poisons < self.expected_poisons:
                    continue         # wait for every producer replica to end
                self._flush_held()   # abandoned barrier round at stream end
                self._shutdown()
                return
            self._run_task_loop_item(item)

    def _call_kernel(self, arr, state):
        tk = time.perf_counter()
        try:
            return self.kernel(arr, state)
        finally:
            self._stats["kernel_s"] += time.perf_counter() - tk

    def _handle(self, item) -> None:
        if isinstance(item, _Watermark):
            self._on_watermark(item)
            return
        arr, t0, lease = item[0], item[1], item[2]
        self._stats["batches"] += 1
        self._stats["tuples_in"] += len(arr)
        if self.lat_sink is not None:
            self.lat_sink.append(time.perf_counter() - t0)
        if self._et_win is not None:
            # event-time windowed operator: arriving batches only fill
            # the buffer; the kernel runs per fired pane on watermark
            # passage (complete panes in, whatever the batch cut was).
            # The window retains rows past this item's release point,
            # so a pooled view is privatized first (the only consumer
            # that holds input rows beyond the batch boundary).
            if lease is not None:
                arr = arr.copy()
                lease.release()
            self._et_win.insert(arr, t0)
            return
        if self.device_depth:
            # async device dispatch: enqueue the (lazy) kernel result
            # and only materialize the oldest once the bounded window
            # is full — host-side route/split/emit of batch N overlaps
            # the device computing batch N+1.  The input lease is held
            # until retirement so the pooled buffer cannot recycle
            # while the device may still read it.
            try:
                lazy = self._call_kernel(arr, self.state)
            except BaseException:
                if lease is not None:
                    lease.release()
                raise
            self._inflight.append((lazy, t0, lease))
            while len(self._inflight) >= self.device_depth:
                self._retire_one()
            return
        try:
            self._dispatch(self._call_kernel(arr, self.state), t0, lease)
        finally:
            if lease is not None:
                lease.release()

    # -- checkpoint barriers ----------------------------------------------
    def _emit_barrier(self, b: int) -> None:
        """Spout side of a checkpoint: retire offset ``b`` into the
        snapshot (every emitted batch is flushed first — drain-on-snapshot,
        so the recorded offset never includes a batch whose rows are still
        buffered on this side of the cut) and forward the numbered barrier
        on every lane a watermark would ride."""
        ckpt_id = b // self.ckpt.every
        self._drain()
        self.ckpt.deposit(
            ckpt_id, self.name,
            payload=state_payload(self.state, copy=True),
            aux=self._aux_payload(), offset=b)
        for port in self.ports:
            for j in port.route.watermark_lanes():
                self._put_wm(port.queues[j], _Barrier(self.lane, ckpt_id))

    def _on_barrier(self, msg: _Barrier) -> None:
        """Align one lane's barrier; on the last lane, cut.

        The cut: retire the whole device dispatch window (in-flight lazy
        results belong before the barrier), deposit a deep-copied state
        payload, forward the barrier downstream (after draining buffered
        jumbos, which logically precede it), then re-process the items
        held back during alignment — a held barrier can immediately open
        (or even complete) the next round, re-holding its lane, so this
        loops until no held item is processable."""
        if not self._aligner.arrive(msg.lane, msg.ckpt_id):
            return
        self._cut(msg.ckpt_id)
        while self._held:
            pending, self._held = self._held, []
            progressed = False
            for item in pending:
                lane = self._lane_of(item)
                if lane is not None and self._aligner.holding(lane):
                    self._held.append(item)
                    continue
                progressed = True
                if isinstance(item, _Barrier):
                    if self._aligner.arrive(item.lane, item.ckpt_id):
                        self._cut(item.ckpt_id)
                else:
                    self._handle(item)
            if not progressed:
                return   # the rest waits on a still-incomplete round

    def _cut(self, ckpt_id: int) -> None:
        self._retire_all()
        self.ckpt.deposit(
            ckpt_id, self.name,
            payload=state_payload(self.state, copy=True),
            aux=self._aux_payload())
        self._drain()
        for port in self.ports:
            for j in port.route.watermark_lanes():
                self._put_wm(port.queues[j], _Barrier(self.lane, ckpt_id))

    def _flush_held(self) -> None:
        """End of stream with an incomplete barrier round (duration cut
        dropped a barrier, or the stream simply drained between barriers):
        the round can never complete, so abandon it — process the held
        data and marks in arrival order, dropping the orphaned barriers.
        Recovery only ever reads *completed* checkpoints, so an abandoned
        round is invisible to it."""
        if self._aligner is None or not self._held:
            return
        self._aligner.reset()
        held, self._held = self._held, []
        for item in held:
            if not isinstance(item, _Barrier):
                self._handle(item)

    def _retire_one(self) -> None:
        """Materialize + dispatch the oldest in-flight device result (FIFO
        — output order and watermark order are identical to the synchronous
        path by construction)."""
        outs, t0, lease = self._inflight.popleft()
        try:
            self._dispatch(
                [None if o is None else np.asarray(o) for o in outs],
                t0, lease)
        finally:
            if lease is not None:
                lease.release()

    def _retire_all(self) -> None:
        while self._inflight:
            self._retire_one()

    def _merged_watermark(self, msg: _Watermark) -> float:
        """Merged frontier after one lane's mark.  With a single producer
        lane the merged value IS the lane's value (regressions are caught
        by the caller's frontier check), so the min-merge bookkeeping is
        skipped entirely."""
        if self._single_lane:
            self._wm_lane = msg.lane
            return msg.value
        return self._wm_merge.update(msg.lane, msg.value)

    def _on_watermark(self, msg: _Watermark) -> None:
        """Merge one lane's watermark; on advance, fire panes and forward.

        The merged watermark is min over producer lanes (monotone per lane,
        see :class:`~.routing.WatermarkMerger`).  Every pane the mark
        released arrives as **one** stacked :class:`~.state.PaneBatch`; a
        :func:`~.state.segmented` kernel runs once over it with
        ``state.segments`` set, an unmarked kernel is driven one segment
        slice at a time with ``state.pane`` set (the single-span compat
        shim over the same buffer).  Either way there is one batched
        dispatch per watermark, and the advanced watermark is forwarded
        along every compiled route *after* the panes it released."""
        # a mark trails the batches before it in queue order: retire every
        # in-flight device result first so outputs never follow their mark
        self._retire_all()
        merged = self._merged_watermark(msg)
        if not merged > self._wm_fwd:
            return
        self._wm_fwd = merged
        if self._et_win is not None:
            batch = self._et_win.on_watermark(merged)
            if batch.n:
                if getattr(self.kernel, "segmented", False):
                    self.state.segments = batch.segments
                    self.state.pane = batch.segments.span(0) \
                        if batch.n == 1 else None
                    try:
                        outs = self._call_kernel(batch.rows, self.state)
                    finally:
                        self.state.segments = None
                        self.state.pane = None
                    self._dispatch(outs, batch.t0)
                else:
                    acc: List[List[np.ndarray]] = [[] for _ in self.ports]
                    for rows, t0, span in batch:
                        self.state.pane = span
                        outs = self._call_kernel(rows, self.state)
                        if len(outs) != len(self.ports):
                            self._dispatch(outs, t0)  # raises the mismatch
                        for i, arr in enumerate(outs):
                            if arr is not None and len(arr):
                                acc[i].append(arr)
                    self.state.pane = None
                    self._dispatch(
                        [np.concatenate(a) if len(a) > 1 else
                         (a[0] if a else None) for a in acc], batch.t0)
        if self.ports:
            self._emit_watermark(merged)

    def _emit_watermark(self, value: float) -> None:
        """Flush buffered jumbos, then forward ``value`` on every lane of
        every output route (a watermark is a promise about the whole
        stream; buffered tuples logically precede it and must not be
        overtaken)."""
        self._drain()
        for port in self.ports:
            for j in port.route.watermark_lanes():
                self._put_wm(port.queues[j], _Watermark(self.lane, value))

    def _put_wm(self, q: queue.Queue, msg: _Watermark) -> None:
        if self.is_spout:                # interruptible put: stop wins
            while True:
                try:
                    q.put(msg, timeout=0.02)
                    return
                except queue.Full:
                    if self.stop_event.is_set():
                        # dropped: in duration mode tail panes may stay
                        # buffered (non-deterministic cut anyway);
                        # deterministic replay (max_batches) never drops —
                        # spouts finish their budget and block here freely
                        return
        q.put(msg)

    # -- the one emit path -------------------------------------------------
    def _dispatch(self, outs, t0: float,
                  lease: Optional[_Lease] = None) -> None:
        """Route kernel/spout outputs to consumer lanes.  ``lease`` is the
        *input* batch's pooled-buffer lease (None for fresh arrays): any
        enqueued array still sharing that buffer's memory — pass-through
        jumbos, kernel outputs that are views of the input — retains it so
        the buffer cannot recycle under a downstream reader."""
        if len(outs) != len(self.ports):
            raise ValueError(
                f"{self.name}: kernel returned {len(outs)} output streams "
                f"for {len(self.ports)} declared consumers")
        for port, arr in zip(self.ports, outs):
            if arr is None or len(arr) == 0:
                continue
            if port.shared_flush:        # broadcast: one flush, all lanes
                self._deliver_fanout(port, arr, t0, lease)
                continue
            for j, part in port.route.split(arr):
                self._deliver(port, j, part, t0, lease)

    def _passthrough_lease(self, port: _OutPort, jumbo: np.ndarray,
                           jlease: Optional[_Lease],
                           in_lease: Optional[_Lease]) -> Optional[_Lease]:
        """Lease for one enqueued jumbo: a flush's own lease (already
        counted), else the input's lease when the jumbo still aliases the
        input's pooled buffer (retained here, once per enqueue)."""
        if jlease is not None:
            return jlease
        if in_lease is not None and port.route.aliases_input() \
                and np.may_share_memory(jumbo, in_lease.buf):
            in_lease.retain()
            return in_lease
        return None

    def _deliver(self, port: _OutPort, j: int, part: np.ndarray,
                 t0: float, in_lease: Optional[_Lease] = None) -> None:
        if not self.jumbo:
            for row in part:             # per-tuple insertion (Fig. 16)
                self._put(port, j, np.asarray([row]), t0)
            return
        for jumbo, jt0, jlease in port.buffers[j].add(part, t0):
            self._put(port, j, jumbo, jt0,
                      self._passthrough_lease(port, jumbo, jlease, in_lease))

    def _deliver_fanout(self, port: _OutPort, arr: np.ndarray, t0: float,
                        in_lease: Optional[_Lease] = None) -> None:
        """Broadcast emit: accumulate once in the port's shared lane buffer
        and enqueue the *same* flush view on every lane, refcounted once
        per lane — no per-consumer copy is ever materialized."""
        k = len(port.queues)
        if not self.jumbo:
            for row in arr:
                row1 = np.asarray([row])
                for j in range(k):
                    self._put(port, j, row1, t0)
            return
        for jumbo, jt0, jlease in port.buffers[0].add(arr, t0):
            lease = self._passthrough_lease(port, jumbo, jlease, in_lease)
            if lease is not None:
                lease.retain(k - 1)      # one reference per lane
            for j in range(k):
                self._put(port, j, jumbo, jt0, lease)

    def _put(self, port: _OutPort, j: int, arr: np.ndarray,
             t0: float, lease: Optional[_Lease] = None) -> None:
        q = port.queues[j]
        # checkpointing lane-tags data items: a consumer's single FIFO
        # input interleaves producer lanes, and alignment must know which
        # lane each item came from to hold back post-barrier items
        item = (arr, t0, lease, self.lane) if self.ckpt is not None \
            else (arr, t0, lease)
        if self.is_spout:                # interruptible put: stop wins
            while True:
                try:
                    q.put(item, timeout=0.02)
                    break
                except queue.Full:
                    if self.stop_event.is_set():
                        if lease is not None:
                            lease.release()
                        return           # dropped, never counted
        else:                            # task: block (backpressure)
            q.put(item)
        if lease is not None and not getattr(q, "by_reference", True):
            # copying transports (shared-memory rings) consumed the bytes
            # synchronously inside put — the consumer process never sees
            # the lease, so this side retires its reference now
            lease.release()
        port.delivered[j] += len(arr)

    def _shutdown(self):
        self._retire_all()
        self._drain()
        self._poison()

    def _drain(self):
        # flush partially-filled jumbo lanes
        for port in self.ports:
            if port.shared_flush:
                out = port.buffers[0].drain()
                if out is not None:
                    jumbo, t0, lease = out
                    if lease is not None:
                        lease.retain(len(port.queues) - 1)
                    for j in range(len(port.queues)):
                        self._put(port, j, jumbo, t0, lease)
                continue
            for j, buf in enumerate(port.buffers):
                out = buf.drain()
                if out is not None:
                    self._put(port, j, *out)

    def _poison(self):
        # once per consumer queue per producer replica
        for port in self.ports:
            for q in port.queues:
                q.put(_POISON)


class _ChainBuffer:
    """Lease-free jumbo accumulator for one intra-chain hop of a fused
    executor.

    Replicates :class:`_JumboBuffer`'s flush-boundary semantics exactly —
    shape-change flush, whole-batch pass-through, overflow concatenate,
    oldest-tuple timestamp — because downstream kernel-call granularity
    *is* those boundaries, and stateful count-window kernels make them
    byte-parity-critical.  No arena/lease: flushed views feed the next
    member's kernel in the same thread, and a fresh store is allocated
    per fill cycle since the tail may pass a flushed view straight into
    an output queue where it lives arbitrarily long.
    """

    __slots__ = ("cap", "_store", "_n", "_t0")

    def __init__(self, cap: int):
        self.cap = cap
        self._store: Optional[np.ndarray] = None
        self._n = 0
        self._t0 = 0.0

    def _flush(self) -> Tuple[np.ndarray, float]:
        view = self._store[: self._n]
        view.flags.writeable = False
        self._store = None
        self._n = 0
        return view, self._t0

    def add(self, arr: np.ndarray, t0: float) -> List[Tuple[np.ndarray,
                                                            float]]:
        out: List[Tuple[np.ndarray, float]] = []
        store = self._store
        if self._n and (store.shape[1:] != arr.shape[1:]
                        or store.dtype != arr.dtype):
            out.append(self._flush())
            store = None
        if self._n == 0 and len(arr) >= self.cap:
            out.append((arr, t0))                      # pass-through
            return out
        if store is None or store.shape[1:] != arr.shape[1:] \
                or store.dtype != arr.dtype:
            self._store = store = np.empty((self.cap,) + arr.shape[1:],
                                           arr.dtype)
        if self._n == 0:
            self._t0 = t0
        end = self._n + len(arr)
        if end > self.cap:
            # overflow: concatenate so the boundary lands where the
            # unfused lane's would (the store stays for the next cycle —
            # its prefix was copied out)
            out.append((np.concatenate([store[: self._n], arr]), self._t0))
            self._n = 0
        elif end == self.cap:
            store[self._n:end] = arr
            self._n = end
            out.append(self._flush())
        else:
            store[self._n:end] = arr
            self._n = end
        return out

    def drain(self) -> Optional[Tuple[np.ndarray, float]]:
        if self._n == 0:
            return None
        return self._flush()


class _FusedMember:
    """One operator of a fused chain as one replica executes it."""

    __slots__ = ("op", "uid", "kernel", "state", "stats")

    def __init__(self, op: str, uid: str, kernel: Callable, state):
        self.op = op
        self.uid = uid
        self.kernel = kernel
        self.state = state
        self.stats = {"batches": 0, "tuples_in": 0, "tuples_out": 0,
                      "queue_wait_s": 0.0, "kernel_s": 0.0}


class FusedExecutor(Executor):
    """One replica of a fused operator chain (the tentpole of operator
    fusion, after Prasaad et al. 1803.11328).

    Member kernels run back-to-back on the same batch in one thread: no
    intermediate queue, no per-hop watermark min-merge (the head merges
    once; marks and checkpoint barriers traverse the chain inline), no
    arena lease per stage.  Inter-member jumbo boundaries are reproduced
    by :class:`_ChainBuffer` so every member sees byte-identical kernel
    calls to the unfused plan, and state handles stay per member — so
    ``migrate_states``, checkpoints and :class:`RuntimeResult`
    fingerprints are byte-identical to the unfused run.  The executor
    consumes as the head (its input queue, its expected poisons) and
    emits as the tail (``self.lane``), which keeps every downstream
    lane/poison count exactly what the unfused plan produced.
    """

    def __init__(self, chain: List[str], index: int, replicas: int,
                 ports: List[_OutPort], batch: int, jumbo: bool,
                 states: List[object], kernels: List[Callable], **kw):
        super().__init__(f"{chain[0]}#{index}", ports, batch, jumbo,
                         states[0], kernel=kernels[0], **kw)
        self.chain = list(chain)
        self._replicas = replicas      # uniform member parallelism
        self.members = [
            _FusedMember(op, f"{op}#{index}", kernels[j], states[j])
            for j, op in enumerate(chain)]
        self.lane = f"{chain[-1]}#{index}"
        self._accs = [_ChainBuffer(batch) for _ in chain[:-1]]
        # base-class counters (queue wait from _task_loop) land on the head
        self._stats = self.members[0].stats

    def stats_payload(self) -> Dict[str, dict]:
        out: Dict[str, dict] = {}
        for j, m in enumerate(self.members):
            s = dict(m.stats)
            if j == len(self.members) - 1:
                s["tuples_out"] = int(sum(p.tuples_entered()
                                          for p in self.ports))
            out[m.uid] = s
        return out

    def _handle(self, item) -> None:
        if isinstance(item, _Watermark):
            self._on_watermark(item)
            return
        arr, t0, lease = item[0], item[1], item[2]
        try:
            self._feed(0, arr, t0, lease)
        finally:
            if lease is not None:
                lease.release()

    def _feed(self, j: int, arr: np.ndarray, t0: float,
              in_lease: Optional[_Lease]) -> None:
        """Run member ``j`` on one jumbo and push its output down the
        chain through the member's :class:`_ChainBuffer` (tail output
        goes out the normal dispatch path; ``in_lease`` rides along so a
        tail pass-through of the inbound pooled buffer still retains it).
        """
        m = self.members[j]
        m.stats["batches"] += 1
        m.stats["tuples_in"] += len(arr)
        last = j == len(self.members) - 1
        if last and self.lat_sink is not None:
            # sink receipt latency samples at the same jumbo boundaries
            # the unfused sink saw
            self.lat_sink.append(time.perf_counter() - t0)
        tk = time.perf_counter()
        try:
            outs = m.kernel(arr, m.state)
        finally:
            m.stats["kernel_s"] += time.perf_counter() - tk
        if last:
            self._dispatch(outs, t0, in_lease)
            return
        if len(outs) != 1:
            raise ValueError(
                f"{self.name}: fused member {m.op!r} returned {len(outs)} "
                "output streams for its single intra-chain consumer")
        out = outs[0]
        if out is None or len(out) == 0:
            return
        m.stats["tuples_out"] += len(out)
        if not self.jumbo:
            for row in out:              # per-tuple mode (Fig. 16) parity
                self._feed(j + 1, np.asarray([row]), t0, in_lease)
            return
        for jum, jt0 in self._accs[j].add(out, t0):
            self._feed(j + 1, jum, jt0, in_lease)

    def _flush_chain(self) -> None:
        """Drain inter-member accumulators head-to-tail: member ``j``'s
        partial jumbo feeds ``j+1`` before ``j+1``'s own partial flushes —
        the same cascade order the unfused pipeline's per-hop drains
        produce at a mark/cut/stream-end.  Accumulator contents are always
        private copies, so no input lease is involved."""
        for j in range(1, len(self.members)):
            out = self._accs[j - 1].drain()
            if out is not None:
                self._feed(j, out[0], out[1], None)

    def _on_watermark(self, msg: _Watermark) -> None:
        """One merge at the head per mark (single-lane fast path applies
        when the head has one producer lane); on advance the chain's
        buffered rows flush member-to-member — they logically precede the
        mark, exactly like the unfused per-hop drains — before the tail
        forwards it.  Chains contain no device or event-time-window
        members by eligibility, so the base pane logic never applies."""
        merged = self._merged_watermark(msg)
        if not merged > self._wm_fwd:
            return
        self._wm_fwd = merged
        self._flush_chain()
        if self.ports:
            self._emit_watermark(merged)

    def _member_aux(self, j: int) -> dict:
        """Checkpoint aux for member ``j``: the head's is its real merge
        bookkeeping; downstream members' is synthesized exactly.  Marks
        ride every lane and each replica of member ``j-1`` forwards the
        same merged frontier, so at an aligned cut every inbound lane of
        member ``j`` sits precisely at this executor's forwarded
        frontier."""
        if j == 0:
            return self._aux_payload()
        fwd = self._wm_fwd
        if fwd == -math.inf:
            return {"wm_lanes": {}, "wm_fwd": fwd}
        prev = self.chain[j - 1]
        return {"wm_lanes": {f"{prev}#{r}": fwd
                             for r in range(self._replicas)},
                "wm_fwd": fwd}

    def _cut(self, ckpt_id: int) -> None:
        """Aligned snapshot through the chain: drain each hop's
        accumulator into the next member (buffered rows logically precede
        the barrier), deposit every member's state under its own uid —
        byte-identical to the unfused executors' deposits — then forward
        the barrier as the tail."""
        for j, m in enumerate(self.members):
            if j:
                out = self._accs[j - 1].drain()
                if out is not None:
                    self._feed(j, out[0], out[1], None)
            self.ckpt.deposit(
                ckpt_id, m.uid,
                payload=state_payload(m.state, copy=True),
                aux=self._member_aux(j))
        self._drain()
        for port in self.ports:
            for jj in port.route.watermark_lanes():
                self._put_wm(port.queues[jj], _Barrier(self.lane, ckpt_id))

    def _shutdown(self):
        self._flush_chain()
        self._drain()
        self._poison()


WM_TARGET_PANES = 128   # adaptive cadence: aim for this many released panes
# per watermark.  Derived from the declared window grid (panes per batch =
# batch * et_spacing / slide, times the probed (key, span) multiplicity for
# keyed pane groups) instead of a hand-calibrated constant: sd_et at the
# bench batch of 256 lands exactly on the previously calibrated 8 marks.


def upstream_spouts(graph, op: str) -> List[str]:
    """Spout operators upstream of ``op`` (inclusive if ``op`` is one)."""
    seen, frontier = set(), [op]
    while frontier:
        x = frontier.pop()
        if x in seen:
            continue
        seen.add(x)
        frontier.extend(graph.producers(x))
    return [s for s in graph.spouts() if s in seen]


def derive_watermark_every(app: StreamingApp, spout: str,
                           batch: int) -> int:
    """Resolve a spout's ``watermark_every="auto"`` declaration.

    Panes released per batch follow from the declared grid: ``batch *
    et_spacing / slide`` spans advance per batch, each multiplied by the
    probed per-span ``(key, span)`` multiplicity for keyed pane groups
    (:func:`~.simulator.probe_pane_keys`).  The cadence then targets
    :data:`WM_TARGET_PANES` panes per mark — enough panes to amortize the
    per-mark jumbo flush + merge + one stacked segmented fire, without the
    fire bursts outgrowing the pipeline's queue slack (the failure mode of
    over-coarse hand tunings).  Clamped to ``[1, 64]`` batches.
    """
    from .simulator import probe_et_spacing, probe_pane_keys
    spacing = probe_et_spacing(app, batch=batch).get(spout, 1.0)
    mult = probe_pane_keys(app, batch=batch)
    panes_per_batch = 0.0
    for op, w in app.time_windows().items():
        if spout not in upstream_spouts(app.graph, op):
            continue
        panes_per_batch += batch * spacing / w.slide * mult.get(op, 1.0)
    if panes_per_batch <= 0:
        return 1
    return int(max(1, min(64, round(WM_TARGET_PANES / panes_per_batch))))


@dataclasses.dataclass
class PreparedApp:
    """Construct phase of the executor lifecycle: everything ``run_app``
    derives *before* any thread (or worker process) starts — validated
    graph, compiled routes, per-replica states, resolved watermark
    cadences.  Backends (threads here, processes in
    :mod:`repro.streaming.procexec`) share this one construct path and
    differ only in how they wire queues and run the executors."""

    lg: object                              # LogicalGraph
    parallelism: Dict[str, int]
    routes: object                          # RoutingTable
    states: Dict[str, List[OperatorState]]
    win_key_by: Dict[str, object]
    wm_every: Dict[str, int]                # resolved per-spout cadence
    #: fused chains (lists of member operator names) this run realizes:
    #: :func:`build_executors` compiles each into one
    #: :class:`FusedExecutor` per replica instead of per-member executors
    chains: List[List[str]] = dataclasses.field(default_factory=list)


def prepare_app(app: StreamingApp,
                parallelism: Optional[Dict[str, int]] = None,
                partition: Optional[Dict[str, str]] = None,
                initial_states: Optional[Dict[str, List[dict]]] = None,
                batch: int = 256, fuse=None) -> PreparedApp:
    """Validate + compile + build state: the serializable construct phase.

    Raises exactly what ``run_app`` raised inline before the split; the
    returned :class:`PreparedApp` feeds :func:`build_executors` in any
    backend.

    ``fuse`` selects operator fusion: ``None``/``"off"`` (no fusion),
    ``"auto"`` (fuse every maximal eligible chain — see
    :mod:`repro.streaming.fusion`), or an explicit list of chains
    (lists of operator names).  Explicit chains are validated
    structurally; a chain realized with mismatched member parallelism is
    dropped, not an error — fusion is an optimization and a plan-derived
    chain may be invalidated by elastic rescaling."""
    lg = app.graph
    parallelism = dict(parallelism or {})
    validate_operator_names(lg, parallelism, "parallelism")
    for name in lg.operators:
        parallelism.setdefault(name, 1)
    routes = compile_routes(app, partition=partition)
    # event-time panes fire per replica from per-replica buffers: a
    # non-keyed split would scatter each pane's rows over replicas and
    # every replica would fire its own partial pane — reject instead of
    # silently aggregating subsets.  Keyed inputs give *sharded* panes;
    # with keyed pane groups (WindowSpec(keyed=True)) the pane unit is
    # (key, span), so replication preserves pane bytes exactly — that is
    # the lift of the PR 4 replication clamp for keyed time windows.
    win_key_by: Dict[str, object] = {}
    for name, sspec in (getattr(app, "state", None) or {}).items():
        if sspec.window is None or not sspec.window.time:
            continue
        strategies = {routes.strategy(u, name) for u in lg.producers(name)}
        if sspec.window.keyed:
            if strategies != {"key"}:
                raise ValueError(
                    f"operator {name!r} declares keyed event-time panes "
                    f"with {sorted(strategies)} input routing: pane groups "
                    "shard by the compiled keyed route, so every input "
                    "stream must be partition='key'")
            win_key_by[name] = routes.key_extractor(name)
        elif parallelism[name] > 1 and strategies != {"key"}:
            raise ValueError(
                f"operator {name!r} declares an event-time window at "
                f"parallelism {parallelism[name]} with "
                f"{sorted(strategies)} input routing: replicas would "
                "each fire partial panes over an arbitrary subset of "
                "rows. Key every input stream (sharded panes) or keep "
                "parallelism 1")

    states: Dict[str, List[OperatorState]] = {
        name: [make_operator_state(app.state.get(name), parallelism[name], j,
                                   key_by=win_key_by.get(name))
               for j in range(parallelism[name])]
        for name in lg.operators}
    if initial_states:
        validate_operator_names(lg, initial_states, "initial_states")
        for name, reps in initial_states.items():
            if len(reps) != parallelism[name]:
                raise ValueError(
                    f"initial_states[{name!r}] has {len(reps)} replica "
                    f"states for parallelism {parallelism[name]} "
                    "(migrate_states targets one replica set)")
            states[name] = list(reps)
        # keyed pane groups shard by the *current* compiled route: re-attach
        # the extractor to migrated window buffers (idempotent)
        for name, kb in win_key_by.items():
            for st in states[name]:
                win = getattr(st, "window", None)
                if isinstance(win, EventTimeWindowState):
                    win.key_by = kb

    wm_every: Dict[str, int] = {}
    declared = getattr(app, "watermark_every", None) or {}
    for name in lg.spouts():
        cadence = declared.get(name, 1)
        wm_every[name] = derive_watermark_every(app, name, batch) \
            if cadence == "auto" else cadence

    chains: List[List[str]] = []
    if fuse is not None and fuse != "off":
        from .fusion import detect_chains, validate_chains
        no_fuse = frozenset(getattr(app, "no_fuse", ()))
        tw = frozenset(app.time_windows())
        if fuse == "auto":
            chains = detect_chains(lg, routes, no_fuse=no_fuse,
                                   time_windows=tw, parallelism=parallelism)
        else:
            chains = validate_chains(lg, routes, fuse, no_fuse=no_fuse,
                                     time_windows=tw)
            chains = [c for c in chains
                      if len({parallelism[m] for m in c}) == 1]
    return PreparedApp(lg, parallelism, routes, states, win_key_by,
                       wm_every, chains)


def resolve_offsets(lg, parallelism: Dict[str, int],
                    initial_offsets: Optional[Dict[str, int]]
                    ) -> Dict[Tuple[str, int], int]:
    """Expand ``initial_offsets`` (spout operator names or replica uids
    like ``"spout#0"`` -> emitted-batch counter) to per-replica start
    batches, validating every key against the graph's spouts."""
    out: Dict[Tuple[str, int], int] = {}
    if not initial_offsets:
        return out
    spouts = set(lg.spouts())
    for key, off in initial_offsets.items():
        if isinstance(off, bool) or not isinstance(off, int) or off < 0:
            raise ValueError(
                f"initial_offsets[{key!r}] must be an int >= 0, got {off!r}")
        name, _, idx = key.partition("#")
        if name not in spouts:
            raise ValueError(
                f"initial_offsets names {key!r}, which is not a spout "
                f"(spouts: {sorted(spouts)})")
        if idx:
            i = int(idx)
            if not 0 <= i < parallelism[name]:
                raise ValueError(
                    f"initial_offsets names replica {key!r} but {name!r} "
                    f"has parallelism {parallelism[name]}")
            out[(name, i)] = off
        else:
            for i in range(parallelism[name]):
                out.setdefault((name, i), off)
    return out


def build_executors(app: StreamingApp, prep: PreparedApp, *, batch: int,
                    jumbo: bool, vectorized: Optional[bool], seed: int,
                    max_batches: Optional[int], stop, latencies: List[float],
                    add_spout_count: Callable[[int], None],
                    in_q_of: Callable, out_q_of: Callable,
                    only=None, dispatch_depth: Optional[int] = None,
                    initial_offsets: Optional[Dict[str, int]] = None,
                    coordinator: Optional[CheckpointCoordinator] = None,
                    final_watermark: bool = True,
                    initial_aux: Optional[Dict[Tuple[str, int], dict]] = None
                    ) -> Tuple[List[Executor], List[Executor]]:
    """Instantiate the executors of a prepared app (the run phase's cast).

    ``in_q_of(name, i)`` returns the input endpoint of a task replica;
    ``out_q_of(name, i, consumer)`` the list of per-consumer-replica output
    endpoints for one producer replica.  Endpoints only need the
    ``queue.Queue`` protocol the :class:`Executor` uses (``get``, blocking
    ``put``, ``put(timeout=)`` raising ``queue.Full``) — threads pass real
    queues, the process backend passes shared-memory rings.  ``only``
    restricts construction to a replica subset (one worker's share).

    ``dispatch_depth`` overrides every device operator's declared in-flight
    window (the sync-vs-async A/B flag); ``initial_offsets`` resumes spout
    replicas at recorded emitted-batch counters (see
    :func:`resolve_offsets`).

    ``coordinator`` enables aligned-barrier checkpointing (spouts inject
    barriers every ``coordinator.every`` batches, every executor deposits
    its aligned snapshot into it); ``initial_aux`` restores per-replica
    watermark bookkeeping from a checkpoint; ``final_watermark=False``
    suspends instead of draining — spouts skip the end-of-stream ``+inf``
    mark so event-time pane buffers stay resident for migration/resume.
    """
    lg, parallelism = prep.lg, prep.parallelism
    offsets = resolve_offsets(lg, parallelism, initial_offsets)
    aux = initial_aux or {}
    spouts: List[Executor] = []
    tasks: List[Executor] = []
    chain_of_head = {c[0]: c for c in prep.chains}
    fused_members = {m for c in prep.chains for m in c[1:]}
    for name, spec in lg.operators.items():
        if name in fused_members:
            continue                 # realized inside the head's executor
        chain = chain_of_head.get(name)
        if chain is not None:
            # one FusedExecutor per replica: consumes as the head, emits
            # as the tail — downstream queues/lanes/poison counts are
            # exactly the unfused plan's
            tail = chain[-1]
            is_sink = not lg.consumers(tail)
            n_producer_units = sum(parallelism[p]
                                   for p in lg.producers(name))
            for i in range(parallelism[name]):
                if only is not None and (name, i) not in only:
                    continue
                ports = [
                    _OutPort(prep.routes.route(tail, cop).bind(
                        parallelism[cop], vectorized=vectorized),
                        out_q_of(tail, i, cop), batch)
                    for cop in lg.consumers(tail)]
                tasks.append(FusedExecutor(
                    chain, i, parallelism[name], ports, batch, jumbo,
                    [prep.states[m][i] for m in chain],
                    [app.kernels[m] for m in chain],
                    in_q=in_q_of(name, i),
                    expected_poisons=max(n_producer_units, 1),
                    lat_sink=latencies if is_sink else None,
                    ckpt=coordinator, initial_aux=aux.get((name, i))))
            continue
        is_sink = not lg.consumers(name)
        n_producer_units = sum(parallelism[p] for p in lg.producers(name))
        for i in range(parallelism[name]):
            if only is not None and (name, i) not in only:
                continue
            ports = [
                _OutPort(prep.routes.route(name, cop).bind(
                    parallelism[cop], vectorized=vectorized),
                    out_q_of(name, i, cop), batch)
                for cop in lg.consumers(name)]
            if spec.is_spout:
                spouts.append(Executor(
                    f"{name}#{i}", ports, batch, jumbo,
                    prep.states[name][i], source=app.source_for(name),
                    stop=stop, seed=seed + 7919 * i,
                    on_delivered=add_spout_count, max_batches=max_batches,
                    event_time=getattr(app, "event_time", {}).get(name),
                    wm_every=prep.wm_every.get(name, 1),
                    wm_interval=getattr(app, "watermark_interval",
                                        {}).get(name),
                    start_batch=offsets.get((name, i), 0),
                    ckpt=coordinator, final_watermark=final_watermark,
                    initial_aux=aux.get((name, i))))
            else:
                depth = 0
                if getattr(spec, "device", False):
                    depth = dispatch_depth if dispatch_depth is not None \
                        else spec.dispatch_depth
                tasks.append(Executor(
                    f"{name}#{i}", ports, batch, jumbo,
                    prep.states[name][i], kernel=app.kernels[name],
                    in_q=in_q_of(name, i),
                    expected_poisons=max(n_producer_units, 1),
                    lat_sink=latencies if is_sink else None,
                    device_depth=depth,
                    ckpt=coordinator, initial_aux=aux.get((name, i))))
    return spouts, tasks


def collect_result(prep: PreparedApp, spout_tuples: int,
                   latencies: List[float], wall: float,
                   spout_offsets: Optional[Dict[str, int]] = None,
                   checkpoints: Optional[List[Checkpoint]] = None,
                   exec_stats: Optional[Dict[str, dict]] = None
                   ) -> RuntimeResult:
    """Assemble the common :class:`RuntimeResult` from final states —
    shared by the threaded and process backends."""
    lg, states = prep.lg, prep.states
    sink_ops = lg.sinks()
    sink_tuples = sum(st.get("seen", 0)
                      for op in sink_ops for st in states[op])
    late = panes = 0
    for reps in states.values():
        for st in reps:
            win = getattr(st, "window", None)
            if isinstance(win, EventTimeWindowState):
                late += win.late_drops
                panes += win.panes_fired
    lat = np.array(latencies) if latencies else np.array([0.0])
    return RuntimeResult(
        duration=wall, sink_tuples=int(sink_tuples),
        spout_tuples=int(spout_tuples),
        throughput=sink_tuples / max(wall, 1e-9),
        latency_p50=float(np.percentile(lat, 50)),
        latency_p99=float(np.percentile(lat, 99)),
        states=states, late_drops=late, panes_fired=panes,
        spout_offsets=dict(spout_offsets or {}),
        checkpoints=list(checkpoints or []),
        exec_stats=dict(exec_stats or {}))


def resolve_checkpoint_every(app: StreamingApp, checkpoint_every) -> \
        Optional[int]:
    """The effective barrier cadence: the ``run_app`` argument wins, else
    the Topology declaration (``Topology(checkpoint_every=)``)."""
    every = checkpoint_every if checkpoint_every is not None \
        else getattr(app, "checkpoint_every", None)
    if every is None:
        return None
    if isinstance(every, bool) or not isinstance(every, int) or every < 1:
        raise ValueError(
            f"checkpoint_every must be an int >= 1 (batches between "
            f"barriers), got {every!r}")
    return every


def validate_from_checkpoint(app: StreamingApp, ckpt: Checkpoint, *,
                             batch: int, seed: int,
                             parallelism: Optional[Dict[str, int]],
                             initial_states, initial_offsets
                             ) -> Tuple[Dict[str, int], Dict[str, int]]:
    """Validate a resume request against its checkpoint and derive the
    effective (parallelism, initial_offsets).  Replay determinism requires
    the same app/seed/batch; the snapshot payloads are per-replica, so the
    checkpoint's parallelism is adopted (an explicit conflicting one is
    an error — resharding snapshots is ``migrate_states``' job, not a
    resume's)."""
    if not isinstance(ckpt, Checkpoint):
        raise ValueError(
            "from_checkpoint expects a Checkpoint (restore_checkpoint() "
            f"or RuntimeResult.checkpoints[-1]), got {type(ckpt).__name__}")
    if initial_states is not None or initial_offsets is not None:
        raise ValueError(
            "from_checkpoint conflicts with explicit initial_states/"
            "initial_offsets: the checkpoint carries both halves of the "
            "cut — passing either separately would tear it")
    if ckpt.app != app.name:
        raise ValueError(
            f"checkpoint belongs to app {ckpt.app!r}, not {app.name!r}")
    if ckpt.seed != seed:
        raise ValueError(
            f"checkpoint was taken at seed {ckpt.seed}, resume requested "
            f"seed {seed}: offset replay would produce different batches")
    if ckpt.batch != batch:
        raise ValueError(
            f"checkpoint was taken at batch={ckpt.batch}, resume requested "
            f"batch={batch}: the deterministic source sequence differs")
    if parallelism:
        for name, k in ckpt.parallelism.items():
            if parallelism.get(name, 1) != k:
                raise ValueError(
                    f"checkpoint holds {k} replica snapshots for "
                    f"{name!r} but parallelism requests "
                    f"{parallelism.get(name, 1)} — snapshots are "
                    "per-replica (use migrate_states to reshard)")
    return dict(ckpt.parallelism), dict(ckpt.spout_offsets)


def install_checkpoint(prep: PreparedApp, ckpt: Checkpoint
                       ) -> Dict[Tuple[str, int], dict]:
    """Restore every snapshot payload onto the prepared per-replica
    states (in place, pre-start — workers fork after this in the process
    backend) and return the ``initial_aux`` watermark bookkeeping map."""
    for uid, payload in ckpt.states.items():
        name, _, idx = uid.partition("#")
        restore_state(prep.states[name][int(idx)], payload)
    return {(uid.partition("#")[0], int(uid.partition("#")[2])): aux
            for uid, aux in ckpt.aux.items()}


def run_app(app: StreamingApp, parallelism: Optional[Dict[str, int]] = None,
            batch: int = 256, duration: float = 1.0, jumbo: bool = True,
            queue_cap: int = 32, partition: Optional[Dict[str, str]] = None,
            seed: int = 0, vectorized: Optional[bool] = None,
            max_batches: Optional[int] = None,
            initial_states: Optional[Dict[str, List[dict]]] = None,
            dispatch_depth: Optional[int] = None,
            initial_offsets: Optional[Dict[str, int]] = None,
            checkpoint_every: Optional[int] = None,
            checkpoint_dir: Optional[str] = None,
            from_checkpoint: Optional[Checkpoint] = None,
            final_watermark: bool = True,
            fuse=None
            ) -> RuntimeResult:
    """Execute ``app`` for ``duration`` seconds and return measured stats.

    Partition strategies and key extractors come from the app's Topology
    declaration, compiled once into routes (:mod:`repro.streaming.routing`);
    the ``partition`` argument overrides per operator.  ``vectorized=None``
    (default) picks the keyed-split implementation per edge from the
    calibrated :func:`~.routing.auto_vectorized` threshold;
    ``True``/``False`` force the argsort+bincount / seed per-mask path
    everywhere (the ``bench_runtime.py`` A/B override).

    Declared operator state (``Topology.op(state=StateSpec(...))``) becomes
    managed stores on the replica state handles: keyed stores are sharded
    exactly like the compiled keyed route, so the union of the replica
    stores equals a single-replica run's store.

    ``max_batches`` switches to *deterministic replay*: every spout emits
    exactly that many batches (seeds ``seed .. seed+max_batches-1``) and the
    run drains fully — no drops, no duration cutoff — which makes keyed
    state byte-reproducible across replica counts.  ``initial_states`` seeds
    per-replica state (one entry per replica, e.g. from
    :func:`repro.streaming.state.migrate_states` after a replan).

    ``dispatch_depth`` overrides every declared device operator's async
    in-flight window (1 forces the synchronous path — the A/B flag; the
    outputs are byte-identical either way, only the overlap changes).
    ``initial_offsets`` resumes each spout's deterministic source sequence
    at a recorded emitted-batch counter (``RuntimeResult.spout_offsets``
    from a previous run): the resumed run emits the batches the original
    would have emitted next, making duration-mode runs prefix-continuable.

    ``checkpoint_every`` (or ``Topology(checkpoint_every=)``) turns on
    aligned-barrier checkpointing: every spout injects a numbered barrier
    after each ``checkpoint_every``-th batch, every executor snapshots its
    state at the aligned cut, and each completed checkpoint lands in
    ``RuntimeResult.checkpoints`` (and, with ``checkpoint_dir``, on disk —
    atomically, so a kill mid-run leaves only complete files).
    ``from_checkpoint`` resumes from such a snapshot: states, offsets and
    watermark bookkeeping restore to the cut, and the resumed run's
    output (sink counters, keyed stores, pane multiset, late drops) is
    byte-identical to never having stopped.  ``final_watermark=False``
    suspends an event-time run instead of draining it (no end-of-stream
    ``+inf`` mark), keeping pane buffers resident for ``migrate_states``.

    ``fuse`` enables operator fusion (``"auto"``, explicit chains, or
    ``None``/``"off"``): eligible 1:1 shuffle segments execute as single
    :class:`FusedExecutor` threads with byte-identical results — see
    :mod:`repro.streaming.fusion` and ``docs/API.md`` §3e.
    """
    every = resolve_checkpoint_every(app, checkpoint_every)
    if from_checkpoint is not None:
        parallelism, initial_offsets = validate_from_checkpoint(
            app, from_checkpoint, batch=batch, seed=seed,
            parallelism=parallelism, initial_states=initial_states,
            initial_offsets=initial_offsets)
        if every is None:
            every = from_checkpoint.checkpoint_every
    prep = prepare_app(app, parallelism, partition, initial_states,
                       batch=batch, fuse=fuse)
    initial_aux = install_checkpoint(prep, from_checkpoint) \
        if from_checkpoint is not None else None
    coordinator = CheckpointCoordinator(
        app, prep.parallelism, batch=batch, seed=seed, every=every,
        directory=checkpoint_dir) if every else None
    lg, parallelism = prep.lg, prep.parallelism

    # one input queue per non-spout replica
    in_qs: Dict[Tuple[str, int], queue.Queue] = {}
    for name in lg.operators:
        if not lg.operators[name].is_spout:
            for i in range(parallelism[name]):
                in_qs[(name, i)] = queue.Queue(maxsize=queue_cap)

    latencies: List[float] = []
    stop = threading.Event()
    spout_counts = [0]
    count_lock = threading.Lock()

    def add_spout_count(n: int) -> None:
        with count_lock:
            spout_counts[0] += n

    spouts, tasks = build_executors(
        app, prep, batch=batch, jumbo=jumbo, vectorized=vectorized,
        seed=seed, max_batches=max_batches, stop=stop, latencies=latencies,
        add_spout_count=add_spout_count,
        in_q_of=lambda name, i: in_qs[(name, i)],
        out_q_of=lambda name, i, cop: [in_qs[(cop, j)]
                                       for j in range(parallelism[cop])],
        dispatch_depth=dispatch_depth, initial_offsets=initial_offsets,
        coordinator=coordinator, final_watermark=final_watermark,
        initial_aux=initial_aux)

    for t in tasks:
        t.start()
    t_start = time.perf_counter()
    for th in spouts:
        th.start()
    if max_batches is None:
        time.sleep(duration)
        stop.set()
        join_timeout = 5.0
    else:
        # deterministic replay: spouts finish their batch budget on their
        # own (backpressure, no drops); stop only guards a crashed consumer
        join_timeout = 60.0
    for th in spouts:
        th.join(timeout=join_timeout)
    stop.set()
    for t in tasks:
        t.join(timeout=join_timeout)
    wall = time.perf_counter() - t_start
    exec_stats: Dict[str, dict] = {}
    for ex in spouts + tasks:
        exec_stats.update(ex.stats_payload())
    return collect_result(prep, spout_counts[0], latencies, wall,
                          spout_offsets={s.name: s.emitted_batches
                                         for s in spouts},
                          checkpoints=coordinator.completed
                          if coordinator else None,
                          exec_stats=exec_stats)
