"""Real threaded mini-runtime (paper §5 / Appendix A, shared-memory design).

Executes a :class:`StreamingApp` for real on the host CPU.  Every replica —
spout or task — is one :class:`Executor` thread sharing a single emit path:
tuples are numpy batches passed *by reference* through bounded queues
(backpressure via blocking put) and accumulated into **jumbo tuples** — one
queue insertion per ``batch`` tuples with a single shared header (timestamp),
amortising queue overhead exactly as §5.2 describes.  ``jumbo=False``
degrades to per-tuple insertion for the Fig. 16 factor analysis.

All partitioning decisions go through compiled :class:`~.routing.Route`
objects (see :mod:`repro.streaming.routing`) — the same tables the planner
and the DES consume — so there is no strategy branching here.  The hot path
is batch-vectorized: keyed splits are one argsort/bincount per batch and
jumbo accumulation copies rows into preallocated buffers instead of
list-append-then-concatenate.

This runtime validates streaming *semantics* (WC really counts words); the
NUMA placement effects are exercised through the simulator instead (this
container has a single socket — see DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from .apps import StreamingApp
from .routing import Route, compile_routes, validate_operator_names
from .state import OperatorState, make_operator_state

_POISON = object()


@dataclasses.dataclass
class RuntimeResult:
    duration: float
    sink_tuples: int
    spout_tuples: int
    throughput: float               # sink tuples/sec
    latency_p50: float
    latency_p99: float
    states: Dict[str, List[dict]]   # per-operator replica OperatorStates
    # (dict-compatible; .managed holds declared KeyedStore/BroadcastTable/
    #  ValueStore instances — see repro.streaming.state)


class _JumboBuffer:
    """Preallocated jumbo accumulator for one (stream, consumer-replica) lane.

    Rows are copied in place into a fixed ``cap``-row store — no per-emit
    list append + concatenate — and ``add`` hands back full jumbos.  The
    flush timestamp is the *oldest* buffered tuple's, so end-to-end latency
    accounting matches the seed runtime.  A whole batch that already fills a
    jumbo passes through untouched (zero copies), which keeps the common
    selectivity-one shuffle path as cheap as before.
    """

    __slots__ = ("cap", "_store", "_n", "_t0")

    def __init__(self, cap: int):
        self.cap = cap
        self._store: Optional[np.ndarray] = None
        self._n = 0
        self._t0 = 0.0

    def add(self, arr: np.ndarray,
            t0: float) -> List[Tuple[np.ndarray, float]]:
        """Buffer ``arr``; return the jumbos (if any) now ready to flush."""
        out: List[Tuple[np.ndarray, float]] = []
        store = self._store
        if self._n and (store.shape[1:] != arr.shape[1:]
                        or store.dtype != arr.dtype):
            # the stream changed row shape mid-lane: flush what we have
            out.append((store[: self._n].copy(), self._t0))
            self._n = 0
        if self._n == 0 and len(arr) >= self.cap:
            out.append((arr, t0))                      # zero-copy fast path
            return out
        if store is None or store.shape[1:] != arr.shape[1:] \
                or store.dtype != arr.dtype:
            self._store = store = np.empty((self.cap,) + arr.shape[1:],
                                           arr.dtype)
        if self._n == 0:
            self._t0 = t0
        end = self._n + len(arr)
        if end >= self.cap:
            out.append((np.concatenate([store[: self._n], arr]), self._t0))
            self._n = 0
        else:
            store[self._n:end] = arr
            self._n = end
        return out

    def drain(self) -> Optional[Tuple[np.ndarray, float]]:
        if self._n == 0:
            return None
        out = self._store[: self._n].copy()
        self._n = 0
        return out, self._t0


class _OutPort:
    """One output stream of an executor: a bound route plus the consumer
    replica queues and their jumbo lanes."""

    __slots__ = ("route", "queues", "buffers", "delivered")

    def __init__(self, route: Route, queues: List[queue.Queue], batch: int):
        self.route = route
        self.queues = queues
        self.buffers = [_JumboBuffer(batch) for _ in queues]
        self.delivered = [0] * len(queues)   # tuples enqueued, per lane

    def tuples_entered(self) -> int:
        return self.route.tuples_entered(self.delivered)


class Executor(threading.Thread):
    """One replica of any operator — spout or task (the paper's "executor").

    Spouts generate input with ``source``; tasks pull jumbos from ``in_q``.
    Both emit through the same path: ``Route.split`` assigns tuples to
    consumer replicas and per-lane jumbo buffers amortise queue insertions,
    for per-tuple (``jumbo=False``) and jumbo modes alike.
    """

    def __init__(self, name: str, ports: List[_OutPort], batch: int,
                 jumbo: bool, state: dict, *,
                 kernel: Optional[Callable] = None,
                 in_q: Optional[queue.Queue] = None,
                 expected_poisons: int = 0,
                 source: Optional[Callable] = None,
                 stop: Optional[threading.Event] = None,
                 seed: int = 0,
                 lat_sink: Optional[List[float]] = None,
                 on_delivered: Optional[Callable[[int], None]] = None,
                 max_batches: Optional[int] = None):
        super().__init__(daemon=True, name=name)
        self.ports = ports
        self.batch = batch
        self.jumbo = jumbo
        self.state = state
        self.kernel = kernel
        self.in_q = in_q
        self.expected_poisons = expected_poisons
        self.source = source
        self.stop_event = stop
        self.seed = seed
        self.lat_sink = lat_sink
        self.on_delivered = on_delivered
        self.max_batches = max_batches

    @property
    def is_spout(self) -> bool:
        return self.source is not None

    def run(self):
        if self.is_spout:
            self._run_spout()
        else:
            self._run_task()

    def _run_spout(self):
        b = 0
        while not self.stop_event.is_set() and \
                (self.max_batches is None or b < self.max_batches):
            arr = self.source(self.batch, self.seed + b)
            b += 1
            t0 = time.perf_counter()
            # logical fan-out: every output stream carries the same batch
            self._dispatch([arr] * len(self.ports), t0)
        self._drain()
        if self.on_delivered is not None:
            # tuples that entered the dataflow: max over streams — fan-out
            # duplicates tuples, it does not multiply them — and only what
            # was actually enqueued (stop can interrupt a keyed delivery
            # between partitions).  Counted before the blocking poison puts
            # so a stalled consumer cannot zero the tally.
            self.on_delivered(max((p.tuples_entered() for p in self.ports),
                                  default=0))
        self._poison()

    def _run_task(self):
        poisons = 0
        while True:
            item = self.in_q.get()
            if item is _POISON:
                poisons += 1
                if poisons < self.expected_poisons:
                    continue         # wait for every producer replica to end
                self._shutdown()
                return
            arr, t0 = item
            if self.lat_sink is not None:
                self.lat_sink.append(time.perf_counter() - t0)
            self._dispatch(self.kernel(arr, self.state), t0)

    # -- the one emit path -------------------------------------------------
    def _dispatch(self, outs, t0: float) -> None:
        if len(outs) != len(self.ports):
            raise ValueError(
                f"{self.name}: kernel returned {len(outs)} output streams "
                f"for {len(self.ports)} declared consumers")
        for port, arr in zip(self.ports, outs):
            if arr is None or len(arr) == 0:
                continue
            for j, part in port.route.split(arr):
                self._deliver(port, j, part, t0)

    def _deliver(self, port: _OutPort, j: int, part: np.ndarray,
                 t0: float) -> None:
        if not self.jumbo:
            for row in part:             # per-tuple insertion (Fig. 16)
                self._put(port, j, np.asarray([row]), t0)
            return
        for jumbo, jt0 in port.buffers[j].add(part, t0):
            self._put(port, j, jumbo, jt0)

    def _put(self, port: _OutPort, j: int, arr: np.ndarray,
             t0: float) -> None:
        q = port.queues[j]
        if self.is_spout:                # interruptible put: stop wins
            while True:
                try:
                    q.put((arr, t0), timeout=0.02)
                    break
                except queue.Full:
                    if self.stop_event.is_set():
                        return           # dropped, never counted
        else:                            # task: block (backpressure)
            q.put((arr, t0))
        port.delivered[j] += len(arr)

    def _shutdown(self):
        self._drain()
        self._poison()

    def _drain(self):
        # flush partially-filled jumbo lanes
        for port in self.ports:
            for j, buf in enumerate(port.buffers):
                out = buf.drain()
                if out is not None:
                    self._put(port, j, *out)

    def _poison(self):
        # once per consumer queue per producer replica
        for port in self.ports:
            for q in port.queues:
                q.put(_POISON)


def run_app(app: StreamingApp, parallelism: Optional[Dict[str, int]] = None,
            batch: int = 256, duration: float = 1.0, jumbo: bool = True,
            queue_cap: int = 32, partition: Optional[Dict[str, str]] = None,
            seed: int = 0, vectorized: bool = True,
            max_batches: Optional[int] = None,
            initial_states: Optional[Dict[str, List[dict]]] = None
            ) -> RuntimeResult:
    """Execute ``app`` for ``duration`` seconds and return measured stats.

    Partition strategies and key extractors come from the app's Topology
    declaration, compiled once into routes (:mod:`repro.streaming.routing`);
    the ``partition`` argument overrides per operator.  ``vectorized=False``
    selects the seed's per-mask keyed split (kept for the
    ``bench_runtime.py`` A/B comparison only).

    Declared operator state (``Topology.op(state=StateSpec(...))``) becomes
    managed stores on the replica state handles: keyed stores are sharded
    exactly like the compiled keyed route, so the union of the replica
    stores equals a single-replica run's store.

    ``max_batches`` switches to *deterministic replay*: every spout emits
    exactly that many batches (seeds ``seed .. seed+max_batches-1``) and the
    run drains fully — no drops, no duration cutoff — which makes keyed
    state byte-reproducible across replica counts.  ``initial_states`` seeds
    per-replica state (one entry per replica, e.g. from
    :func:`repro.streaming.state.migrate_states` after a replan).
    """
    lg = app.graph
    parallelism = dict(parallelism or {})
    validate_operator_names(lg, parallelism, "parallelism")
    for name in lg.operators:
        parallelism.setdefault(name, 1)
    routes = compile_routes(app, partition=partition)

    # one input queue per non-spout replica
    in_qs: Dict[Tuple[str, int], queue.Queue] = {}
    for name in lg.operators:
        if not lg.operators[name].is_spout:
            for i in range(parallelism[name]):
                in_qs[(name, i)] = queue.Queue(maxsize=queue_cap)

    states: Dict[str, List[OperatorState]] = {
        name: [make_operator_state(app.state.get(name), parallelism[name], j)
               for j in range(parallelism[name])]
        for name in lg.operators}
    if initial_states:
        validate_operator_names(lg, initial_states, "initial_states")
        for name, reps in initial_states.items():
            if len(reps) != parallelism[name]:
                raise ValueError(
                    f"initial_states[{name!r}] has {len(reps)} replica "
                    f"states for parallelism {parallelism[name]} "
                    "(migrate_states targets one replica set)")
            states[name] = list(reps)
    latencies: List[float] = []
    stop = threading.Event()
    spout_counts = [0]
    count_lock = threading.Lock()

    def add_spout_count(n: int) -> None:
        with count_lock:
            spout_counts[0] += n

    def make_ports(name: str) -> List[_OutPort]:
        return [
            _OutPort(routes.route(name, cop).bind(parallelism[cop],
                                                  vectorized=vectorized),
                     [in_qs[(cop, j)] for j in range(parallelism[cop])],
                     batch)
            for cop in lg.consumers(name)]

    spouts: List[Executor] = []
    tasks: List[Executor] = []
    for name, spec in lg.operators.items():
        is_sink = not lg.consumers(name)
        n_producer_units = sum(parallelism[p] for p in lg.producers(name))
        for i in range(parallelism[name]):
            if spec.is_spout:
                spouts.append(Executor(
                    f"{name}#{i}", make_ports(name), batch, jumbo,
                    states[name][i], source=app.source_for(name), stop=stop,
                    seed=seed + 7919 * i, on_delivered=add_spout_count,
                    max_batches=max_batches))
            else:
                tasks.append(Executor(
                    f"{name}#{i}", make_ports(name), batch, jumbo,
                    states[name][i], kernel=app.kernels[name],
                    in_q=in_qs[(name, i)],
                    expected_poisons=max(n_producer_units, 1),
                    lat_sink=latencies if is_sink else None))

    for t in tasks:
        t.start()
    t_start = time.perf_counter()
    for th in spouts:
        th.start()
    if max_batches is None:
        time.sleep(duration)
        stop.set()
        join_timeout = 5.0
    else:
        # deterministic replay: spouts finish their batch budget on their
        # own (backpressure, no drops); stop only guards a crashed consumer
        join_timeout = 60.0
    for th in spouts:
        th.join(timeout=join_timeout)
    stop.set()
    for t in tasks:
        t.join(timeout=join_timeout)
    wall = time.perf_counter() - t_start

    sink_ops = lg.sinks()
    sink_tuples = sum(st.get("seen", 0)
                      for op in sink_ops for st in states[op])
    lat = np.array(latencies) if latencies else np.array([0.0])
    return RuntimeResult(
        duration=wall, sink_tuples=int(sink_tuples),
        spout_tuples=int(spout_counts[0]),
        throughput=sink_tuples / max(wall, 1e-9),
        latency_p50=float(np.percentile(lat, 50)),
        latency_p99=float(np.percentile(lat, 99)),
        states=states)
