"""Real threaded mini-runtime (paper §5 / Appendix A, shared-memory design).

Executes a :class:`StreamingApp` for real on the host CPU: every replica is a
thread (task = executor + partition controller), tuples are numpy batches
passed *by reference* through bounded queues (backpressure via blocking put),
and outputs are accumulated into **jumbo tuples** — one queue insertion per
``batch`` tuples with a single shared header (timestamp), amortising queue
overhead exactly as §5.2 describes.  ``jumbo=False`` degrades to per-tuple
insertion for the Fig. 16 factor analysis.

This runtime validates streaming *semantics* (WC really counts words); the
NUMA placement effects are exercised through the simulator instead (this
container has a single socket — see DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from .apps import StreamingApp

_POISON = object()


@dataclasses.dataclass
class RuntimeResult:
    duration: float
    sink_tuples: int
    spout_tuples: int
    throughput: float               # sink tuples/sec
    latency_p50: float
    latency_p99: float
    states: Dict[str, List[dict]]   # per-operator replica states (counts etc.)


class _Task(threading.Thread):
    """One replica: pulls jumbo tuples, runs the kernel, partitions output."""

    def __init__(self, name, kernel, in_q, outs, batch, jumbo, state,
                 expected_poisons, lat_sink=None):
        super().__init__(daemon=True, name=name)
        self.kernel = kernel
        self.in_q = in_q
        self.outs = outs            # list (per output stream) of lists of
                                    # (queue, strategy, index, k)
        self.batch = batch
        self.jumbo = jumbo
        self.state = state
        self.expected_poisons = expected_poisons
        self.lat_sink = lat_sink
        self._buf: Dict[int, List[Tuple[np.ndarray, float]]] = {}
        self._rr: Dict[int, int] = {}       # independent counter per stream

    def _flush(self, stream, consumer_idx, arr, t0):
        q, _, _, _ = self.outs[stream][consumer_idx]
        q.put((arr, t0))

    def _emit(self, stream, arr, t0):
        if arr is None or len(arr) == 0:
            return
        consumers = self.outs[stream]
        if not consumers:
            return
        strategy = consumers[0][1]
        k = len(consumers)
        if strategy == "key":
            keys = (arr if arr.ndim == 1 else arr[:, 0]).astype(np.int64)
            for i in range(k):
                part = arr[keys % k == i]
                if len(part):
                    self._emit_to(stream, i, part, t0)
        else:                        # shuffle: whole jumbo round-robin
            rr = self._rr.get(stream, 0)
            self._emit_to(stream, rr % k, arr, t0)
            self._rr[stream] = rr + 1

    def _emit_to(self, stream, i, arr, t0):
        if not self.jumbo:
            for row in arr:          # per-tuple insertion (no jumbo)
                self._flush(stream, i, np.asarray([row]), t0)
            return
        key = (stream, i)
        buf = self._buf.setdefault(key, [])
        buf.append((arr, t0))
        total = sum(len(a) for a, _ in buf)
        if total >= self.batch:
            merged = np.concatenate([a for a, _ in buf])
            self._flush(stream, i, merged, buf[0][1])
            buf.clear()

    def run(self):
        poisons = 0
        while True:
            item = self.in_q.get()
            if item is _POISON:
                poisons += 1
                if poisons < self.expected_poisons:
                    continue         # wait for every producer replica to end
                # drain buffers, propagate poison once per consumer queue
                for (stream, i), buf in self._buf.items():
                    if buf:
                        merged = np.concatenate([a for a, _ in buf])
                        self._flush(stream, i, merged, buf[0][1])
                self._buf.clear()
                for consumers in self.outs:
                    for q, _, _, _ in consumers:
                        q.put(_POISON)
                return
            arr, t0 = item
            if self.lat_sink is not None:
                self.lat_sink.append(time.perf_counter() - t0)
            out = self.kernel(arr, self.state)
            for stream, oarr in enumerate(out):
                self._emit(stream, oarr, t0)


def run_app(app: StreamingApp, parallelism: Optional[Dict[str, int]] = None,
            batch: int = 256, duration: float = 1.0, jumbo: bool = True,
            queue_cap: int = 32, partition: Optional[Dict[str, str]] = None,
            seed: int = 0) -> RuntimeResult:
    """Execute ``app`` for ``duration`` seconds and return measured stats.

    Partition strategies come from the app's Topology declaration
    (``app.partition``); the ``partition`` argument overrides per operator.
    """
    lg = app.graph
    parallelism = dict(parallelism or {})
    for name in lg.operators:
        parallelism.setdefault(name, 1)
    strategies = dict(getattr(app, "partition", None) or {})
    strategies.update(partition or {})
    partition = strategies
    for op_name, strat in partition.items():
        if strat not in ("shuffle", "key"):
            raise ValueError(f"operator {op_name!r}: unknown partition "
                             f"strategy {strat!r} (choose 'shuffle' or "
                             "'key')")

    # one input queue per non-spout replica
    in_qs: Dict[Tuple[str, int], queue.Queue] = {}
    for name in lg.operators:
        if not lg.operators[name].is_spout:
            for i in range(parallelism[name]):
                in_qs[(name, i)] = queue.Queue(maxsize=queue_cap)

    states: Dict[str, List[dict]] = {
        name: [dict() for _ in range(parallelism[name])]
        for name in lg.operators}
    latencies: List[float] = []

    tasks: List[_Task] = []
    for name, spec in lg.operators.items():
        if spec.is_spout:
            continue
        cons_ops = lg.consumers(name)
        n_producer_units = sum(parallelism[p] for p in lg.producers(name))
        for i in range(parallelism[name]):
            outs = []
            for stream, cop in enumerate(cons_ops):
                strat = partition.get(cop, "shuffle")
                outs.append([(in_qs[(cop, j)], strat, j, parallelism[cop])
                             for j in range(parallelism[cop])])
            is_sink = not cons_ops
            t = _Task(f"{name}#{i}", app.kernels[name], in_qs[(name, i)],
                      outs, batch, jumbo, states[name][i],
                      expected_poisons=max(n_producer_units, 1),
                      lat_sink=latencies if is_sink else None)
            tasks.append(t)

    stop = threading.Event()
    spout_counts = [0]
    count_lock = threading.Lock()
    spout_threads = []
    for name, spec in lg.operators.items():
        if not spec.is_spout:
            continue
        cons_ops = lg.consumers(name)
        for i in range(parallelism[name]):

            def spout_loop(name=name, cons_ops=cons_ops, i=i):
                source = app.source_for(name) if hasattr(app, "source_for") \
                    else app.make_source
                # independent round-robin counter per consumer op: a shared
                # counter advanced once per loop sends every consumer the
                # same index stream, skewing multi-consumer topologies
                # (e.g. Linear Road's dispatcher fan-out)
                rr = {cop: 0 for cop in cons_ops}
                b = 0
                while not stop.is_set():
                    arr = source(batch, seed + 7919 * i + b)
                    b += 1
                    t0 = time.perf_counter()
                    # tuples that entered the dataflow this batch: stop can
                    # interrupt a keyed delivery between key partitions, so
                    # count what was actually enqueued (max over consumers —
                    # fan-out duplicates tuples, it does not multiply them)
                    batch_delivered = 0
                    for cop in cons_ops:
                        k = parallelism[cop]
                        if partition.get(cop, "shuffle") == "key":
                            keys = (arr if arr.ndim == 1 else
                                    arr[:, 0]).astype(np.int64)
                            targets = [(j, arr[keys % k == j])
                                       for j in range(k)]
                            targets = [(j, p) for j, p in targets if len(p)]
                        else:
                            targets = [(rr[cop] % k, arr)]
                            rr[cop] += 1
                        cop_delivered = 0
                        for j, part in targets:
                            q = in_qs[(cop, j)]
                            while not stop.is_set():      # backpressure
                                try:
                                    q.put((part, t0), timeout=0.02)
                                    cop_delivered += len(part)
                                    break
                                except queue.Full:
                                    continue
                        batch_delivered = max(batch_delivered, cop_delivered)
                    if batch_delivered:
                        with count_lock:
                            spout_counts[0] += batch_delivered
                for cop in cons_ops:
                    for j in range(parallelism[cop]):
                        in_qs[(cop, j)].put(_POISON)

            th = threading.Thread(target=spout_loop, daemon=True)
            spout_threads.append(th)

    for t in tasks:
        t.start()
    t_start = time.perf_counter()
    for th in spout_threads:
        th.start()
    time.sleep(duration)
    stop.set()
    for th in spout_threads:
        th.join(timeout=5.0)
    for t in tasks:
        t.join(timeout=5.0)
    wall = time.perf_counter() - t_start

    sink_ops = lg.sinks()
    sink_tuples = sum(st.get("seen", 0)
                      for op in sink_ops for st in states[op])
    lat = np.array(latencies) if latencies else np.array([0.0])
    return RuntimeResult(
        duration=wall, sink_tuples=int(sink_tuples),
        spout_tuples=int(spout_counts[0]),
        throughput=sink_tuples / max(wall, 1e-9),
        latency_p50=float(np.percentile(lat, 50)),
        latency_p99=float(np.percentile(lat, 99)),
        states=states)
