"""Operator fusion: compile 1:1 pipeline segments into single executors.

BriskStream's RLAS prices every producer-consumer pair by relative
location, but the best case — distance zero — still costs a full queue
hop in the runtime: enqueue, fan-in wait, watermark min-merge, arena
lease hand-off.  Following Prasaad et al. (arXiv:1803.11328), a maximal
chain of fusion-eligible edges is collapsed into one ``FusedExecutor``
that calls the member kernels back-to-back on the same batch.

An edge ``u -> v`` is fusion-eligible when all of the following hold:

- ``u`` is not a spout (spout replay offsets stay per-source),
- ``u`` has exactly one consumer and ``v`` exactly one producer,
- the edge is shuffle-routed (keyed and broadcast edges repartition
  or replicate data and must stay queue-crossing),
- neither endpoint is a ``device=True`` operator (v1 keeps the async
  dispatch window at a queue boundary),
- neither endpoint carries an event-time window (pane firing is driven
  by the watermark frontier at a lane boundary; count windows live
  inside kernels and fuse fine),
- neither endpoint opted out via ``fuse=False``,
- when a parallelism map is given, both endpoints run the same number
  of replicas (replica ``i`` of the chain fuses end-to-end).

Chains are *maximal* runs of eligible edges.  This module is pure graph
logic: the runtime realization lives in ``runtime.FusedExecutor`` and
the planner pricing in ``fuse_graph`` below, which rewrites a logical
graph + route table so a chain becomes one ``OperatorSpec`` with summed
(selectivity-weighted) service time and zero intra-chain comm cost —
letting RLAS/BnB choose fusion against replication.

Distribution contract: fusing an edge turns its shuffle into replica-
local *forwarding* — chain replica ``i`` is member ``i`` of every stage,
end-to-end.  Any assignment of batches to replicas is a valid shuffle,
so stream contents, global counters and keyed-state bytes are preserved,
but the unfused plan's whole-batch round-robin is not emulated across
executors.  Byte-for-byte parity with the unfused plan therefore holds
when the chain runs one replica (every boundary distribution is the
identity) and at preserved boundaries (the head's inbound route,
including keyed shards, is verbatim); a *replicated* chain is instead
deterministic against itself — same fused plan, same bytes — which is
what checkpoint restore and migration consume.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

from repro.core import LogicalGraph, OperatorSpec

from .routing import RouteSpec, RoutingTable

__all__ = [
    "detect_chains",
    "validate_chains",
    "fuse_graph",
    "fused_name",
    "fuse_parallelism",
    "expand_parallelism",
]


def fused_name(chain: Sequence[str]) -> str:
    """Display/plan name of a fused chain: ``"parser+avg+spike"``."""
    return "+".join(chain)


def _edge_eligible(lg: LogicalGraph, routes: RoutingTable, u: str, v: str,
                   no_fuse: frozenset, time_windows: frozenset,
                   parallelism: Optional[Mapping[str, int]]) -> bool:
    if lg.operators[u].is_spout:
        return False
    if u in no_fuse or v in no_fuse:
        return False
    if u in time_windows or v in time_windows:
        return False
    if len(lg.consumers(u)) != 1 or len(lg.producers(v)) != 1:
        return False
    if routes.strategy(u, v) != "shuffle":
        return False
    if lg.operators[u].device or lg.operators[v].device:
        return False
    if parallelism is not None and \
            parallelism.get(u, 1) != parallelism.get(v, 1):
        return False
    return True


def detect_chains(lg: LogicalGraph, routes: RoutingTable, *,
                  no_fuse: Iterable[str] = (),
                  time_windows: Iterable[str] = (),
                  parallelism: Optional[Mapping[str, int]] = None,
                  ) -> List[List[str]]:
    """Maximal fusion-eligible chains, in topological order of their heads.

    With ``parallelism=None`` the detection is structural (the planner
    assigns one replica count to the whole fused operator, so members
    match by construction); with a map, mismatched edges break chains.
    """
    no_fuse = frozenset(no_fuse)
    time_windows = frozenset(time_windows)
    nxt: Dict[str, str] = {}
    prv: Dict[str, str] = {}
    for u, v in lg.edges:
        if _edge_eligible(lg, routes, u, v, no_fuse, time_windows,
                          parallelism):
            # eligible edges have unique endpoints on both sides, so
            # nxt/prv are functions, never multimaps
            nxt[u] = v
            prv[v] = u
    chains: List[List[str]] = []
    for u in lg.topo_order():
        if u in nxt and u not in prv:
            chain = [u]
            while chain[-1] in nxt:
                chain.append(nxt[chain[-1]])
            chains.append(chain)
    return chains


def validate_chains(lg: LogicalGraph, routes: RoutingTable,
                    chains: Iterable[Sequence[str]], *,
                    no_fuse: Iterable[str] = (),
                    time_windows: Iterable[str] = (),
                    ) -> List[List[str]]:
    """Check explicitly requested chains against the eligibility rules.

    Raises ``ValueError`` on any structural violation (unknown member,
    short chain, overlapping chains, keyed/broadcast/device/windowed or
    fan-crossing edge).  Parallelism is *not* checked here: a chain that
    is structurally sound but realized with mismatched replica counts is
    silently dropped at prepare time — fusion is an optimization, and a
    plan-derived chain may be invalidated by elastic rescaling.
    """
    no_fuse = frozenset(no_fuse)
    time_windows = frozenset(time_windows)
    out: List[List[str]] = []
    seen: set = set()
    for chain in chains:
        chain = list(chain)
        if len(chain) < 2:
            raise ValueError(f"fusion chain {chain!r} needs >= 2 operators")
        for m in chain:
            if m not in lg.operators:
                raise ValueError(f"fusion chain member {m!r} is not an "
                                 "operator of this graph")
            if m in seen:
                raise ValueError(f"operator {m!r} appears in more than one "
                                 "fusion chain")
            seen.add(m)
        for u, v in zip(chain, chain[1:]):
            if v not in lg.consumers(u):
                raise ValueError(f"fusion chain edge {u!r} -> {v!r} is not "
                                 "an edge of this graph")
            if not _edge_eligible(lg, routes, u, v, no_fuse, time_windows,
                                  None):
                raise ValueError(
                    f"edge {u!r} -> {v!r} is not fusion-eligible (needs "
                    "shuffle routing, fan-in 1 / fan-out 1, no device or "
                    "event-time window endpoint, no fuse=False opt-out)")
        out.append(chain)
    return out


def _prefix_products(lg: LogicalGraph, chain: Sequence[str]) -> List[float]:
    """``P[j]`` = expected tuples reaching member ``j`` per head-input tuple."""
    prods = [1.0]
    for u, v in zip(chain, chain[1:]):
        prods.append(prods[-1] * lg.sel(u, v))
    return prods


def fuse_graph(lg: LogicalGraph, routes: RoutingTable,
               chains: Sequence[Sequence[str]],
               ) -> Tuple[LogicalGraph, RoutingTable]:
    """Rewrite ``(lg, routes)`` so each chain is one logical operator.

    The fused spec prices what one replica actually executes: service
    time is the selectivity-weighted sum of member service times (a
    tuple that dies at member ``j`` never costs ``j+1``'s kernel), the
    intra-chain edges vanish (zero comm cost — the collocation limit
    RLAS prices made exact), and the fused selectivity composes the
    members' so downstream rates are unchanged.  Inbound routing of the
    head (including keyed/broadcast strategies) and outbound routing of
    the tail are preserved verbatim.
    """
    fused_of: Dict[str, str] = {}
    tail_scale: Dict[str, float] = {}
    specs: Dict[str, OperatorSpec] = {}
    for chain in chains:
        fname = fused_name(chain)
        prods = _prefix_products(lg, chain)
        exec_ns = mem = state_b = device_ns = 0.0
        resident = 0.0
        resident_shared = True
        for m, p in zip(chain, prods):
            spec = lg.operators[m]
            exec_ns += p * spec.exec_ns
            mem += p * spec.mem_bytes
            state_b += p * spec.state_bytes
            device_ns += p * spec.device_ns
            resident += spec.state_resident_tuples
            if spec.state_resident_tuples > 0:
                resident_shared = resident_shared and spec.state_resident_shared
        head_spec = lg.operators[chain[0]]
        tail_spec = lg.operators[chain[-1]]
        specs[fname] = OperatorSpec(
            name=fname,
            exec_ns=exec_ns,
            tuple_bytes=head_spec.tuple_bytes,
            mem_bytes=mem,
            selectivity=prods[-1] * tail_spec.selectivity,
            state_bytes=state_b,
            state_resident_tuples=resident,
            state_resident_shared=resident_shared,
        )
        tail_scale[chain[-1]] = prods[-1]
        for m in chain:
            fused_of[m] = fname

    operators: Dict[str, OperatorSpec] = {}
    for name, spec in lg.operators.items():
        fname = fused_of.get(name)
        if fname is None:
            operators[name] = spec
        elif fname not in operators:
            operators[fname] = specs[fname]

    edges: List[Tuple[str, str]] = []
    edge_sel: Dict[Tuple[str, str], float] = {}
    orig_edge: Dict[Tuple[str, str], Tuple[str, str]] = {}
    for u, v in lg.edges:
        mu = fused_of.get(u, u)
        mv = fused_of.get(v, v)
        if mu == mv:
            continue                     # intra-chain edge: fused away
        edges.append((mu, mv))
        orig_edge[(mu, mv)] = (u, v)
        if u in tail_scale:
            # per-input-tuple rate out of the fused op = rate at the
            # tail times the tail's own per-edge selectivity
            edge_sel[(mu, mv)] = tail_scale[u] * lg.sel(u, v)
        elif (u, v) in lg.edge_selectivity:
            edge_sel[(mu, mv)] = lg.edge_selectivity[(u, v)]

    fused_lg = LogicalGraph(operators, edges, edge_sel)

    new_routes: Dict[Tuple[str, str], RouteSpec] = {}
    for mu in fused_lg.operators:
        for stream, mv in enumerate(fused_lg.consumers(mu)):
            u, v = orig_edge[(mu, mv)]
            old = routes.route(u, v)
            new_routes[(mu, mv)] = dataclasses.replace(
                old, producer=mu, consumer=mv, stream=stream,
                selectivity=edge_sel.get((mu, mv), fused_lg.sel(mu, mv)))
    return fused_lg, RoutingTable(fused_lg, new_routes)


def fuse_parallelism(par: Mapping[str, int],
                     chains: Sequence[Sequence[str]]) -> Dict[str, int]:
    """Collapse a member-keyed parallelism map onto fused names."""
    member = {m: fused_name(c) for c in chains for m in c}
    out: Dict[str, int] = {}
    for op, k in par.items():
        out[member.get(op, op)] = int(k)
    return out


def expand_parallelism(par: Mapping[str, int],
                       chains: Sequence[Sequence[str]]) -> Dict[str, int]:
    """Expand a fused-keyed parallelism map back to member names."""
    by_name = {fused_name(c): c for c in chains}
    out: Dict[str, int] = {}
    for op, k in par.items():
        for m in by_name.get(op, [op]):
            out[m] = int(k)
    return out
