"""One routing substrate: compiled per-edge routes shared by every layer.

The paper's throughput story (§5.2 jumbo tuples, §3.1 rate model) only holds
if the *same* edge semantics — partition strategy, key extraction, per-stream
selectivity, consumer fan-out — are what the planner models, what the DES
measures and what the threaded runtime executes.  This module is that single
source of truth:

* :class:`RouteSpec` — one logical stream (producer -> consumer) compiled
  from the Topology declaration: strategy (``shuffle`` / ``key`` /
  ``broadcast``), declared key extractor, per-stream selectivity.
* :class:`Route` — a spec bound to a concrete consumer fan-out.  Its
  ``split`` is the only place tuple->replica assignment happens at runtime;
  key partitioning is vectorized (one ``argsort`` + ``bincount`` instead of
  ``k`` boolean masks per batch).
* :class:`RoutingTable` — all routes of one logical graph, compiled once by
  :func:`compile_routes`.  ``repro.core.ExecutionGraph`` derives its edge
  weights from it (the planner side), :func:`unit_delivery` derives the DES
  delivery tables from it (the simulator side), and the runtime binds its
  per-replica :class:`Route` objects from it (the execution side).

Keeping all three consumers on these tables closes the drift the ROADMAP
flagged (non-first-stream ``edge_selectivity`` silently ignored by routing)
and makes every later routing feature a one-place change.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Mapping, Optional, Tuple, Union

import numpy as np

PARTITION_STRATEGIES = ("shuffle", "key", "broadcast")

#: a key extractor: a column index into 2-D batches, or ``f(batch) -> keys``
KeyBy = Union[int, Callable[[np.ndarray], np.ndarray]]


#: a consumer's declared partitioning: one strategy for every input stream,
#: or a per-producer mapping (e.g. FD's predictor reads a shuffled feature
#: stream AND a broadcast model-sync stream)
PartitionDecl = Union[str, Mapping[str, str]]


def validate_strategy(op: str, strategy: str) -> None:
    if strategy not in PARTITION_STRATEGIES:
        raise ValueError(
            f"operator {op!r}: unknown partition strategy {strategy!r} "
            f"(choose from {PARTITION_STRATEGIES})")


def validate_partition_decl(op: str, decl: PartitionDecl) -> None:
    """A partition declaration is one strategy or a per-producer mapping."""
    if isinstance(decl, str):
        validate_strategy(op, decl)
        return
    if not isinstance(decl, Mapping):
        raise ValueError(
            f"operator {op!r}: partition must be a strategy or a "
            f"{{producer: strategy}} mapping, got {type(decl).__name__}")
    for producer, strategy in decl.items():
        validate_strategy(op, strategy)


def edge_strategy(strategies: Mapping[str, PartitionDecl], producer: str,
                  consumer: str) -> str:
    """Resolve the strategy of one edge from the consumer declarations
    (per-producer mappings default unnamed producers to shuffle)."""
    decl = strategies.get(consumer, "shuffle")
    if isinstance(decl, Mapping):
        return decl.get(producer, "shuffle")
    return decl


def declares_key(decl: PartitionDecl) -> bool:
    """True when a partition declaration keys at least one input stream."""
    if isinstance(decl, Mapping):
        return "key" in decl.values()
    return decl == "key"


def validate_operator_names(graph, names, what: str) -> None:
    """Reject references to operators the graph does not declare (one rule
    for every per-operator mapping: parallelism, partition, key_by)."""
    unknown = sorted(set(names) - set(graph.operators))
    if unknown:
        raise ValueError(
            f"{what} names unknown operators {unknown} "
            f"(declared: {sorted(graph.operators)})")


def validate_key_extractor(op: str, key_by: KeyBy) -> None:
    """A key extractor is a column index or a callable (bools are not
    column indices)."""
    if callable(key_by):
        return
    if isinstance(key_by, bool) or not isinstance(key_by, (int, np.integer)):
        raise ValueError(
            f"operator {op!r}: key_by must be a column index or a "
            f"callable, got {type(key_by).__name__}")


def extract_keys(arr: np.ndarray, key_by: Optional[KeyBy]) -> np.ndarray:
    """Integer keys for ``arr`` under a declared extractor.

    ``None`` keeps the historical convention: the tuple itself for 1-D
    batches, column 0 for 2-D batches.
    """
    if callable(key_by):
        keys = np.asarray(key_by(arr))
        if keys.shape[:1] != arr.shape[:1]:
            raise ValueError(
                f"key extractor returned {keys.shape} keys for a batch of "
                f"{len(arr)} tuples")
        return keys.astype(np.int64, copy=False)
    col = 0 if key_by is None else int(key_by)
    if arr.ndim == 1:
        if col != 0:
            raise ValueError(
                f"key_by column {col} requested on a 1-D batch")
        return arr.astype(np.int64, copy=False)
    return arr[:, col].astype(np.int64, copy=False)


def validate_time_extractor(op: str, event_time) -> None:
    """An event-time extractor is a column index or a callable (same shape
    rule as key extractors, distinct message)."""
    if callable(event_time):
        return
    if isinstance(event_time, bool) or \
            not isinstance(event_time, (int, np.integer)):
        raise ValueError(
            f"operator {op!r}: event_time must be a column index or a "
            f"callable, got {type(event_time).__name__}")


def extract_event_times(arr: np.ndarray, time_by) -> np.ndarray:
    """Float event times for ``arr`` under a declared extractor.

    ``None`` mirrors :func:`extract_keys`: the tuple itself for 1-D
    batches, column 0 for 2-D batches.
    """
    if callable(time_by):
        ets = np.asarray(time_by(arr), dtype=np.float64)
        if ets.shape != arr.shape[:1]:
            raise ValueError(
                f"event-time extractor returned {ets.shape} times for a "
                f"batch of {len(arr)} tuples")
        return ets
    col = 0 if time_by is None else int(time_by)
    if arr.ndim == 1:
        if col != 0:
            raise ValueError(
                f"event_time column {col} requested on a 1-D batch")
        return arr.astype(np.float64, copy=False)
    return arr[:, col].astype(np.float64, copy=False)


class WatermarkMerger:
    """Min-merge of per-lane low-watermarks, monotone per lane.

    One lane per producer execution unit.  A lane's watermark never
    regresses (stale values are ignored), and the merged watermark is the
    minimum over *all* expected lanes — ``-inf`` until every lane has
    reported, because an unheard-from producer may still hold arbitrarily
    old tuples.  Min-merge is associative and commutative, so replica
    fan-in can be merged in any grouping (the property test pins this
    down); that is what lets watermarks ride the same compiled routes as
    data with no ordering coordination across lanes.
    """

    __slots__ = ("expected", "_lanes")

    def __init__(self, expected: int):
        self.expected = expected
        self._lanes: Dict[str, float] = {}

    def update(self, lane: str, value: float) -> float:
        """Advance ``lane`` to ``value`` (monotone) and return the merged
        watermark."""
        if value > self._lanes.get(lane, -math.inf):
            self._lanes[lane] = value
        return self.merged

    @property
    def merged(self) -> float:
        if len(self._lanes) < self.expected:
            return -math.inf
        return min(self._lanes.values())

    def lane(self, name: str) -> float:
        return self._lanes.get(name, -math.inf)


class BarrierAligner:
    """Per-consumer checkpoint-barrier alignment (the Chandy-Lamport cut).

    The watermark idiom, one notch stricter: a checkpoint barrier is a
    second kind of mark that rides every route a watermark rides, but
    where watermarks *min-merge* (a stale lane just holds the merged value
    back), barriers must **align** — the consumer snapshots its state only
    once barrier *n* has arrived on *every* producer lane, and everything
    a fast lane sends after its barrier is held back until then (otherwise
    post-barrier effects leak into the snapshot and replay double-applies
    them).  One aligner per executor, ``expected`` producer lanes, exactly
    like the poison count.

    Rounds are strictly sequential by construction: a lane that has
    delivered barrier ``n`` is *holding* — the executor queues that lane's
    subsequent items (data, watermarks, even barrier ``n+1``) instead of
    processing them — so a barrier for a different round while one is
    active is a protocol violation, not a case to handle.
    """

    __slots__ = ("expected", "active", "_arrived")

    def __init__(self, expected: int):
        self.expected = expected
        self.active: Optional[int] = None     # ckpt id being aligned
        self._arrived: set = set()

    def arrive(self, lane: str, ckpt_id: int) -> bool:
        """Record barrier ``ckpt_id`` from ``lane``; True when this
        completes the round (all expected lanes aligned)."""
        if self.active is None:
            self.active = ckpt_id
            self._arrived = set()
        elif ckpt_id != self.active:
            raise RuntimeError(
                f"barrier {ckpt_id} from lane {lane!r} while round "
                f"{self.active} is still aligning")
        self._arrived.add(lane)
        if len(self._arrived) >= self.expected:
            self.active = None
            self._arrived = set()
            return True
        return False

    def holding(self, lane: str) -> bool:
        """True while ``lane`` has aligned the active round and its
        subsequent items must be held back."""
        return self.active is not None and lane in self._arrived

    def reset(self) -> None:
        """Abandon the active round (end of stream reached before every
        lane's barrier arrived — the round can never complete)."""
        self.active = None
        self._arrived = set()


#: calibrated crossover for the keyed-split implementation, refit from a
#: dense best-of-3 micro grid (rows in {128..10240} x k in {2,4,8},
#: us/call): the per-mask path is k linear scans and stays cache-friendly
#: while k is small; the radix argsort+gather is one O(n) pass whose setup
#: amortizes quickly as fan-out grows.  The measured crossover falls much
#: faster in k than the previous ``rows * k**2`` fit assumed (k=2 flips
#: near 5120 rows, k=4 by 256 rows, k=8 always prefers vectorized — the
#: old rule misclassified the small-row k>=4 points, where vectorized
#: wins 1.1-1.6x): ``rows * k**3 > 8192`` leaves at most two near-tie
#: misses on the fresh 21-point grid ((128, 4) and (2560, 2), both within
#: 4% of best), versus 11-12% regret at the k=4 mid-rows under any larger
#: threshold.  Boundary points are within run-to-run noise either way;
#: the threshold's job is the clear regions, where forcing the wrong path
#: costs 1.5-3x per split.
VEC_CROSSOVER = 8192


def auto_vectorized(rows: int, k: int) -> bool:
    """Per-call implementation choice for a keyed split: True selects the
    vectorized argsort+bincount path, False the per-mask scans.  Batch
    size is stable per edge, so this is effectively a per-edge decision —
    made from the calibrated :data:`VEC_CROSSOVER` threshold instead of a
    global flag (``vectorized=`` on ``run_app``/``Plan.execute`` remains
    the override)."""
    return rows * k * k * k > VEC_CROSSOVER


def split_by_key(arr: np.ndarray, keys: np.ndarray,
                 k: int) -> List[Tuple[int, np.ndarray]]:
    """Vectorized keyed split: one stable argsort + bincount per batch
    instead of ``k`` boolean masks (k full-array scans + gathers).

    The residues fit in uint8 for any realistic fan-out, where numpy's
    stable argsort is a single-pass radix sort — O(n) rather than the
    per-mask path's O(n*k).  The stable order preserves arrival order
    within each partition, so the result is row-for-row identical to the
    per-mask path.  Returns ``(replica, rows)`` for non-empty partitions;
    the rows are views into one gathered array (no per-partition copies).
    """
    keys = keys % k
    if k <= 256:
        keys = keys.astype(np.uint8)
    counts = np.bincount(keys, minlength=k)
    gathered = arr[np.argsort(keys, kind="stable")]
    ends = np.cumsum(counts)
    return [(j, gathered[ends[j] - counts[j]:ends[j]])
            for j in range(k) if counts[j]]


def split_by_key_masks(arr: np.ndarray, keys: np.ndarray,
                       k: int) -> List[Tuple[int, np.ndarray]]:
    """The seed runtime's per-mask path (k boolean scans per batch).

    Kept only as the baseline for ``benchmarks/bench_runtime.py`` and the
    parity tests; the runtime uses :func:`split_by_key`.
    """
    keys = keys % k
    out = []
    for j in range(k):
        part = arr[keys == j]
        if len(part):
            out.append((j, part))
    return out


@dataclasses.dataclass(frozen=True)
class RouteSpec:
    """One logical stream, compiled from the Topology declaration.

    ``stream`` is the producer's output-stream index (consumer declaration
    order — the position of this edge's array in the kernel's return list).
    ``selectivity`` is the declared per-stream selectivity (the producer's
    default or the consumer's per-edge override, paper Table 8).
    """

    producer: str
    consumer: str
    stream: int
    strategy: str = "shuffle"
    selectivity: float = 1.0
    key_by: Optional[KeyBy] = None

    def keys(self, arr: np.ndarray) -> np.ndarray:
        return extract_keys(arr, self.key_by)

    def unit_weight(self, group: int, fanout: int) -> float:
        """Tuples arriving at one consumer unit of ``group`` fused replicas
        (``fanout`` replicas total) per tuple processed by a producer unit —
        the replica-level edge weight of the §3.1 rate model."""
        if self.strategy == "broadcast":
            return self.selectivity * group
        return self.selectivity * group / fanout

    def bind(self, fanout: int,
             vectorized: Optional[bool] = None) -> "Route":
        return Route(self, fanout, vectorized)


class Route:
    """A :class:`RouteSpec` bound to a concrete consumer fan-out.

    Owns the per-producer-replica round-robin cursor, so every executor
    binds its own instance.  ``vectorized`` selects the keyed-split
    implementation: ``None`` (default) picks per edge from the calibrated
    :func:`auto_vectorized` threshold, ``True``/``False`` force the
    argsort+bincount path / the seed's per-mask path (the benchmark A/B
    override).
    """

    __slots__ = ("spec", "fanout", "vectorized", "_rr")

    def __init__(self, spec: RouteSpec, fanout: int,
                 vectorized: Optional[bool] = None):
        assert fanout >= 1
        self.spec = spec
        self.fanout = fanout
        self.vectorized = vectorized
        self._rr = 0

    @property
    def is_broadcast(self) -> bool:
        """True when every consumer replica receives every tuple — the
        fan-out shape where the runtime shares **one** jumbo flush across
        all lanes (one refcounted buffer view enqueued ``fanout`` times)
        instead of accumulating a private per-lane copy.  Lanes of a
        broadcast route fill in lockstep by definition, which is what makes
        a single shared accumulation buffer correct."""
        return self.spec.strategy == "broadcast"

    def aliases_input(self) -> bool:
        """True when :meth:`split` may return arrays sharing memory with
        its input (shuffle passes the whole batch through; broadcast hands
        the same array to every lane).  Keyed splits always materialize new
        arrays (argsort+gather or boolean masks), so their parts never
        alias — the emit path uses this to skip the overlap check that
        guards pooled-buffer recycling."""
        return self.fanout == 1 or self.spec.strategy != "key"

    def split(self, arr: np.ndarray) -> List[Tuple[int, np.ndarray]]:
        """Assign a batch to consumer replicas: ``[(replica, rows), ...]``."""
        k = self.fanout
        if k == 1:
            return [(0, arr)]
        strategy = self.spec.strategy
        if strategy == "key":
            keys = self.spec.keys(arr)
            use_vec = auto_vectorized(len(arr), k) \
                if self.vectorized is None else self.vectorized
            if use_vec:
                return split_by_key(arr, keys, k)
            return split_by_key_masks(arr, keys, k)
        if strategy == "broadcast":
            return [(j, arr) for j in range(k)]
        j = self._rr % k                 # shuffle: whole batch round-robin
        self._rr += 1
        return [(j, arr)]

    def watermark_lanes(self) -> range:
        """Lanes a low-watermark is forwarded on: *every* consumer replica,
        regardless of the data strategy — a watermark is a promise about
        the whole stream, so each replica needs it even when the data split
        sends it only a subset of tuples."""
        return range(self.fanout)

    def tuples_entered(self, lane_counts) -> int:
        """Distinct tuples that entered this stream, given per-replica
        delivered counts: broadcast duplicates a tuple onto every lane
        (count it once), partitioning strategies split it (sum lanes)."""
        if self.spec.strategy == "broadcast":
            return max(lane_counts, default=0)
        return sum(lane_counts)

    def __repr__(self) -> str:
        return (f"Route({self.spec.producer}->{self.spec.consumer} "
                f"{self.spec.strategy} sel={self.spec.selectivity} "
                f"k={self.fanout})")


class RoutingTable:
    """All compiled routes of one logical graph (one entry per edge)."""

    def __init__(self, graph, routes: Dict[Tuple[str, str], RouteSpec]):
        self.graph = graph
        self._routes = dict(routes)
        self._out: Dict[str, List[RouteSpec]] = {}
        for (u, _), spec in sorted(self._routes.items(),
                                   key=lambda kv: kv[1].stream):
            self._out.setdefault(u, []).append(spec)

    def route(self, producer: str, consumer: str) -> RouteSpec:
        return self._routes[(producer, consumer)]

    def out_routes(self, producer: str) -> List[RouteSpec]:
        """Routes leaving ``producer`` in output-stream order (the order of
        the kernel's return list)."""
        return self._out.get(producer, [])

    def sel(self, producer: str, consumer: str) -> float:
        return self._routes[(producer, consumer)].selectivity

    def strategy(self, producer: str, consumer: str) -> str:
        return self._routes[(producer, consumer)].strategy

    def key_extractor(self, consumer: str) -> Optional[KeyBy]:
        """The declared key extractor of ``consumer``'s keyed input routes
        (one declaration per consumer, so every keyed edge agrees).  This
        is what keyed pane groups shard by — the same extractor the router
        splits on, so a key's panes live exactly where its tuples land."""
        for (_, v), spec in self._routes.items():
            if v == consumer and spec.strategy == "key":
                return spec.key_by
        return None

    def unit_weight(self, producer: str, consumer: str, group: int,
                    fanout: int) -> float:
        return self._routes[(producer, consumer)].unit_weight(group, fanout)

    def items(self):
        return self._routes.items()

    def __len__(self) -> int:
        return len(self._routes)

    def __contains__(self, edge) -> bool:
        return edge in self._routes


def compile_routes(source, partition: Optional[Mapping[str,
                                                       PartitionDecl]] = None,
                   key_by: Optional[Mapping[str, KeyBy]] = None
                   ) -> RoutingTable:
    """Compile the routing table for an app or logical graph.

    ``source`` is a ``StreamingApp`` (whose declared ``partition`` /
    ``key_by`` travel with it) or a bare ``LogicalGraph``.  The ``partition``
    and ``key_by`` arguments override per *consumer* operator (that is how
    ``run_app(partition=...)`` overrides a declaration); an override
    replaces the consumer's whole declaration, including a per-producer
    mapping.
    """
    graph = getattr(source, "graph", source)
    strategies: Dict[str, PartitionDecl] = \
        dict(getattr(source, "partition", None) or {})
    strategies.update(partition or {})
    extractors = dict(getattr(source, "key_by", None) or {})
    validate_operator_names(graph, strategies, "partition")
    for op, decl in strategies.items():
        validate_partition_decl(op, decl)
        if isinstance(decl, Mapping):
            producers = set(graph.producers(op))
            unknown = sorted(set(decl) - producers)
            if unknown:
                raise ValueError(
                    f"operator {op!r}: partition mapping names {unknown}, "
                    f"which are not producers of {op!r} "
                    f"(producers: {sorted(producers)})")
    # a partition override away from "key" disables the *declared* extractor
    # (so run_app(partition={'op': 'shuffle'}) A/Bs keyed-by apps cleanly);
    # an extractor passed explicitly alongside a non-key strategy is a
    # caller error and is rejected below
    for op in [o for o, kb in extractors.items()
               if not declares_key(strategies.get(o, "shuffle"))]:
        del extractors[op]
    extractors.update(key_by or {})
    validate_operator_names(graph, extractors, "key_by")
    for op, kb in extractors.items():
        if not declares_key(strategies.get(op, "shuffle")):
            raise ValueError(
                f"operator {op!r} declares key_by but its partition "
                f"strategy is {strategies.get(op, 'shuffle')!r} (key "
                "extractors require partition='key')")
        validate_key_extractor(op, kb)
    routes: Dict[Tuple[str, str], RouteSpec] = {}
    for u in graph.operators:
        for stream, v in enumerate(graph.consumers(u)):
            strategy = edge_strategy(strategies, u, v)
            routes[(u, v)] = RouteSpec(
                producer=u, consumer=v, stream=stream,
                strategy=strategy,
                selectivity=graph.sel(u, v),
                key_by=extractors.get(v) if strategy == "key" else None)
    return RoutingTable(graph, routes)


def unit_delivery(graph, routes: Optional[RoutingTable] = None
                  ) -> Dict[int, List[Tuple[int, float]]]:
    """Replica-level delivery table for the DES, derived from the routes.

    ``table[u] = [(v, w), ...]``: a producer unit ``u`` hands ``w`` tuples to
    consumer unit ``v`` per tuple it processes — selectivity x strategy x
    fan-out, the same quantities ``ExecutionGraph`` feeds the rate model.
    """
    if routes is None:
        routes = getattr(graph, "routes", None) or \
            compile_routes(graph.logical)
    table: Dict[int, List[Tuple[int, float]]] = {
        u: [] for u in range(graph.n_units)}
    for (pu, cv), spec in routes.items():
        fanout = graph.parallelism.get(cv, 1)
        for ui in graph.units_of(pu):
            for vi in graph.units_of(cv):
                w = spec.unit_weight(graph.replicas[vi].group, fanout)
                table[ui].append((vi, w))
    return table
