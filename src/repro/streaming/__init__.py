"""Streaming substrate: declarative API, benchmark apps, simulators, runtime.

Preferred entry point::

    from repro.streaming import Job, Topology
    plan = Job(topology).plan(machine, optimizer="rlas")
    plan.estimate(); plan.simulate(); plan.execute()
"""
from .api import (Job, Metrics, Plan, StreamingApp, Topology, TopologyError)
from .routing import (PARTITION_STRATEGIES, Route, RouteSpec, RoutingTable,
                      WatermarkMerger, compile_routes, extract_event_times)
from .state import (BroadcastTable, EventTimeWindowState, KeyedStore,
                    OperatorState, PaneBatch, PaneSegments, StateSpec,
                    UndeclaredStateError, ValueStore, WindowSpec,
                    WindowState, gather_segments, grid_pane_ends,
                    merge_keyed, migrate_states, pane_range,
                    repartition_keyed, segmented)

__all__ = ["Job", "Metrics", "Plan", "StreamingApp", "Topology",
           "TopologyError", "PARTITION_STRATEGIES", "Route", "RouteSpec",
           "RoutingTable", "WatermarkMerger", "compile_routes",
           "extract_event_times",
           "BroadcastTable", "EventTimeWindowState", "KeyedStore",
           "OperatorState", "PaneBatch", "PaneSegments", "StateSpec",
           "UndeclaredStateError", "ValueStore", "WindowSpec",
           "WindowState", "gather_segments", "grid_pane_ends",
           "merge_keyed", "migrate_states", "pane_range",
           "repartition_keyed", "segmented"]
