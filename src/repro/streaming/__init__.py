"""Streaming substrate: declarative API, benchmark apps, simulators, runtime.

Preferred entry point::

    from repro.streaming import Job, Topology
    plan = Job(topology).plan(machine, optimizer="rlas")
    plan.estimate(); plan.simulate(); plan.execute()
"""
from .api import (Job, Metrics, Plan, StreamingApp, Topology, TopologyError)
from .routing import (PARTITION_STRATEGIES, Route, RouteSpec, RoutingTable,
                      compile_routes)
from .state import (BroadcastTable, KeyedStore, OperatorState, StateSpec,
                    ValueStore, WindowSpec, WindowState, merge_keyed,
                    migrate_states, repartition_keyed)

__all__ = ["Job", "Metrics", "Plan", "StreamingApp", "Topology",
           "TopologyError", "PARTITION_STRATEGIES", "Route", "RouteSpec",
           "RoutingTable", "compile_routes",
           "BroadcastTable", "KeyedStore", "OperatorState", "StateSpec",
           "ValueStore", "WindowSpec", "WindowState", "merge_keyed",
           "migrate_states", "repartition_keyed"]
