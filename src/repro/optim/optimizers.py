"""Optimizers (no optax dependency): AdamW and Adafactor, with global-norm
clipping and warmup+cosine schedule.

AdamW keeps f32 moments (2 x 4 bytes/param); Adafactor keeps factored second
moments (~4 bytes/row+col) — the memory-feasible choice for the 235B/398B/
671B cells (see EXPERIMENTS.md §Dry-run memory table).  Both update params
in their storage dtype; moments/statistics are always f32.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., Tuple[Any, Any]]   # (grads, state, params) -> (p, s)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale
                                   ).astype(x.dtype), tree), norm


def warmup_cosine(base_lr: float, warmup: int, total: int,
                  floor: float = 0.1) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * jnp.minimum(1.0, (step + 1) / max(warmup, 1))
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, base_lr * cos)
    return lr


def adamw(lr: Callable | float, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"mu": jax.tree.map(zeros, params),
                "nu": jax.tree.map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = lr_fn(step)
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            upd = (m / c1) / (jnp.sqrt(v / c2) + eps)
            if p.ndim >= 2:                       # no decay on norms/bias
                upd = upd + weight_decay * p.astype(jnp.float32)
            return m, v, (p.astype(jnp.float32) - lr_t * upd).astype(p.dtype)

        flat = jax.tree.map(upd, grads, state["mu"], state["nu"], params)
        mu = jax.tree.map(lambda t: t[0], flat,
                          is_leaf=lambda x: isinstance(x, tuple))
        nu = jax.tree.map(lambda t: t[1], flat,
                          is_leaf=lambda x: isinstance(x, tuple))
        new_p = jax.tree.map(lambda t: t[2], flat,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"mu": mu, "nu": nu, "step": step}

    return Optimizer(init, update)


def adafactor(lr: Callable | float, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0,
              weight_decay: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        def stats(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"stats": jax.tree.map(stats, params,
                                      is_leaf=lambda x: hasattr(x, "ndim")),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = lr_fn(step)
        beta = 1.0 - (step.astype(jnp.float32) + 1.0) ** -decay

        def upd(g, st, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if p.ndim >= 2:
                vr = beta * st["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * st["vc"] + (1 - beta) * g2.mean(axis=-2)
                # Shazeer-Stern factored estimate: V ~= vr vc^T / mean(vr)
                mean_vr = jnp.maximum(vr.mean(axis=-1)[..., None, None], eps)
                vhat = vr[..., :, None] * vc[..., None, :] / mean_vr
                u = g / jnp.sqrt(jnp.maximum(vhat, eps))
                new_st = {"vr": vr, "vc": vc}
            else:
                v = beta * st["v"] + (1 - beta) * g2
                u = g / jnp.sqrt(jnp.maximum(v, eps))
                new_st = {"v": v}
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            p32 = p.astype(jnp.float32)
            if weight_decay and p.ndim >= 2:
                u = u + weight_decay * p32
            return new_st, (p32 - lr_t * u).astype(p.dtype)

        is_stats = lambda x: isinstance(x, dict) and ("vr" in x or "v" in x)
        flat = jax.tree.map(upd, grads, state["stats"], params,
                            is_leaf=lambda x: hasattr(x, "ndim"))
        stats = jax.tree.map(lambda t: t[0], flat,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_p = jax.tree.map(lambda t: t[1], flat,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"stats": stats, "step": step}

    return Optimizer(init, update)


def pick_optimizer(n_params: int, lr) -> Tuple[str, Optimizer]:
    """Memory policy: Adafactor above 20B params (moments would not fit),
    AdamW otherwise."""
    if n_params > 20e9:
        return "adafactor", adafactor(lr)
    return "adamw", adamw(lr)
