"""Gradient compression for the slow (cross-pod DCN) axis.

Int8 quantization with per-bucket scales and stochastic rounding (unbiased:
E[dequant(quant(g))] = g), plus the *jumbo-tuple* analogue for gradients —
bucketing all leaves into one flat buffer so the cross-pod exchange is a
single large transfer instead of hundreds of small ones (paper §5.2: one
queue insertion per jumbo tuple, headers deduplicated).

Exchange pattern (see launch docs): within a pod, gradients reduce over ICI
in bf16; across pods the quantized int8 buffer is all-gathered (s8 on the
wire = 4x less DCN traffic than f32) and summed locally after dequantization.
``shard_map``-based ``cross_pod_allreduce_int8`` expresses this; on a mesh
without a 'pod' axis it degrades to identity.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map as _shard_map


def quantize_int8(x: jax.Array, key: jax.Array,
                  stochastic: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8 quantization. Returns (q, scale)."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    y = x32 / scale
    if stochastic:
        noise = jax.random.uniform(key, x.shape, jnp.float32) - 0.5
        y = y + noise
    q = jnp.clip(jnp.round(y), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array,
                    dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def flatten_bucket(tree: Any) -> Tuple[jax.Array, Any]:
    """Jumbo-tuple bucketing: concat all leaves into one f32 buffer."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    flat = jnp.concatenate([l.astype(jnp.float32).reshape(-1)
                            for l in leaves])
    meta = (treedef, [(l.shape, l.dtype) for l in leaves])
    return flat, meta


def unflatten_bucket(flat: jax.Array, meta) -> Any:
    treedef, shapes = meta
    out = []
    off = 0
    for shape, dtype in shapes:
        n = 1
        for d in shape:
            n *= d
        out.append(flat[off:off + n].reshape(shape).astype(dtype))
        off += n
    return jax.tree_util.tree_unflatten(treedef, out)


def cross_pod_allreduce_int8(grads: Any, mesh, key: jax.Array,
                             pod_axis: str = "pod") -> Any:
    """All-reduce gradients across pods with int8 wire format.

    Protocol per shard_map instance: (1) quantize the local (already
    ICI-reduced) gradient bucket to int8 with a stochastic-rounding scale,
    (2) all_gather the int8 buffer + scales over the pod axis (s8 on the
    DCN), (3) dequantize-and-mean locally."""
    if pod_axis not in mesh.axis_names:
        return grads
    flat, meta = flatten_bucket(grads)
    other_axes = tuple(a for a in mesh.axis_names if a != pod_axis)

    @functools.partial(
        _shard_map, mesh=mesh,
        in_specs=(P(), P()), out_specs=P(),
        check_vma=False)
    def exchange(buf, k):
        q, scale = quantize_int8(buf, k)
        qs = jax.lax.all_gather(q, pod_axis)            # (n_pods, N) int8
        ss = jax.lax.all_gather(scale, pod_axis)        # (n_pods,)
        deq = (qs.astype(jnp.float32) * ss[:, None]).mean(axis=0)
        return deq

    reduced = exchange(flat, key)
    return unflatten_bucket(reduced, meta)
