"""Minimal functional parameter utilities (no flax dependency).

Parameters are nested dicts of jnp arrays.  Layer stacks used under
``lax.scan`` hold *stacked* parameters (leading axis = repeat count), built by
vmapping the single-layer initializer over per-repeat PRNG keys.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def dense_init(key, d_in: int, d_out: int, scale: float = None,
               dtype=jnp.bfloat16) -> jax.Array:
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32)
            * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.bfloat16) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def stack_init(init_fn: Callable[[jax.Array], Params], key, n: int) -> Params:
    """Stack n independent inits along a new leading axis (for lax.scan)."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def param_bytes(params: Params) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))


def param_count(params: Params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def cast_floats(tree: Params, dtype) -> Params:
    def c(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree.map(c, tree)
