"""Top-k mixture-of-experts with sorted capacity dispatch.

Dispatch is gather-based (sort token-copies by expert, slice each expert's
capacity window), NOT one-hot-einsum based: the compiled FLOPs are then
``top_k * capacity_factor`` times the dense-equivalent expert FLOPs — an
honest roofline — instead of the T*E*C dispatch-einsum blow-up.  Under GSPMD
with experts sharded over the ``model`` axis the gathers lower to
all-to-all/all-gather collectives, the analogue of the paper's cross-socket
data shuffle.

Tokens beyond an expert's capacity are dropped (standard capacity-factor
semantics); the router adds a switch-style load-balance auxiliary loss.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import partitioning as part
from .config import ModelConfig
from .module import dense_init
from .layers import mlp_apply, mlp_init


def moe_init(key, cfg: ModelConfig, dtype) -> Dict:
    ks = jax.random.split(key, 4)
    d, e, h = cfg.d_model, cfg.n_experts, cfg.d_expert
    params = {
        "router": dense_init(ks[0], d, e, scale=0.02, dtype=jnp.float32),
        "experts": {
            "gate": dense_init(ks[1], d, e * h, dtype=dtype).reshape(d, e, h)
                    .transpose(1, 0, 2),                        # (E, D, H)
            "up": dense_init(ks[2], d, e * h, dtype=dtype).reshape(d, e, h)
                  .transpose(1, 0, 2),
            "down": dense_init(ks[3], e * h, d,
                               scale=h ** -0.5 / (2 * cfg.n_layers) ** 0.5,
                               dtype=dtype).reshape(e, h, d),
        },
    }
    if cfg.n_shared_experts:
        params["shared"] = mlp_init(
            jax.random.fold_in(key, 7), cfg, dtype,
            d_ff=cfg.n_shared_experts * cfg.d_expert)
    return params


def _dispatch_group(xf, probs, k, e, cap):
    """Sorted capacity dispatch for one token group.

    xf: (Tg, D); probs: (Tg, E).  Returns (xg (E,cap,D), tok (E,cap),
    wgt (E,cap)) with ``tok`` indices local to the group."""
    t = xf.shape[0]
    top_p, top_idx = jax.lax.top_k(probs, k)                    # (Tg, k)
    top_w = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    flat_e = top_idx.reshape(-1)                                # (Tg*k,)
    flat_t = jnp.repeat(jnp.arange(t), k)
    flat_w = top_w.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st_, sw = flat_e[order], flat_t[order], flat_w[order]
    sizes = jnp.bincount(se, length=e)                          # (E,)
    starts = jnp.cumsum(sizes) - sizes
    win = starts[:, None] + jnp.arange(cap)[None]               # (E, cap)
    valid = (jnp.arange(cap)[None] < jnp.minimum(sizes, cap)[:, None])
    win = jnp.clip(win, 0, t * k - 1)
    tok = st_[win]                                              # (E, cap)
    wgt = jnp.where(valid, sw[win], 0.0)
    xg = xf[tok] * valid[..., None].astype(xf.dtype)            # (E, cap, D)
    return xg, tok, wgt


def moe_apply(p, x, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (y, aux_loss).

    ``cfg.moe_dispatch_groups`` > 1 enables *grouped local dispatch*: tokens
    are routed within data-shard-aligned groups, so the dispatch gather moves
    each group's tokens only across the expert (model) axis — all-to-all
    shaped traffic — instead of all-gathering every token to every shard
    (EXPERIMENTS.md §Perf H3).  Capacity is per (expert, group), preserving
    total expert FLOPs."""
    b, s, d = x.shape
    t = b * s
    k = cfg.top_k
    e = cfg.n_experts
    g = max(1, cfg.moe_dispatch_groups)
    assert t % g == 0, (t, g)
    xf = x.reshape(t, d)

    logits = (xf.astype(jnp.float32) @ p["router"])             # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)

    # switch-style load balance loss
    top1 = jnp.argmax(probs, axis=-1)
    frac_tokens = jnp.mean(jax.nn.one_hot(top1, e, dtype=jnp.float32),
                           axis=0)
    frac_probs = probs.mean(axis=0)
    aux = e * jnp.sum(frac_tokens * frac_probs) * cfg.router_aux_weight

    cap = int(max(1, -(-t * k * cfg.capacity_factor // (e * g))))
    xg, tok, wgt = jax.vmap(
        lambda xfg, pg: _dispatch_group(xfg, pg, k, e, cap)
    )(xf.reshape(g, t // g, d), probs.reshape(g, t // g, e))
    # xg: (G, E, cap, D) — groups over the batch axes, experts over 'model':
    # hierarchical EP (without the batch-axes sharding the expert FLOPs
    # inflate by the DP degree — observed 16x on qwen3).
    if g > 1:
        xg = part.constrain(xg, "BATCH", "model", None, None)
        h = jax.nn.silu(jnp.einsum("gecd,edh->gech", xg,
                                   p["experts"]["gate"])) \
            * jnp.einsum("gecd,edh->gech", xg, p["experts"]["up"])
        h = part.constrain(h, "BATCH", "model", None, None)
        out = jnp.einsum("gech,ehd->gecd", h, p["experts"]["down"])
        out = part.constrain(out, "BATCH", "model", None, None)
    else:
        xg1 = part.constrain(xg[0], "model", "BATCH", None)
        h = jax.nn.silu(jnp.einsum("ecd,edh->ech", xg1,
                                   p["experts"]["gate"])) \
            * jnp.einsum("ecd,edh->ech", xg1, p["experts"]["up"])
        h = part.constrain(h, "model", "BATCH", None)
        out = jnp.einsum("ech,ehd->ecd", h, p["experts"]["down"])
        out = part.constrain(out, "model", "BATCH", None)[None]

    # combine: per-group scatter-add back to the group's tokens (token-
    # sharded — unconstrained GSPMD tends to replicate this over the model
    # axis, costing TP-degree x activation memory)
    acc_dt = jnp.bfloat16 if cfg.moe_combine_dtype == "bfloat16" \
        else jnp.float32

    def combine(out_g, tok_g, wgt_g):
        yg = jnp.zeros((t // g, d), acc_dt)
        return yg.at[tok_g.reshape(-1)].add(
            (out_g * wgt_g[..., None]).reshape(-1, d).astype(acc_dt))

    y = jax.vmap(combine)(out, tok, wgt)                        # (G, T/G, D)
    y = part.constrain(y.reshape(t, d), "BATCH", None)
    y = y.astype(x.dtype)
    if cfg.n_shared_experts:
        y = y + mlp_apply(p["shared"], xf)
    return y.reshape(b, s, d), aux
