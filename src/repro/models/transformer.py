"""Decoder-only LM assembled from periodic blocks.

Layer stacks run as ``lax.scan`` over a *period super-block* (1 layer for
dense archs, 8 for Jamba's [attn + 7 mamba], 2 for xLSTM's alternation) with
stacked parameters, keeping the compiled HLO size independent of depth.
``first_k_dense`` (DeepSeek) layers run unscanned before the stack.

Entry points:
  init(key, cfg)                      -> params
  forward(params, x, cfg, positions)  -> (hidden, aux_loss)
  lm_loss(params, batch, cfg)         -> (loss, metrics)
  init_cache(cfg, batch, max_len)     -> decode cache
  decode_step(params, cache, tok, pos, cfg) -> (logits, cache)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from . import moe as M
from . import ssm as S
from .config import ModelConfig
from .module import dense_init, embed_init, stack_init

Params = Dict[str, Any]


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# --------------------------------------------------------------------------
# Block init / apply / decode
# --------------------------------------------------------------------------

def block_init(key, spec, cfg: ModelConfig, dtype) -> Params:
    mixer, ffn = spec
    k1, k2 = jax.random.split(key)
    bp: Params = {"ln1": L.rmsnorm_init(cfg.d_model)}
    if mixer == "attn":
        bp["mixer"] = L.attn_init(k1, cfg, dtype)
    elif mixer == "mla":
        bp["mixer"] = L.mla_init(k1, cfg, dtype)
    elif mixer == "mamba":
        bp["mixer"] = S.mamba_init(k1, cfg, dtype)
    elif mixer == "mlstm":
        bp["mixer"] = S.mlstm_init(k1, cfg, dtype)
    elif mixer == "slstm":
        bp["mixer"] = S.slstm_init(k1, cfg, dtype)
    else:
        raise ValueError(mixer)
    if ffn is not None:
        bp["ln2"] = L.rmsnorm_init(cfg.d_model)
        bp["ffn"] = M.moe_init(k2, cfg, dtype) if ffn == "moe" \
            else L.mlp_init(k2, cfg, dtype)
    return bp


def block_apply(bp, x, spec, cfg: ModelConfig, positions):
    mixer, ffn = spec
    aux = jnp.zeros((), jnp.float32)
    h = L.rmsnorm(x, bp["ln1"], cfg.norm_eps)
    if mixer == "attn":
        mx = L.attn_apply(bp["mixer"], h, cfg, positions)
    elif mixer == "mla":
        mx = L.mla_apply(bp["mixer"], h, cfg, positions)
    elif mixer == "mamba":
        mx = S.mamba_apply(bp["mixer"], h, cfg)
    elif mixer == "mlstm":
        mx = S.mlstm_apply(bp["mixer"], h, cfg)
    elif mixer == "slstm":
        mx = S.slstm_apply(bp["mixer"], h, cfg)
    x = x + mx
    if ffn is not None:
        h2 = L.rmsnorm(x, bp["ln2"], cfg.norm_eps)
        if ffn == "moe":
            y, aux = M.moe_apply(bp["ffn"], h2, cfg)
        else:
            y = L.mlp_apply(bp["ffn"], h2)
        x = x + y
    return x, aux


def block_make_cache(spec, cfg: ModelConfig, batch: int, max_len: int, dtype):
    mixer, _ = spec
    if mixer == "attn":
        return L.attn_make_cache(cfg, batch, max_len, dtype)
    if mixer == "mla":
        return L.mla_make_cache(cfg, batch, max_len, dtype)
    if mixer == "mamba":
        return S.mamba_make_cache(cfg, batch, dtype)
    if mixer == "mlstm":
        return S.mlstm_make_cache(cfg, batch, dtype)
    if mixer == "slstm":
        return S.slstm_make_cache(cfg, batch, dtype)
    raise ValueError(mixer)


def block_decode(bp, x, cache, spec, cfg: ModelConfig, pos):
    mixer, ffn = spec
    h = L.rmsnorm(x, bp["ln1"], cfg.norm_eps)
    if mixer == "attn":
        mx, cache = L.attn_decode(bp["mixer"], h, cache, pos, cfg)
    elif mixer == "mla":
        mx, cache = L.mla_decode(bp["mixer"], h, cache, pos, cfg)
    elif mixer == "mamba":
        mx, cache = S.mamba_decode(bp["mixer"], h, cache, cfg)
    elif mixer == "mlstm":
        mx, cache = S.mlstm_decode(bp["mixer"], h, cache, cfg)
    elif mixer == "slstm":
        mx, cache = S.slstm_decode(bp["mixer"], h, cache, cfg)
    x = x + mx
    if ffn is not None:
        h2 = L.rmsnorm(x, bp["ln2"], cfg.norm_eps)
        if ffn == "moe":
            y, _ = M.moe_apply(bp["ffn"], h2[:, None, :], cfg)
            y = y[:, 0]
        else:
            y = L.mlp_apply(bp["ffn"], h2)
        x = x + y
    return x, cache


# --------------------------------------------------------------------------
# Full model
# --------------------------------------------------------------------------

def init(key, cfg: ModelConfig) -> Params:
    dtype = _dtype(cfg)
    keys = jax.random.split(key, 8)
    params: Params = {
        "embed": embed_init(keys[0], cfg.vocab, cfg.d_model, dtype),
        "final_norm": L.rmsnorm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(keys[1], cfg.d_model, cfg.vocab,
                                    dtype=dtype)
    if cfg.first_k_dense:
        spec = (cfg.period[0][0], "mlp")
        params["prefix"] = [
            block_init(jax.random.fold_in(keys[2], i), spec, cfg, dtype)
            for i in range(cfg.first_k_dense)]
    stack = {}
    for i, spec in enumerate(cfg.period):
        stack[f"pos{i}"] = stack_init(
            lambda k, spec=spec: block_init(k, spec, cfg, dtype),
            jax.random.fold_in(keys[3], i), cfg.n_periods)
    params["stack"] = stack
    if cfg.mtp:
        params["mtp"] = {
            "proj": dense_init(keys[4], 2 * cfg.d_model, cfg.d_model,
                               dtype=dtype),
            "norm_h": L.rmsnorm_init(cfg.d_model),
            "norm_e": L.rmsnorm_init(cfg.d_model),
            "block": block_init(keys[5], cfg.period[0], cfg, dtype),
            "final_norm": L.rmsnorm_init(cfg.d_model),
        }
    return params


def forward(params, x, cfg: ModelConfig, positions) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) embedded inputs -> (hidden (B,S,D), aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.first_k_dense:
        spec = (cfg.period[0][0], "mlp")
        for bp in params["prefix"]:
            x, a = block_apply(bp, x, spec, cfg, positions)
            aux += a

    def period_body(carry, xs):
        x, aux = carry
        for i, spec in enumerate(cfg.period):
            x, a = block_apply(xs[f"pos{i}"], x, spec, cfg, positions)
            aux += a
        return (x, aux), None

    if cfg.remat:
        period_body = jax.checkpoint(
            period_body, policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.scan_layers:
        (x, aux), _ = jax.lax.scan(period_body, (x, aux), params["stack"])
    else:
        for j in range(cfg.n_periods):
            sl = jax.tree.map(lambda a: a[j], params["stack"])
            (x, aux), _ = period_body((x, aux), sl)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x, aux


def logits_fn(params, h, cfg: ModelConfig) -> jax.Array:
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    return (h @ w).astype(jnp.float32)


def _chunked_ce(params, h, labels, mask, cfg: ModelConfig,
                chunk: int = 256) -> Tuple[jax.Array, jax.Array]:
    """Cross-entropy without materialising (B, S, V) logits at once."""
    b, s, d = h.shape
    chunk = min(chunk, s)
    tot, cnt = jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)
    for i in range(0, s, chunk):
        # final chunk may be ragged (e.g. the MTP branch's shifted sequence)
        lg = logits_fn(params, h[:, i:i + chunk], cfg)       # (B, c, V) f32
        lab = labels[:, i:i + chunk]
        msk = mask[:, i:i + chunk]
        lse = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, lab[..., None], axis=-1)[..., 0]
        tot += jnp.sum((lse - gold) * msk)
        cnt += jnp.sum(msk)
    return tot, cnt


def embed_tokens(params, tokens, cfg: ModelConfig) -> jax.Array:
    return params["embed"][tokens]


def lm_loss(params, batch, cfg: ModelConfig) -> Tuple[jax.Array, Dict]:
    """batch: {'inputs': (B,S) int32 | 'embeds': (B,S,D), 'labels': (B,S),
    optional 'mask': (B,S)}."""
    if "embeds" in batch:
        x = batch["embeds"].astype(_dtype(cfg))
    else:
        x = embed_tokens(params, batch["inputs"], cfg)
    b, s, _ = x.shape
    positions = jnp.arange(s)
    labels = batch["labels"]
    mask = batch.get("mask", jnp.ones_like(labels, jnp.float32))
    h, aux = forward(params, x, cfg, positions)
    tot, cnt = _chunked_ce(params, h, labels, mask, cfg)
    loss = tot / jnp.maximum(cnt, 1.0)
    metrics = {"ce": loss, "aux": aux, "tokens": cnt}
    if cfg.mtp and "inputs" in batch:
        mp = params["mtp"]
        # predict token t+2: combine h_t with embedding of t+1 (= labels_t)
        h_in = L.rmsnorm(h[:, :-1], mp["norm_h"], cfg.norm_eps)
        e_in = L.rmsnorm(embed_tokens(params, labels[:, :-1], cfg),
                         mp["norm_e"], cfg.norm_eps)
        x2 = jnp.concatenate([h_in, e_in], axis=-1) @ mp["proj"]
        x2, _ = block_apply(mp["block"], x2, cfg.period[0], cfg,
                            positions[:-1])
        x2 = L.rmsnorm(x2, mp["final_norm"], cfg.norm_eps)
        tot2, cnt2 = _chunked_ce(params, x2, labels[:, 1:], mask[:, 1:], cfg)
        mtp_loss = tot2 / jnp.maximum(cnt2, 1.0)
        loss = loss + cfg.mtp_weight * mtp_loss
        metrics["mtp"] = mtp_loss
    loss = loss + aux
    return loss, metrics


# --------------------------------------------------------------------------
# Decode
# --------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    dtype = _dtype(cfg)
    cache: Params = {}
    if cfg.first_k_dense:
        spec = (cfg.period[0][0], "mlp")
        cache["prefix"] = [block_make_cache(spec, cfg, batch, max_len, dtype)
                           for _ in range(cfg.first_k_dense)]
    stack = {}
    for i, spec in enumerate(cfg.period):
        one = block_make_cache(spec, cfg, batch, max_len, dtype)
        stack[f"pos{i}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_periods,) + a.shape),
            one)
    cache["stack"] = stack
    return cache


def decode_step(params, cache, tokens, pos, cfg: ModelConfig):
    """tokens: (B,) int32; pos: scalar int32 absolute position.
    Returns (logits (B, V) f32, new_cache)."""
    x = params["embed"][tokens]

    def period_body(x, xs):
        bp, bc = xs
        new_bc = {}
        for i, spec in enumerate(cfg.period):
            x, new_bc[f"pos{i}"] = block_decode(
                bp[f"pos{i}"], x, bc[f"pos{i}"], spec, cfg, pos)
        return x, new_bc

    new_cache: Params = {}
    if cfg.first_k_dense:
        spec = (cfg.period[0][0], "mlp")
        new_cache["prefix"] = []
        for bp, bc in zip(params["prefix"], cache["prefix"]):
            x, nc = block_decode(bp, x, bc, spec, cfg, pos)
            new_cache["prefix"].append(nc)
    x, new_stack = jax.lax.scan(period_body, x,
                                (params["stack"], cache["stack"]))
    new_cache["stack"] = new_stack
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_fn(params, x, cfg)
    return logits, new_cache
