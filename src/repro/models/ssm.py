"""State-space and recurrent mixers: Mamba (Jamba) and xLSTM (sLSTM+mLSTM).

Training paths are *cost-transparent*: chunked python loops + associative
scans rather than long `lax.scan`s, so `cost_analysis` on the compiled step
counts the real work (see kernels/ops.py docstring).  The one exception is
sLSTM, whose stabilised recurrence is not associative — it uses `lax.scan`
over time and the roofline pipeline adds an analytic correction
(benchmarks/roofline.py).

Decode paths are single-step state updates (O(1) per token — these mixers are
the reason the `long_500k` cell is runnable for xLSTM/Jamba).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from .config import ModelConfig
from .module import dense_init
from .layers import rmsnorm, rmsnorm_init


# --------------------------------------------------------------------------
# Causal depthwise conv (shared by mamba / mLSTM)
# --------------------------------------------------------------------------

def _causal_conv(x, w, state=None):
    """x: (B, S, C); w: (C, K) depthwise. state: (B, K-1, C) history or None.
    Returns (y (B,S,C), new_state)."""
    b, s, c = x.shape
    k = w.shape[1]
    hist = jnp.zeros((b, k - 1, c), x.dtype) if state is None else state
    xp = jnp.concatenate([hist.astype(x.dtype), x], axis=1)
    cols = [xp[:, i:i + s] for i in range(k)]                  # K shifted views
    y = sum(cols[i] * w[:, i] for i in range(k))
    return y, xp[:, -(k - 1):] if k > 1 else jnp.zeros((b, 0, c), x.dtype)


def _conv_step(x, w, state):
    """x: (B, C); state: (B, K-1, C). Returns (y (B,C), new_state)."""
    k = w.shape[1]
    xp = jnp.concatenate([state.astype(x.dtype), x[:, None]], axis=1)  # (B,K,C)
    y = jnp.einsum("bkc,ck->bc", xp, w)
    return y, xp[:, 1:]


# --------------------------------------------------------------------------
# Mamba
# --------------------------------------------------------------------------

def mamba_init(key, cfg: ModelConfig, dtype) -> Dict:
    d, di, n, r = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, dtype=dtype),
        "conv": (jax.random.normal(ks[1], (di, cfg.ssm_conv), jnp.float32)
                 * (cfg.ssm_conv ** -0.5)).astype(dtype),
        "x_proj": dense_init(ks[2], di, r + 2 * n, dtype=dtype),
        "dt_proj": dense_init(ks[3], r, di, scale=r ** -0.5, dtype=dtype),
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32),
                                  (di, 1))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], di, d,
                               scale=di ** -0.5 / (2 * cfg.n_layers) ** 0.5,
                               dtype=dtype),
    }


def _mamba_core(p, xc, z, cfg, h0=None):
    """xc: (B,S,di) post-conv activations; z: gate. Returns (y, h_last)."""
    r, n = cfg.dt_rank, cfg.ssm_state
    proj = xc @ p["x_proj"]                                     # (B,S,r+2n)
    dt_r, Bm, Cm = jnp.split(proj, [r, r + n], axis=-1)
    dt = jax.nn.softplus(dt_r @ p["dt_proj"] + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, h_last = ops.mamba_scan(xc, dt, A, Bm, Cm, p["D"], h0=h0,
                               impl=cfg.attn_impl if cfg.attn_impl == "pallas"
                               else "jnp")
    return y * jax.nn.silu(z), h_last


def mamba_apply(p, x, cfg: ModelConfig) -> jax.Array:
    xz = x @ p["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)
    xc, _ = _causal_conv(xin, p["conv"])
    xc = jax.nn.silu(xc)
    y, _ = _mamba_core(p, xc, z, cfg)
    return y @ p["out_proj"]


def mamba_make_cache(cfg: ModelConfig, batch: int, dtype):
    di, n, k = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    return {"conv": jnp.zeros((batch, k - 1, di), dtype),
            "h": jnp.zeros((batch, di, n), jnp.float32)}


def mamba_decode(p, x, cache, cfg: ModelConfig):
    """x: (B, D). Returns (out (B, D), new_cache)."""
    xz = x @ p["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = _conv_step(xin, p["conv"], cache["conv"])
    xc = jax.nn.silu(xc)
    r, n = cfg.dt_rank, cfg.ssm_state
    proj = xc @ p["x_proj"]
    dt_r, Bm, Cm = jnp.split(proj, [r, r + n], axis=-1)
    dt = jax.nn.softplus(dt_r @ p["dt_proj"] + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, h = ops.mamba_step(xc, dt, A, Bm, Cm, p["D"], cache["h"])
    out = (y * jax.nn.silu(z)) @ p["out_proj"]
    return out, {"conv": conv_state, "h": h}


# --------------------------------------------------------------------------
# mLSTM (matrix-memory LSTM with exponential gating), chunkwise-parallel
# --------------------------------------------------------------------------

def mlstm_init(key, cfg: ModelConfig, dtype) -> Dict:
    d, di, h = cfg.d_model, cfg.d_inner, cfg.n_heads
    ks = jax.random.split(key, 7)
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, dtype=dtype),
        "conv": (jax.random.normal(ks[1], (di, cfg.ssm_conv), jnp.float32)
                 * (cfg.ssm_conv ** -0.5)).astype(dtype),
        "wq": dense_init(ks[2], di, di, dtype=dtype),
        "wk": dense_init(ks[3], di, di, dtype=dtype),
        "wv": dense_init(ks[4], di, di, dtype=dtype),
        "w_gates": dense_init(ks[5], d, 2 * h, scale=0.02, dtype=jnp.float32),
        "gate_bias": jnp.concatenate(
            [jnp.linspace(3.0, 6.0, h), jnp.zeros(h)]),  # forget bias high
        "norm": rmsnorm_init(cfg.d_inner),
        "out_proj": dense_init(ks[6], di, d,
                               scale=di ** -0.5 / (2 * cfg.n_layers) ** 0.5,
                               dtype=dtype),
    }


def _mlstm_chunk(q, k, v, logf, logi, state):
    """One chunk of the stabilised mLSTM recurrence.

    q/k/v: (B, H, W, dh); logf/logi: (B, H, W); state = (C (B,H,dh,dh),
    n (B,H,dh), m (B,H)).  Returns (h (B,H,W,dh), new_state).
    """
    b, hh, w, dh = q.shape
    C0, n0, m0 = state
    F = jnp.cumsum(logf, axis=-1)                              # (B,H,W)
    # log-weights of key j for query i (j <= i):  F_i - F_j + logi_j
    lw = F[..., :, None] - F[..., None, :] + logi[..., None, :]
    mask = jnp.tril(jnp.ones((w, w), bool))
    lw = jnp.where(mask, lw, -jnp.inf)
    inter_lw = m0[..., None] + F                               # (B,H,W)
    m = jnp.maximum(jnp.max(lw, axis=-1), inter_lw)            # (B,H,W)
    m = jnp.maximum(m, -1e30)
    dec = jnp.exp(lw - m[..., None])                           # (B,H,W,W)
    inter = jnp.exp(inter_lw - m)                              # (B,H,W)
    scale = dh ** -0.5
    scores = jnp.einsum("bhwd,bhud->bhwu", q, k) * scale * dec
    h_intra = jnp.einsum("bhwu,bhud->bhwd", scores, v)
    h_inter = inter[..., None] * jnp.einsum("bhij,bhwj->bhwi", C0, q) * scale
    n_i = jnp.einsum("bhwu,bhud->bhwd", dec, k) \
        + inter[..., None] * n0[..., None, :].repeat(w, axis=-2)
    denom = jnp.maximum(
        jnp.abs(jnp.einsum("bhwd,bhwd->bhw", n_i, q) * scale),
        jnp.exp(-m))
    h = (h_intra + h_inter) / denom[..., None]
    # chunk-end state
    Fw = F[..., -1]                                            # (B,H)
    lw_end = Fw[..., None] - F + logi                          # (B,H,W)
    m_end = jnp.maximum(m0 + Fw, jnp.max(lw_end, axis=-1))
    wgt = jnp.exp(lw_end - m_end[..., None])
    carry = jnp.exp(m0 + Fw - m_end)
    C1 = carry[..., None, None] * C0 + jnp.einsum(
        "bhw,bhwd,bhwe->bhde", wgt, v, k)
    n1 = carry[..., None] * n0 + jnp.einsum("bhw,bhwd->bhd", wgt, k)
    return h, (C1, n1, m_end)


def mlstm_apply(p, x, cfg: ModelConfig) -> jax.Array:
    b, s, d = x.shape
    hh = cfg.n_heads
    di = cfg.d_inner
    dh = di // hh
    xz = x @ p["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)
    xc, _ = _causal_conv(xin, p["conv"])
    xc = jax.nn.silu(xc)
    q = (xc @ p["wq"]).reshape(b, s, hh, dh).transpose(0, 2, 1, 3)
    k = (xc @ p["wk"]).reshape(b, s, hh, dh).transpose(0, 2, 1, 3)
    v = (xin @ p["wv"]).reshape(b, s, hh, dh).transpose(0, 2, 1, 3)
    gates = x.astype(jnp.float32) @ p["w_gates"] + p["gate_bias"]
    logf = jax.nn.log_sigmoid(gates[..., :hh]).transpose(0, 2, 1)
    logi = gates[..., hh:].transpose(0, 2, 1)                  # (B,H,S)
    # adaptive chunk: cap the unrolled python loop at 32 chunks so 32k+
    # sequences stay compile-tractable (intra-chunk work is quadratic in w,
    # still tiny vs the projections at these widths)
    w = min(max(cfg.lstm_chunk, s // 32), s)
    assert s % w == 0
    state = (jnp.zeros((b, hh, dh, dh), jnp.float32),
             jnp.zeros((b, hh, dh), jnp.float32),
             jnp.full((b, hh), -1e30, jnp.float32))
    hs = []
    for c0 in range(0, s, w):                  # static chunk loop
        hc, state = _mlstm_chunk(
            q[:, :, c0:c0 + w].astype(jnp.float32),
            k[:, :, c0:c0 + w].astype(jnp.float32),
            v[:, :, c0:c0 + w].astype(jnp.float32),
            logf[:, :, c0:c0 + w], logi[:, :, c0:c0 + w], state)
        hs.append(hc)
    h = jnp.concatenate(hs, axis=2).transpose(0, 2, 1, 3).reshape(b, s, di)
    h = rmsnorm(h.astype(x.dtype), p["norm"], cfg.norm_eps)
    return (h * jax.nn.silu(z)) @ p["out_proj"]


def mlstm_make_cache(cfg: ModelConfig, batch: int, dtype):
    hh = cfg.n_heads
    dh = cfg.d_inner // hh
    return {"conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
            "C": jnp.zeros((batch, hh, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, hh, dh), jnp.float32),
            "m": jnp.full((batch, hh), -1e30, jnp.float32)}


def mlstm_decode(p, x, cache, cfg: ModelConfig):
    b, d = x.shape
    hh = cfg.n_heads
    di = cfg.d_inner
    dh = di // hh
    xz = x @ p["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = _conv_step(xin, p["conv"], cache["conv"])
    xc = jax.nn.silu(xc)
    q = (xc @ p["wq"]).reshape(b, hh, dh).astype(jnp.float32)
    k = (xc @ p["wk"]).reshape(b, hh, dh).astype(jnp.float32)
    v = (xin @ p["wv"]).reshape(b, hh, dh).astype(jnp.float32)
    gates = x.astype(jnp.float32) @ p["w_gates"] + p["gate_bias"]
    logf = jax.nn.log_sigmoid(gates[..., :hh])
    logi = gates[..., hh:]
    m = jnp.maximum(logf + cache["m"], logi)
    fc = jnp.exp(logf + cache["m"] - m)
    ic = jnp.exp(logi - m)
    scale = dh ** -0.5
    C = fc[..., None, None] * cache["C"] + ic[..., None, None] * \
        jnp.einsum("bhd,bhe->bhde", v, k)
    n = fc[..., None] * cache["n"] + ic[..., None] * k
    num = jnp.einsum("bhde,bhe->bhd", C, q) * scale
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, q) * scale),
                      jnp.exp(-m))
    h = (num / den[..., None]).reshape(b, di)
    h = rmsnorm(h.astype(x.dtype), p["norm"], cfg.norm_eps)
    out = (h * jax.nn.silu(z)) @ p["out_proj"]
    return out, {"conv": conv_state, "C": C, "n": n, "m": m}


# --------------------------------------------------------------------------
# sLSTM (scalar-memory LSTM with exponential gating + recurrent weights)
# --------------------------------------------------------------------------

def slstm_init(key, cfg: ModelConfig, dtype) -> Dict:
    d, h = cfg.d_model, cfg.n_heads
    dh = d // h
    ks = jax.random.split(key, 3)
    return {
        "w": dense_init(ks[0], d, 4 * d, dtype=dtype),         # i,f,z,o
        "r": (jax.random.normal(ks[1], (4, h, dh, dh), jnp.float32)
              * dh ** -0.5).astype(dtype),
        "b": jnp.concatenate([jnp.zeros(d), jnp.full(d, 3.0),
                              jnp.zeros(2 * d)]),
        "out_proj": dense_init(ks[2], d, d,
                               scale=d ** -0.5 / (2 * cfg.n_layers) ** 0.5,
                               dtype=dtype),
    }


def _slstm_cell(p, wx_t, state, cfg: ModelConfig):
    """wx_t: (B, 4D) precomputed input contribution; state=(h,c,n,m)."""
    h_prev, c_prev, n_prev, m_prev = state
    b, d = h_prev.shape
    hh = cfg.n_heads
    dh = d // hh
    hp = h_prev.reshape(b, hh, dh)
    rec = jnp.einsum("bhd,ghde->bghe", hp.astype(jnp.float32),
                     p["r"].astype(jnp.float32)).reshape(b, 4 * d)
    g = wx_t.astype(jnp.float32) + rec + p["b"]
    gi, gf, gz, go = jnp.split(g, 4, axis=-1)
    m = jnp.maximum(jax.nn.log_sigmoid(gf) + m_prev, gi)
    i = jnp.exp(gi - m)
    f = jnp.exp(jax.nn.log_sigmoid(gf) + m_prev - m)
    c = f * c_prev + i * jnp.tanh(gz)
    n = f * n_prev + i
    h = jax.nn.sigmoid(go) * c / jnp.maximum(n, 1e-6)
    return h, (h, c, n, m)


def slstm_apply(p, x, cfg: ModelConfig) -> jax.Array:
    """Sequential scan over time (non-associative recurrence)."""
    b, s, d = x.shape
    wx = x @ p["w"]                                            # (B,S,4D)
    state = tuple(jnp.zeros((b, d), jnp.float32) for _ in range(3)) + \
        (jnp.full((b, d), 0.0, jnp.float32),)

    def step(st, wx_t):
        h, st2 = _slstm_cell(p, wx_t, st, cfg)
        return st2, h

    _, hs = jax.lax.scan(step, state, jnp.moveaxis(wx, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).astype(x.dtype)
    return h @ p["out_proj"]


def slstm_make_cache(cfg: ModelConfig, batch: int, dtype):
    d = cfg.d_model
    return {"h": jnp.zeros((batch, d), jnp.float32),
            "c": jnp.zeros((batch, d), jnp.float32),
            "n": jnp.zeros((batch, d), jnp.float32),
            "m": jnp.zeros((batch, d), jnp.float32)}


def slstm_decode(p, x, cache, cfg: ModelConfig):
    wx = x @ p["w"]
    h, (h2, c, n, m) = _slstm_cell(
        p, wx, (cache["h"], cache["c"], cache["n"], cache["m"]), cfg)
    out = h.astype(x.dtype) @ p["out_proj"]
    return out, {"h": h2, "c": c, "n": n, "m": m}
