"""Model configuration covering all ten assigned architectures.

A model is a stack of *blocks*; each block has a ``mixer`` (token mixing:
attention variants, Mamba, sLSTM, mLSTM) and an optional ``ffn`` (dense MLP or
MoE).  Periodic patterns (Jamba's [attn + 7 mamba], xLSTM's alternation,
DeepSeek's dense prefix) are expressed with ``period`` + ``first_k_dense``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

BlockSpec = Tuple[str, Optional[str]]           # (mixer, ffn)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense|moe|ssm|hybrid|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                 # 0 -> d_model // n_heads
    period: Tuple[BlockSpec, ...] = (("attn", "mlp"),)
    window: Optional[int] = None      # sliding-window attention width
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    max_seq: int = 8192               # learned-positions budget (enc-dec only)

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    d_expert: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    first_k_dense: int = 0            # DeepSeek: dense FFN for first k layers
    router_aux_weight: float = 0.001
    moe_dispatch_groups: int = 1      # grouped local dispatch (H3, a2a-shaped)
    moe_combine_dtype: str = "float32"  # scatter-add accumulator (H3 iter-3:
                                        # bfloat16 halves combine traffic)

    # --- MLA (DeepSeek) ---
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- SSM / xLSTM ---
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0              # 0 -> ceil(d_model / 16)
    lstm_chunk: int = 64              # mLSTM chunkwise block length

    # --- encoder-decoder (Whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 1500           # post-conv frames (frontend stubbed)

    # --- VLM (LLaVA) ---
    img_tokens: int = 0               # stub patch embeddings per sample

    # --- DeepSeek MTP ---
    mtp: bool = False
    mtp_weight: float = 0.3

    # --- runtime ---
    force_fsdp: bool = False          # ZeRO-3 sharding even for small models
    pure_dp: bool = False             # no TP: replicate params, batch over
                                      # every mesh axis (right-sizing for
                                      # sub-1B models; EXPERIMENTS.md H1)
    seq_shard: bool = False           # context parallelism: shard the
                                      # sequence dim over 'model' (long
                                      # prefill; EXPERIMENTS.md H2)
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    attn_impl: str = "jnp"            # jnp | pallas | ref
    q_chunk: int = 1024
    kv_chunk: int = 1024
    mamba_chunk: int = 64

    def __post_init__(self):
        assert self.n_layers % len(self.period) == 0, \
            f"{self.name}: n_layers {self.n_layers} not divisible by " \
            f"period {len(self.period)}"
        if self.first_k_dense:
            assert len(self.period) == 1, "dense prefix needs uniform period"

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_periods(self) -> int:
        return (self.n_layers - self.first_k_dense) // len(self.period)

    @property
    def d_inner(self) -> int:          # mamba / mLSTM expanded width
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or -(-self.d_model // 16)

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def blocks(self) -> Sequence[BlockSpec]:
        """Full per-layer (mixer, ffn) list, including the dense prefix."""
        out = [("attn" if not self.mla else "mla", "mlp")] * self.first_k_dense
        body = list(self.period) * self.n_periods
        return out + body

    # rough parameter counts, used for roofline MODEL_FLOPS = 6 N D
    def param_count(self) -> Tuple[int, int]:
        """(total_params, active_params_per_token)."""
        d, v = self.d_model, self.vocab
        total = v * d * (1 if self.tie_embeddings else 2)
        active = total
        for mixer, ffn in self.blocks():
            pm = self._mixer_params(mixer)
            total += pm
            active += pm
            if ffn == "mlp":
                pf = 3 * d * self.d_ff
                total += pf
                active += pf
            elif ffn == "moe":
                pe = 3 * d * self.d_expert
                total += self.n_experts * pe + d * self.n_experts
                active += (self.top_k + self.n_shared_experts) * pe
                total += self.n_shared_experts * pe
            total += 2 * d                       # norms
            active += 2 * d
        if self.is_encdec:                        # encoder stack + cross attn
            enc = self.encoder_layers * (4 * d * d + 3 * d * self.d_ff + 2 * d)
            cross = self.n_layers * 4 * d * d
            total += enc + cross
            active += enc + cross
        return total, active

    def _mixer_params(self, mixer: str) -> int:
        d = self.d_model
        if mixer == "attn":
            q = d * self.n_heads * self.hd
            kv = 2 * d * self.n_kv_heads * self.hd
            o = self.n_heads * self.hd * d
            return q + kv + o
        if mixer == "mla":
            qk_head = self.qk_nope_head_dim + self.qk_rope_head_dim
            p = d * self.q_lora_rank + self.q_lora_rank * self.n_heads * qk_head
            p += d * (self.kv_lora_rank + self.qk_rope_head_dim)
            p += self.kv_lora_rank * self.n_heads * (
                self.qk_nope_head_dim + self.v_head_dim)
            p += self.n_heads * self.v_head_dim * d
            return p
        if mixer == "mamba":
            di, n = self.d_inner, self.ssm_state
            p = d * 2 * di                        # in proj (x, z)
            p += di * self.ssm_conv               # conv
            p += di * (self.dt_rank + 2 * n)      # x -> dt, B, C
            p += self.dt_rank * di + di * n + di  # dt proj, A, D
            p += di * d                           # out proj
            return p
        if mixer in ("slstm", "mlstm"):
            di = self.d_inner
            if mixer == "mlstm":
                return d * 2 * di + di * 3 * di + 2 * d * self.n_heads \
                    + di * d + di * self.ssm_conv
            return 4 * d * d + 4 * d * d // self.n_heads + d * d
        raise ValueError(mixer)
