"""Whisper-style encoder-decoder (audio frontend stubbed per the brief).

``input_specs`` provides precomputed frame embeddings (B, enc_seq, D) — the
conv1d+GELU frontend is a stub.  The encoder is a bidirectional transformer
with learned positions; the decoder adds causal self-attention (KV cache) and
cross-attention over encoder states (K/V precomputed once at prefill).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from .config import ModelConfig
from .module import dense_init, embed_init, stack_init
from .transformer import _chunked_ce, _dtype, logits_fn

Params = Dict[str, Any]


def _enc_block_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {"ln1": L.rmsnorm_init(cfg.d_model),
            "attn": L.attn_init(k1, cfg, dtype),
            "ln2": L.rmsnorm_init(cfg.d_model),
            "mlp": L.mlp_init(k2, cfg, dtype)}


def _dec_block_init(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": L.rmsnorm_init(cfg.d_model),
            "self": L.attn_init(k1, cfg, dtype),
            "ln_x": L.rmsnorm_init(cfg.d_model),
            "cross": L.attn_init(k2, cfg, dtype),
            "ln2": L.rmsnorm_init(cfg.d_model),
            "mlp": L.mlp_init(k3, cfg, dtype)}


def init(key, cfg: ModelConfig) -> Params:
    dtype = _dtype(cfg)
    ks = jax.random.split(key, 6)
    return {
        "embed": embed_init(ks[0], cfg.vocab, cfg.d_model, dtype),
        "enc_pos": (jax.random.normal(ks[1], (cfg.encoder_seq, cfg.d_model),
                                      jnp.float32) * 0.02).astype(dtype),
        "dec_pos": (jax.random.normal(ks[2], (cfg.max_seq, cfg.d_model),
                                      jnp.float32) * 0.02).astype(dtype),
        "encoder": stack_init(lambda k: _enc_block_init(k, cfg, dtype),
                              ks[3], cfg.encoder_layers),
        "decoder": stack_init(lambda k: _dec_block_init(k, cfg, dtype),
                              ks[4], cfg.n_layers),
        "enc_norm": L.rmsnorm_init(cfg.d_model),
        "final_norm": L.rmsnorm_init(cfg.d_model),
    }
    # decoder head is tied to the embedding (Whisper style)


def encode(params, frames, cfg: ModelConfig) -> jax.Array:
    """frames: (B, enc_seq, D) stub embeddings -> encoder states."""
    x = frames.astype(_dtype(cfg)) + params["enc_pos"][None]
    positions = jnp.arange(frames.shape[1])

    def body(x, bp):
        h = L.rmsnorm(x, bp["ln1"], cfg.norm_eps)
        x = x + L.attn_apply(bp["attn"], h, cfg, positions, causal=False,
                             use_rope=False)
        h = L.rmsnorm(x, bp["ln2"], cfg.norm_eps)
        x = x + L.mlp_apply(bp["mlp"], h)
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["encoder"])
    else:   # unrolled: exact costs in the dry-run (no enc-dec scan correction)
        for i in range(cfg.encoder_layers):
            x, _ = body(x, jax.tree.map(lambda a: a[i], params["encoder"]))
    return L.rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def cross_kv(params, enc_states, cfg: ModelConfig):
    """Precompute per-decoder-layer cross K/V: (L, B, Hkv, S_enc, hd) x2."""
    b, s, d = enc_states.shape
    hkv, hd = cfg.n_kv_heads, cfg.hd

    def one(bp):
        k = (enc_states @ bp["cross"]["wk"]).reshape(b, s, hkv, hd)
        v = (enc_states @ bp["cross"]["wv"]).reshape(b, s, hkv, hd)
        return k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)

    return jax.vmap(one)(params["decoder"])


def decode_train(params, enc_states, tokens, cfg: ModelConfig) -> jax.Array:
    """Teacher-forced decoder pass. tokens: (B, S). Returns hidden (B,S,D)."""
    b, s = tokens.shape
    x = params["embed"][tokens] + params["dec_pos"][:s][None]
    positions = jnp.arange(s)
    ckv = cross_kv(params, enc_states, cfg)

    def body(x, xs):
        bp, (ck, cv) = xs
        h = L.rmsnorm(x, bp["ln1"], cfg.norm_eps)
        x = x + L.attn_apply(bp["self"], h, cfg, positions, causal=True,
                             use_rope=False)
        h = L.rmsnorm(x, bp["ln_x"], cfg.norm_eps)
        x = x + L.cross_attn_apply(bp["cross"], h, (ck, cv), cfg)
        h = L.rmsnorm(x, bp["ln2"], cfg.norm_eps)
        x = x + L.mlp_apply(bp["mlp"], h)
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, (params["decoder"], ckv))
    else:
        for i in range(cfg.n_layers):
            x, _ = body(x, jax.tree.map(lambda a: a[i],
                                        (params["decoder"], ckv)))
    return L.rmsnorm(x, params["final_norm"], cfg.norm_eps)


def lm_loss(params, batch, cfg: ModelConfig):
    """batch: {'frames': (B,enc_seq,D), 'inputs': (B,S), 'labels': (B,S)}."""
    enc = encode(params, batch["frames"], cfg)
    h = decode_train(params, enc, batch["inputs"], cfg)
    mask = batch.get("mask",
                     jnp.ones_like(batch["labels"], jnp.float32))
    tot, cnt = _chunked_ce(params, h, batch["labels"], mask, cfg)
    loss = tot / jnp.maximum(cnt, 1.0)
    return loss, {"ce": loss, "tokens": cnt}


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               enc_states=None, params=None) -> Params:
    """Self-attn ring caches + (optionally precomputed) cross K/V."""
    dtype = _dtype(cfg)
    one = L.attn_make_cache(cfg, batch, max_len, dtype)
    cache: Params = {
        "self": jax.tree.map(
            lambda a: jnp.broadcast_to(
                a[None], (cfg.n_layers,) + a.shape), one)}
    if enc_states is not None:
        cache["cross"] = cross_kv(params, enc_states, cfg)
    else:
        hkv, hd = cfg.n_kv_heads, cfg.hd
        cache["cross"] = (
            jnp.zeros((cfg.n_layers, batch, hkv, cfg.encoder_seq, hd), dtype),
            jnp.zeros((cfg.n_layers, batch, hkv, cfg.encoder_seq, hd), dtype))
    return cache


def decode_step(params, cache, tokens, pos, cfg: ModelConfig):
    """tokens: (B,). Returns (logits (B,V), new_cache)."""
    x = params["embed"][tokens] + jax.lax.dynamic_index_in_dim(
        params["dec_pos"], pos, keepdims=False)

    def body(x, xs):
        bp, sc, ck, cv = xs
        h = L.rmsnorm(x, bp["ln1"], cfg.norm_eps)
        mx, sc = L.attn_decode(bp["self"], h, sc, pos, cfg, use_rope=False)
        x = x + mx
        h = L.rmsnorm(x, bp["ln_x"], cfg.norm_eps)
        q = (h @ bp["cross"]["wq"]).reshape(
            x.shape[0], cfg.n_heads, cfg.hd)
        from repro.kernels import ops
        ca = ops.decode_attention(q, ck, cv, impl=cfg.attn_impl)
        x = x + ca.reshape(x.shape[0], -1) @ bp["cross"]["wo"]
        h = L.rmsnorm(x, bp["ln2"], cfg.norm_eps)
        x = x + L.mlp_apply(bp["mlp"], h)
        return x, sc

    x, new_self = jax.lax.scan(
        body, x, (params["decoder"], cache["self"],
                  cache["cross"][0], cache["cross"][1]))
    new_cache = {"self": new_self, "cross": cache["cross"]}
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    w = params["embed"].T
    return (x @ w).astype(jnp.float32), new_cache
