"""Model zoo: one composable block system covering all assigned archs.

``model_api(cfg)`` returns the family-appropriate (init, loss_fn, cache_fn,
decode_fn) tuple so the launcher/trainer never branches on architecture.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple

import jax.numpy as jnp

from . import encdec, frontends, transformer
from .config import ModelConfig


class ModelAPI(NamedTuple):
    init: Callable          # (key, cfg) -> params
    loss: Callable          # (params, batch, cfg) -> (loss, metrics)
    init_cache: Callable    # (cfg, batch, max_len[, ...]) -> cache
    decode_step: Callable   # (params, cache, tokens, pos, cfg) -> (logits, cache)


def model_api(cfg: ModelConfig) -> ModelAPI:
    if cfg.is_encdec:
        return ModelAPI(encdec.init, encdec.lm_loss, encdec.init_cache,
                        encdec.decode_step)
    return ModelAPI(transformer.init, transformer.lm_loss,
                    transformer.init_cache, transformer.decode_step)


__all__ = ["ModelConfig", "ModelAPI", "model_api", "transformer", "encdec",
           "frontends"]
