"""Stub modality frontends (per the brief: backbone only; ``input_specs``
provides precomputed frame/patch embeddings).

These generate *synthetic* frontend outputs with the right shapes/dtypes for
smoke tests and the end-to-end examples; the dry-run consumes
ShapeDtypeStructs of the same shapes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig


def audio_frames(key, cfg: ModelConfig, batch: int) -> jax.Array:
    """Whisper stub: post-conv frame embeddings (B, enc_seq, D)."""
    return jax.random.normal(
        key, (batch, cfg.encoder_seq, cfg.d_model), jnp.float32) * 0.02


def image_patches(key, cfg: ModelConfig, batch: int) -> jax.Array:
    """LLaVA anyres stub: projected patch embeddings (B, img_tokens, D).

    Real LLaVA-NeXT tiles the image (anyres) into up to 5 crops of 576
    patches; ``cfg.img_tokens`` carries the flattened count.
    """
    return jax.random.normal(
        key, (batch, cfg.img_tokens, cfg.d_model), jnp.float32) * 0.02


def fuse_vlm_inputs(params, patches, tokens, cfg: ModelConfig) -> jax.Array:
    """[img patches; text embeds] -> (B, img_tokens + text_len, D)."""
    text = params["embed"][tokens]
    return jnp.concatenate([patches.astype(text.dtype), text], axis=1)
