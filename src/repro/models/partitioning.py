"""Lightweight activation-sharding constraints for model internals.

The launch layer registers the active mesh (+ the batch axes) here; model
code calls :func:`constrain` at GSPMD decision points (MoE dispatch/combine
being the critical one — without a constraint the combine scatter tends to
come out replicated over the model axis, inflating activation memory by the
TP degree).  With no mesh registered (unit tests, single-device smoke runs)
``constrain`` is a no-op.
"""
from __future__ import annotations

import contextlib
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = {"mesh": None, "batch_axes": ("data",)}


def set_mesh(mesh: Optional[Mesh], batch_axes: Tuple[str, ...] = ("data",)):
    _STATE["mesh"] = mesh
    _STATE["batch_axes"] = tuple(batch_axes)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], batch_axes: Tuple[str, ...] = ("data",)):
    prev = (_STATE["mesh"], _STATE["batch_axes"])
    set_mesh(mesh, batch_axes)
    try:
        yield
    finally:
        set_mesh(*prev)


def batch_axes() -> Tuple[str, ...]:
    return _STATE["batch_axes"]


def constrain(x, *spec):
    """with_sharding_constraint(x, P(*spec)) if a mesh is registered.

    Spec entries: None, a mesh axis name, 'BATCH' (expands to the registered
    batch axes), or a tuple of axis names."""
    mesh = _STATE["mesh"]
    if mesh is None:
        return x
    resolved = []
    for s in spec:
        if s == "BATCH":
            resolved.append(_STATE["batch_axes"])
        else:
            resolved.append(s)
    try:
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*resolved)))
    except Exception:
        return x
