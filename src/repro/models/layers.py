"""Attention (GQA / SWA / MLA), RoPE, RMSNorm and MLP layers.

All functions are pure: ``*_init(key, cfg) -> params`` and
``*_apply(params, x, ...) -> y``.  Decode variants consume/return explicit
caches (KV tensors + a scalar position) so the serving loop and the dry-run
can shard them as first-class inputs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from .config import ModelConfig
from .module import dense_init


def rmsnorm_init(d: int) -> jax.Array:
    return jnp.ones((d,), jnp.float32)


def rmsnorm(x, scale, eps):
    return ops.rmsnorm(x, scale, eps)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D) with even D; positions: (S,) or (B, S)."""
    d = x.shape[-1]
    freqs = theta ** (-jnp.arange(0, d, 2, dtype=jnp.float32) / d)
    if positions.ndim == 1:
        positions = positions[None]
    ang = positions[..., None].astype(jnp.float32) * freqs      # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# GQA attention (with optional sliding window), train + decode
# --------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig, dtype, cross: bool = False) -> Dict:
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, h * hd, dtype=dtype),
        "wk": dense_init(ks[1], d, hkv * hd, dtype=dtype),
        "wv": dense_init(ks[2], d, hkv * hd, dtype=dtype),
        "wo": dense_init(ks[3], h * hd, d,
                         scale=(h * hd) ** -0.5 / (2 * cfg.n_layers) ** 0.5,
                         dtype=dtype),
    }


def attn_apply(p, x, cfg: ModelConfig, positions, causal=True,
               use_rope=True) -> jax.Array:
    """Full-sequence attention. x: (B, S, D) -> (B, S, D)."""
    b, s, d = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p["wq"]).reshape(b, s, h, hd)
    k = (x @ p["wk"]).reshape(b, s, hkv, hd)
    v = (x @ p["wv"]).reshape(b, s, hkv, hd)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    qt, kt, vt = (t.transpose(0, 2, 1, 3) for t in (q, k, v))
    from . import partitioning as part
    mesh = part._STATE["mesh"]
    if cfg.seq_shard and causal and mesh is not None and \
            s % mesh.shape["model"] == 0:
        # context parallelism (H2): S sharded over 'model'; ring-gather K/V
        out = ops.cp_flash_attention(
            qt, kt, vt, mesh, axis="model", causal=True, window=cfg.window,
            q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    else:
        out = ops.flash_attention(
            qt, kt, vt, causal=causal, window=cfg.window,
            impl=cfg.attn_impl, q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, h * hd)
    return out @ p["wo"]


def cross_attn_apply(p, x, kv_cache, cfg: ModelConfig) -> jax.Array:
    """Cross attention vs precomputed encoder K/V: kv_cache = (k, v) with
    shape (B, Henc_kv, S_enc, hd)."""
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.hd
    q = (x @ p["wq"]).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k, v = kv_cache
    out = ops.flash_attention(q, k, v, causal=False, window=None,
                              impl=cfg.attn_impl, q_chunk=cfg.q_chunk,
                              kv_chunk=cfg.kv_chunk)
    return out.transpose(0, 2, 1, 3).reshape(b, s, h * hd) @ p["wo"]


def attn_make_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    hkv, hd = cfg.n_kv_heads, cfg.hd
    cache_len = min(max_len, cfg.window) if cfg.window else max_len
    return {"k": jnp.zeros((batch, hkv, cache_len, hd), dtype),
            "v": jnp.zeros((batch, hkv, cache_len, hd), dtype)}


def attn_decode(p, x, cache, pos, cfg: ModelConfig, use_rope=True):
    """One-token decode. x: (B, D); cache k/v: (B, Hkv, C, hd); ``pos``:
    scalar absolute position.  Sliding windows use a ring buffer of width
    ``cfg.window``.  Returns (out (B, D), new_cache)."""
    b, d = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p["wq"]).reshape(b, 1, h, hd)
    k = (x @ p["wk"]).reshape(b, 1, hkv, hd)
    v = (x @ p["wv"]).reshape(b, 1, hkv, hd)
    if use_rope:
        pq = jnp.full((1,), pos)
        q = apply_rope(q, pq, cfg.rope_theta)
        k = apply_rope(k, pq, cfg.rope_theta)
    c = cache["k"].shape[2]
    slot = pos % c if cfg.window else pos
    ck = jax.lax.dynamic_update_slice(
        cache["k"], k.transpose(0, 2, 1, 3).astype(cache["k"].dtype),
        (0, 0, slot, 0))
    cv = jax.lax.dynamic_update_slice(
        cache["v"], v.transpose(0, 2, 1, 3).astype(cache["v"].dtype),
        (0, 0, slot, 0))
    length = jnp.minimum(pos + 1, c)
    out = ops.decode_attention(
        q[:, 0].transpose(0, 2, 1).reshape(b, h, hd)
        if False else q.reshape(b, h, hd),
        ck, cv, length=jnp.broadcast_to(length, (b,)).astype(jnp.int32),
        impl=cfg.attn_impl)
    # NOTE on ring buffers: with a window ring buffer every slot < length is
    # valid (all within the last `window` positions), so no extra masking is
    # needed beyond `length`.
    return out.reshape(b, h * hd) @ p["wo"], {"k": ck, "v": cv}


# --------------------------------------------------------------------------
# MLA — DeepSeek-V3 multi-head latent attention
# --------------------------------------------------------------------------

def mla_init(key, cfg: ModelConfig, dtype) -> Dict:
    d = cfg.d_model
    h = cfg.n_heads
    qk = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    ks = jax.random.split(key, 7)
    return {
        "wdq": dense_init(ks[0], d, cfg.q_lora_rank, dtype=dtype),
        "q_norm": rmsnorm_init(cfg.q_lora_rank),
        "wuq": dense_init(ks[1], cfg.q_lora_rank, h * qk, dtype=dtype),
        "wdkv": dense_init(ks[2], d, cfg.kv_lora_rank, dtype=dtype),
        "kv_norm": rmsnorm_init(cfg.kv_lora_rank),
        "wkr": dense_init(ks[3], d, cfg.qk_rope_head_dim, dtype=dtype),
        "wukv": dense_init(
            ks[4], cfg.kv_lora_rank,
            h * (cfg.qk_nope_head_dim + cfg.v_head_dim), dtype=dtype),
        "wo": dense_init(ks[5], h * cfg.v_head_dim, d,
                         scale=(h * cfg.v_head_dim) ** -0.5
                         / (2 * cfg.n_layers) ** 0.5, dtype=dtype),
    }


def _mla_qkv(p, x, cfg: ModelConfig, positions):
    """Shared q / (compressed kv) computation. Returns q, c_kv, k_rope."""
    b, s, _ = x.shape
    h = cfg.n_heads
    cq = rmsnorm(x @ p["wdq"], p["q_norm"], cfg.norm_eps)
    q = (cq @ p["wuq"]).reshape(
        b, s, h, cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    c_kv = rmsnorm(x @ p["wdkv"], p["kv_norm"], cfg.norm_eps)   # (B,S,r_kv)
    k_rope = apply_rope((x @ p["wkr"])[:, :, None, :], positions,
                        cfg.rope_theta)                          # (B,S,1,dr)
    return q_nope, q_rope, c_kv, k_rope


def mla_apply(p, x, cfg: ModelConfig, positions) -> jax.Array:
    b, s, _ = x.shape
    h = cfg.n_heads
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, x, cfg, positions)
    kv = (c_kv @ p["wukv"]).reshape(
        b, s, h, cfg.qk_nope_head_dim + cfg.v_head_dim)
    k_nope, v = jnp.split(kv, [cfg.qk_nope_head_dim], axis=-1)
    k_rope_h = jnp.broadcast_to(
        k_rope, (b, s, h, cfg.qk_rope_head_dim))
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate([k_nope, k_rope_h], -1)
    scale = (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim) ** -0.5
    # v head dim != qk head dim -> pad v to qk width for the shared kernel
    qk = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qk - cfg.v_head_dim)))
    out = ops.flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        vp.transpose(0, 2, 1, 3), causal=True, window=None, scale=scale,
        impl=cfg.attn_impl, q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    out = out.transpose(0, 2, 1, 3)[..., :cfg.v_head_dim]
    return out.reshape(b, s, h * cfg.v_head_dim) @ p["wo"]


def mla_make_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    """Compressed cache: c_kv (B, S, r_kv) + k_rope (B, S, dr)."""
    return {"c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim),
                                dtype)}


def mla_decode(p, x, cache, pos, cfg: ModelConfig, absorbed: bool = True):
    """One-token MLA decode against the *compressed* cache.

    ``absorbed=True`` uses the weight-absorption trick: queries are mapped
    into the latent space (q' = q_nope @ W_ukv^k) and attention runs directly
    over c_kv — no per-step decompression of the whole cache.  With
    ``absorbed=False`` the cache is decompressed each step (baseline; see
    EXPERIMENTS.md §Perf for the measured difference).
    """
    b, d = x.shape
    h = cfg.n_heads
    q_nope, q_rope, c_kv_new, k_rope_new = _mla_qkv(
        p, x[:, None, :], cfg, jnp.full((1,), pos))
    cache = {
        "c_kv": jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv_new.astype(cache["c_kv"].dtype), (0, pos, 0)),
        "k_rope": jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope_new[:, :, 0].astype(
                cache["k_rope"].dtype), (0, pos, 0)),
    }
    scale = (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim) ** -0.5
    s_max = cache["c_kv"].shape[1]
    kpos = jnp.arange(s_max)
    valid = kpos <= pos
    wukv = p["wukv"].reshape(
        cfg.kv_lora_rank, h, cfg.qk_nope_head_dim + cfg.v_head_dim)
    wk = wukv[:, :, :cfg.qk_nope_head_dim]            # (r, h, dqk)
    wv = wukv[:, :, cfg.qk_nope_head_dim:]            # (r, h, dv)
    if absorbed:
        q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], wk)
        logits = jnp.einsum("bhr,bsr->bhs", q_lat,
                            cache["c_kv"].astype(jnp.float32))
        logits += jnp.einsum("bhd,bsd->bhs", q_rope[:, 0],
                             cache["k_rope"].astype(jnp.float32))
        logits = jnp.where(valid[None, None], logits * scale, -1e30)
        w = jax.nn.softmax(logits, axis=-1)
        o_lat = jnp.einsum("bhs,bsr->bhr", w,
                           cache["c_kv"].astype(jnp.float32))
        out = jnp.einsum("bhr,rhd->bhd", o_lat, wv)
    else:
        kv = jnp.einsum("bsr,rhd->bshd", cache["c_kv"].astype(jnp.float32),
                        wukv)
        k_nope, v = jnp.split(kv, [cfg.qk_nope_head_dim], axis=-1)
        logits = jnp.einsum("bhd,bshd->bhs", q_nope[:, 0], k_nope)
        logits += jnp.einsum("bhd,bsd->bhs", q_rope[:, 0],
                             cache["k_rope"].astype(jnp.float32))
        logits = jnp.where(valid[None, None], logits * scale, -1e30)
        w = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhs,bshd->bhd", w, v)
    out = out.astype(x.dtype).reshape(b, h * cfg.v_head_dim)
    return out @ p["wo"], cache


# --------------------------------------------------------------------------
# Dense MLP (SwiGLU)
# --------------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig, dtype, d_ff: Optional[int] = None) -> Dict:
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    return {"gate": dense_init(ks[0], d, d_ff, dtype=dtype),
            "up": dense_init(ks[1], d, d_ff, dtype=dtype),
            "down": dense_init(ks[2], d_ff, d,
                               scale=d_ff ** -0.5 / (2 * cfg.n_layers) ** 0.5,
                               dtype=dtype)}


def mlp_apply(p, x) -> jax.Array:
    return (jax.nn.silu(x @ p["gate"]) * (x @ p["up"])) @ p["down"]
