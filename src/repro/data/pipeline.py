"""Deterministic, shardable, resumable data pipeline.

Production-shaped guarantees without external deps:
* **Determinism** — batch ``i`` of shard ``s`` depends only on (seed, i, s)
  via threefry counters; restarts reproduce the identical stream.
* **Sharding** — each data-parallel host pulls only its shard (``shard_id``,
  ``n_shards``); no coordination needed.
* **Resumability** — state is a single step counter; ``state()`` /
  ``restore()`` round-trips through checkpoints (fault tolerance).
* **Backpressure-free prefetch** — a bounded background thread keeps
  ``prefetch`` batches ready (the streaming paper's jumbo-tuple + bounded
  queue pattern applied to the input pipeline).

Two sources: synthetic LM tokens (zipfian, so losses are non-degenerate) and
a memory-mapped binary corpus (``BinTokenSource``).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class PipelineState:
    step: int
    seed: int
    shard_id: int
    n_shards: int

    def to_dict(self):
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d):
        return PipelineState(**d)


class SyntheticLM:
    """Zipfian synthetic token stream -> {'inputs', 'labels'} batches."""

    def __init__(self, batch: int, seq: int, vocab: int, seed: int = 0,
                 shard_id: int = 0, n_shards: int = 1, alpha: float = 1.1):
        assert batch % n_shards == 0
        self.batch = batch // n_shards
        self.seq = seq
        self.vocab = vocab
        self.st = PipelineState(0, seed, shard_id, n_shards)
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        probs = ranks ** -alpha
        self._cdf = np.cumsum(probs / probs.sum())

    def state(self) -> Dict:
        return self.st.to_dict()

    def restore(self, d: Dict) -> None:
        self.st = PipelineState.from_dict(d)

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence(
                [self.st.seed, self.st.shard_id, step]))

    def next_batch(self) -> Dict[str, np.ndarray]:
        rng = self._rng(self.st.step)
        u = rng.random((self.batch, self.seq + 1))
        toks = np.searchsorted(self._cdf, u).astype(np.int32)
        toks = np.clip(toks, 0, self.vocab - 1)
        self.st.step += 1
        return {"inputs": toks[:, :-1], "labels": toks[:, 1:]}


class BinTokenSource:
    """Memory-mapped corpus of int32 tokens; deterministic random windows."""

    def __init__(self, path: str, batch: int, seq: int, seed: int = 0,
                 shard_id: int = 0, n_shards: int = 1):
        assert batch % n_shards == 0
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        assert len(self.tokens) > seq + 1, "corpus too small"
        self.batch = batch // n_shards
        self.seq = seq
        self.st = PipelineState(0, seed, shard_id, n_shards)

    def state(self) -> Dict:
        return self.st.to_dict()

    def restore(self, d: Dict) -> None:
        self.st = PipelineState.from_dict(d)

    def next_batch(self) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.st.seed, self.st.shard_id,
                                    self.st.step]))
        starts = rng.integers(0, len(self.tokens) - self.seq - 1,
                              size=self.batch)
        rows = np.stack([np.asarray(self.tokens[s:s + self.seq + 1])
                         for s in starts])
        self.st.step += 1
        return {"inputs": rows[:, :-1], "labels": rows[:, 1:]}


class Prefetcher:
    """Bounded background prefetch (jumbo-batch queue with backpressure)."""

    def __init__(self, source, prefetch: int = 2):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        while not self._stop.is_set():
            batch = self.source.next_batch()
            while not self._stop.is_set():
                try:
                    self.q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def next_batch(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
