"""Version-portability shims for the small jax surface this repo touches.

The container tracks whatever jax release is baked into the image, and two
APIs the kernels rely on have drifted across releases:

* ``shard_map`` graduated from ``jax.experimental.shard_map`` (keyword
  ``check_rep``) to top-level ``jax.shard_map`` (keyword ``check_vma``).
* ``Compiled.cost_analysis()`` returned a one-element list of dicts before
  returning the dict directly.

Keeping the mapping here means kernel and launch code is written against the
modern spelling and still runs on the pinned image (these were the four
"pre-existing environment-bound" tier-1 failures — they were version drift,
not environment limits).
"""
from __future__ import annotations

from typing import Dict

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` on releases that have it, else the experimental
    spelling with ``check_vma`` mapped onto its older ``check_rep`` name."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as sm_exp
    return sm_exp(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=check_vma)


def cost_analysis(compiled) -> Dict[str, float]:
    """``Compiled.cost_analysis()`` as a dict on every supported release."""
    c = compiled.cost_analysis()
    if isinstance(c, (list, tuple)):
        c = c[0] if c else {}
    return c


def compiled_flops(compiled) -> float:
    return float(cost_analysis(compiled).get("flops", 0.0))
