"""Process-parallel backend contract (ISSUE 6).

The parity contract: threads and processes execute the same prepared app
over the same compiled routes — only the transport differs (in-process
queues vs shared-memory SPSC rings) — so under deterministic replay the
outputs are byte-identical: spout/sink counters, merged keyed state, pane
multisets, late drops.  Plus: the ring speaks the executor's queue
protocol, crashes and wedges tear down without orphaning ``/dev/shm``
segments, state migrates across a process-backend replan byte-for-byte,
and plan-faithful grouping realizes the plan's socket map.
"""
import os
import queue
import time

import numpy as np
import pytest

from repro.core import server_a, subset
from repro.streaming.api import Job, Topology
from repro.streaming.apps import (linear_road, spike_detection_eventtime,
                                  spike_detection_keyed, word_count)
from repro.streaming.procexec import (BACKENDS, ShmRing, get_backend,
                                      host_device_env, plan_placement,
                                      register_backend, register_ring_dtype,
                                      run_app_processes, socket_core_map)
from repro.streaming.runtime import _POISON, _Watermark, run_app
from repro.streaming.state import (KeyedStore, StateSpec, WindowSpec,
                                   merge_keyed, migrate_states)


def _shm_leftovers():
    return [f for f in os.listdir("/dev/shm") if f.startswith("bsr")]


def _summary(r):
    return (r.spout_tuples, r.sink_tuples, r.late_drops, r.panes_fired)


def _keyed_bytes(r):
    out = {}
    for op, reps in r.states.items():
        stores = [s.managed for s in reps if isinstance(s.managed, KeyedStore)]
        if stores:
            out[op] = merge_keyed(stores).tobytes()
    return out


def _sink_scratch(r, lg):
    return {op: [{k: v for k, v in st.items() if np.isscalar(v)}
                 for st in r.states[op]] for op in lg.sinks()}


# ---------------------------------------------------------------------------
# ShmRing: the executor queue protocol over one shared segment
# ---------------------------------------------------------------------------

def test_ring_roundtrip_data_watermark_poison():
    ring = ShmRing(capacity=4)
    try:
        arr = np.arange(12.0).reshape(3, 4)
        ring.put((arr, 1.25))
        ring.put(_Watermark("spout#0", 64.0))
        ring.put(_POISON)
        got, t0, lease = ring.get()
        assert got.tobytes() == arr.tobytes() and t0 == 1.25
        assert lease is None        # ring hand-off already owns its copy
        wm = ring.get()
        assert isinstance(wm, _Watermark)
        assert (wm.lane, wm.value) == ("spout#0", 64.0)
        assert ring.get() is _POISON          # sentinel survives by identity
        with pytest.raises(queue.Empty):
            ring.get_nowait()
    finally:
        ring.close()
        ring.unlink()


def _tag_of(ring, slot):
    return ring._buf[16 + slot * ring.slot_bytes]


@pytest.mark.parametrize("arr", [
    np.arange(7, dtype=np.int64),
    np.random.default_rng(0).random((3, 5)).astype(np.float32),
    np.zeros((2, 3, 4), dtype=np.uint16),
    np.array([True, False, True]),
    np.empty((0,), dtype=np.float64),          # empty batch
    np.empty((0, 8), dtype=np.int32),
], ids=["i64", "f32-2d", "u16-3d", "bool", "empty", "empty-2d"])
def test_ring_raw_roundtrip_preserves_bytes_dtype_shape(arr):
    ring = ShmRing(capacity=2, slot_bytes=8192)
    try:
        slot = ring._tail() % ring.capacity
        ring.put((arr, 2.5))
        assert _tag_of(ring, slot) == 0        # raw tag, no pickle
        got, t0, _ = ring.get()
        assert got.dtype == arr.dtype and got.shape == arr.shape
        assert got.tobytes() == np.ascontiguousarray(arr).tobytes()
        assert t0 == 2.5
    finally:
        ring.close()
        ring.unlink()


def test_ring_pickle_fallback_tag_parity():
    """Unregistered dtypes fall back to tagged pickle slots; registering
    them moves the same batch to the raw path — bytes identical either
    way.  ``raw=False`` forces the fallback everywhere (the A/B flag)."""
    sd = np.dtype([("key", "i8"), ("val", "f4")])
    s = np.zeros(5, sd)
    s["key"] = np.arange(5)
    s["val"] = 0.5
    u = np.array(["event", "spïke", ""], dtype="<U8")
    ring = ShmRing(capacity=4, slot_bytes=8192)
    try:
        for a in (s, u):                       # unregistered -> pickle tag
            slot = ring._tail() % ring.capacity
            ring.put((a, 1.0))
            assert _tag_of(ring, slot) == 1
            got, t0, _ = ring.get()
            assert got.dtype == a.dtype and got.tobytes() == a.tobytes()
        did = register_ring_dtype(sd)
        assert register_ring_dtype(sd) == did  # idempotent
        register_ring_dtype("<U8")
        for a in (s, u):                       # registered -> raw tag
            slot = ring._tail() % ring.capacity
            ring.put((a, 1.0))
            assert _tag_of(ring, slot) == 0
            got, t0, _ = ring.get()
            assert got.dtype == a.dtype and got.tobytes() == a.tobytes()
    finally:
        ring.close()
        ring.unlink()
    forced = ShmRing(capacity=2, slot_bytes=8192, raw=False)
    try:
        slot = forced._tail() % forced.capacity
        forced.put((np.arange(4.0), 3.0))      # registered dtype, still pickle
        assert _tag_of(forced, slot) == 1
        got, t0, _ = forced.get()
        assert got.tobytes() == np.arange(4.0).tobytes() and t0 == 3.0
    finally:
        forced.close()
        forced.unlink()


def test_ring_wrap_around_and_copy_counters():
    """Slots reuse cleanly past the wrap point (consumer copies before the
    head advance hands the slot back) and the byte counters account every
    copy on both sides."""
    ring = ShmRing(capacity=3, slot_bytes=4096)
    try:
        for k in range(10):                    # > 3 laps over 3 slots
            a = np.full(16, k, dtype=np.int64)
            ring.put((a, float(k)))
            got, t0, _ = ring.get()
            assert np.array_equal(got, a) and t0 == float(k)
        assert ring.put_slots == ring.get_slots == 10
        assert ring.put_tuples == ring.get_tuples == 160
        assert ring.put_bytes == ring.get_bytes == 10 * 16 * 8
    finally:
        ring.close()
        ring.unlink()


def test_ring_property_roundtrip():
    """Property-test the slot codec over random shapes/dtypes/offsets —
    every batch that fits must round-trip byte-identically, raw or
    fallback alike."""
    hyp = pytest.importorskip("hypothesis")
    hnp = pytest.importorskip("hypothesis.extra.numpy")
    from hypothesis import given, settings, strategies as st

    dtypes = st.sampled_from([np.dtype(s) for s in
                              ("int8", "uint32", "int64", "float32",
                               "float64", "complex64", "<U3")])
    shapes = st.lists(st.integers(0, 7), min_size=1, max_size=3).map(tuple)

    @settings(max_examples=60, deadline=None)
    @given(dt=dtypes, shape=shapes, data=st.data(),
           t0=st.floats(allow_nan=False, allow_infinity=False, width=32))
    def roundtrip(dt, shape, data, t0):
        arr = data.draw(hnp.arrays(dt, shape))
        ring = ShmRing(capacity=2, slot_bytes=1 << 14)
        try:
            ring.put((arr, float(t0)))
            got, got_t0, _ = ring.get()
            assert got.dtype == arr.dtype and got.shape == arr.shape
            assert got.tobytes() == np.ascontiguousarray(arr).tobytes()
            assert got_t0 == float(t0)
        finally:
            ring.close()
            ring.unlink()

    roundtrip()
    assert not _shm_leftovers()


def test_ring_backpressure_full_and_oversize():
    ring = ShmRing(capacity=2, slot_bytes=4096)
    try:
        a = np.zeros(8)
        ring.put((a, 0.0))
        ring.put((a, 0.0))
        t0 = time.perf_counter()
        with pytest.raises(queue.Full):
            ring.put((a, 0.0), timeout=0.05)   # full: bounded wait, then Full
        assert time.perf_counter() - t0 < 2.0
        with pytest.raises(ValueError, match="slot_bytes"):
            ring.put((np.zeros(4096), 0.0))    # never split, always explain
    finally:
        ring.close()
        ring.unlink()


def test_backend_registry():
    assert callable(get_backend("threads"))
    assert get_backend("processes") is run_app_processes
    with pytest.raises(ValueError, match="gpu.*processes.*threads"):
        get_backend("gpu")
    register_backend("test-noop", lambda app, **kw: None)
    try:
        assert get_backend("test-noop")(None) is None
    finally:
        del BACKENDS["test-noop"]


# ---------------------------------------------------------------------------
# The parity contract: threads vs processes, byte for byte
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("make_app", [word_count, linear_road,
                                      spike_detection_eventtime,
                                      spike_detection_keyed],
                         ids=["wc", "lr", "sd_et", "sd_key"])
def test_backend_parity_benchmark_apps(make_app):
    kw = dict(batch=128, max_batches=5, seed=3)
    rt = run_app(make_app(), **kw)
    rp = run_app_processes(make_app(), **kw)
    assert _summary(rt) == _summary(rp)
    assert _keyed_bytes(rt) == _keyed_bytes(rp)
    lg = make_app().graph
    assert _sink_scratch(rt, lg) == _sink_scratch(rp, lg)
    assert not _shm_leftovers()


def test_ring_format_parity_raw_vs_pickle():
    """The slot encoding is invisible to results: forcing every ring back
    to the pickle fallback (``ring_format="pickle"``) reproduces the raw
    default byte for byte — the invariant behind the serialization A/B."""
    kw = dict(batch=128, max_batches=5, seed=3)
    raw = run_app_processes(word_count(), ring_format="raw", **kw)
    pkl = run_app_processes(word_count(), ring_format="pickle", **kw)
    assert _summary(raw) == _summary(pkl)
    assert _keyed_bytes(raw) == _keyed_bytes(pkl)
    with pytest.raises(ValueError, match="ring_format"):
        run_app_processes(word_count(), ring_format="arrow", **kw)
    assert not _shm_leftovers()


def test_backend_parity_parallel_and_grouped():
    """Parity holds at parallelism > 1 for any worker grouping — solo
    workers (every edge a ring) and two-socket grouping (mixed local
    queues + rings) alike."""
    par = {"splitter": 2, "counter": 2}
    kw = dict(parallelism=par, batch=128, max_batches=5, seed=3)
    rt = run_app(word_count(), **kw)
    rp = run_app_processes(word_count(), **kw)
    groups = {"spout": 0, ("splitter", 0): 0, ("splitter", 1): 1,
              ("counter", 0): 0, ("counter", 1): 1, "sink": 1}
    rg = run_app_processes(word_count(), groups=groups, pin={0: [0], 1: [0]},
                           **kw)
    assert _summary(rt) == _summary(rp) == _summary(rg)
    assert _keyed_bytes(rt) == _keyed_bytes(rp) == _keyed_bytes(rg)
    assert not _shm_leftovers()


def test_pane_multiset_byte_parity_across_backends():
    """Keyed event-time pane *contents* cross the rings byte-identically:
    a recording sink keeps every pane-aggregate row it receives; the
    multiset of row bytes matches the threaded run exactly."""
    def recording_sink(batch, state):
        state.setdefault("rows", []).extend(
            np.ascontiguousarray(r).tobytes() for r in batch)
        return []

    def run(backend):
        app = spike_detection_keyed()
        app.kernels["sink"] = recording_sink
        r = backend(app, batch=128, max_batches=5, seed=3)
        return sorted(r.states["sink"][0]["rows"]), r.panes_fired

    rows_t, panes_t = run(run_app)
    rows_p, panes_p = run(run_app_processes)
    assert panes_t == panes_p > 0
    assert rows_t == rows_p


def test_plan_execute_backend_dispatch_and_placement():
    plan = Job(word_count()).plan(server_a(), optimizer="ff")
    kw = dict(batch=128, batches=5, seed=3, max_threads=6)
    rt = plan.execute(**kw)
    rp = plan.execute(backend="processes", **kw)              # faithful
    rf = plan.execute(backend="processes", faithful=False, **kw)
    for m in (rt, rp, rf):
        assert m.raw.spout_tuples > 0
    assert _summary(rt.raw) == _summary(rp.raw) == _summary(rf.raw)
    assert _keyed_bytes(rt.raw) == _keyed_bytes(rp.raw) == _keyed_bytes(rf.raw)
    with pytest.raises(ValueError, match="unknown execution backend"):
        plan.execute(backend="fpga")
    with pytest.raises(ValueError, match="backend='processes'"):
        plan.execute(env={"X": "1"})          # env is a worker-process knob


def test_plan_placement_groups_follow_socket_map():
    plan = Job(word_count()).plan(server_a(), optimizer="ff")
    par = {op: 1 for op in plan.parallelism}
    groups, pins = plan_placement(plan, par)
    assert set(groups) == {(op, 0) for op in par}
    sockets = set(groups.values())
    assert all(0 <= s < plan.machine.n_sockets for s in sockets)
    # pins partition the host cores over the plan's sockets
    assert set().union(*pins.values()) <= set(os.sched_getaffinity(0))


# ---------------------------------------------------------------------------
# State across process boundaries: migration round trip
# ---------------------------------------------------------------------------

def test_migration_round_trip_through_process_backend():
    """The WC conservation contract (test_state) with both execution legs
    on the process backend: interrupted + replanned + migrated equals the
    uninterrupted threaded single-replica run, byte for byte."""
    total, cut, seed = 8, 3, 42
    app = word_count()
    ref = run_app(word_count(), {n: 1 for n in app.graph.operators},
                  batch=64, max_batches=total, seed=seed)
    ref_counts = ref.states["counter"][0].managed.table

    job = Job(app)
    par1 = {"spout": 1, "parser": 1, "splitter": 2, "counter": 3, "sink": 1}
    plan1 = job.plan(server_a(), optimizer="ff", parallelism=par1)
    r1 = plan1.execute(batches=cut, batch=64, seed=seed, parallelism=par1,
                       backend="processes").raw

    plan2 = plan1.replan(subset(server_a(), 2))
    par2 = {"spout": 1, "parser": 1, "splitter": 1, "counter": 2, "sink": 1}
    seeded = migrate_states(app, r1.states, par2)
    r2 = plan2.execute(batches=total - cut, batch=64, seed=seed + cut,
                       parallelism=par2, initial_states=seeded,
                       backend="processes").raw

    merged = merge_keyed([st.managed for st in r2.states["counter"]])
    assert merged.tobytes() == ref_counts.tobytes()
    assert r1.spout_tuples + r2.spout_tuples == ref.spout_tuples
    assert not _shm_leftovers()


# ---------------------------------------------------------------------------
# Failure paths: crashes and wedges must not orphan segments
# ---------------------------------------------------------------------------

def _chain_app(kernel):
    return (Topology("chain")
            .spout("s", lambda b, sd: np.random.default_rng(sd)
                   .normal(size=b).astype(np.float64), exec_ns=100.0)
            .op("work", kernel, exec_ns=100.0)
            .sink("sink", lambda b, st: [], exec_ns=50.0)
            .build())


def test_worker_crash_raises_and_cleans_up():
    def exploding(batch, state):
        state["n"] = state.get("n", 0) + 1
        if state["n"] >= 2:
            raise RuntimeError("kaboom in worker")
        return [batch]

    with pytest.raises(RuntimeError, match="kaboom in worker"):
        run_app_processes(_chain_app(exploding), batch=32, max_batches=6,
                          seed=0, timeout=30.0)
    assert not _shm_leftovers()


def test_wedged_worker_times_out_fast_and_cleans_up():
    def wedged(batch, state):
        time.sleep(60.0)
        return [batch]

    t0 = time.perf_counter()
    with pytest.raises(TimeoutError, match="deadline"):
        run_app_processes(_chain_app(wedged), batch=32, max_batches=4,
                          seed=0, timeout=2.0)
    assert time.perf_counter() - t0 < 20.0    # fail fast, not join_timeout
    assert not _shm_leftovers()


# ---------------------------------------------------------------------------
# Worker environment: pinning, env injection, the JAX host-device variant
# ---------------------------------------------------------------------------

def test_env_and_affinity_reach_the_worker():
    def observer(batch, state):
        if "env" not in state:
            state["env"] = os.environ.get("PROCEXEC_TEST_FLAG", "")
            state["affinity"] = sorted(os.sched_getaffinity(0))
        return [batch]

    host = sorted(os.sched_getaffinity(0))
    groups = {"s": 0, "work": 0, "sink": 0}
    r = run_app_processes(_chain_app(observer), batch=32, max_batches=3,
                          seed=0, groups=groups, pin={0: [host[0]]},
                          env={"PROCEXEC_TEST_FLAG": "on"})
    st = r.states["work"][0]
    assert st["env"] == "on"                   # injected pre-kernel
    assert st["affinity"] == [host[0]]         # sched_setaffinity applied
    assert os.environ.get("PROCEXEC_TEST_FLAG") is None   # parent untouched


def test_host_device_env_composes_xla_flags():
    env = host_device_env(4)
    assert "--xla_force_host_platform_device_count=4" in env["XLA_FLAGS"]
    assert "TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD" in env
    # an existing count flag is replaced, other flags preserved
    old = os.environ.get("XLA_FLAGS")
    os.environ["XLA_FLAGS"] = \
        "--xla_cpu_enable_fast_math=true " \
        "--xla_force_host_platform_device_count=2"
    try:
        env = host_device_env(8, base={"A": "b"})
        assert env["A"] == "b"
        assert "--xla_cpu_enable_fast_math=true" in env["XLA_FLAGS"]
        assert "device_count=8" in env["XLA_FLAGS"]
        assert "device_count=2" not in env["XLA_FLAGS"]
    finally:
        if old is None:
            del os.environ["XLA_FLAGS"]
        else:
            os.environ["XLA_FLAGS"] = old


def test_socket_core_map_round_robin():
    assert socket_core_map(2, cores=[0, 1, 2, 3, 4]) == \
        {0: [0, 2, 4], 1: [1, 3]}
    # more sockets than cores: empty buckets dropped (those workers float)
    assert socket_core_map(4, cores=[7]) == {0: [7]}


def test_socket_core_map_numa_topology(tmp_path, monkeypatch):
    """With a multi-node sysfs tree, modelled sockets map onto whole NUMA
    nodes (affinity-intersected) instead of round-robining blindly; a
    single-node or absent tree falls back to round-robin."""
    for node, cpulist in [("node0", "0-3,8-9"), ("node1", "4-7"),
                          ("node7x", "ignored")]:     # non-numeric suffix
        d = tmp_path / node
        d.mkdir()
        (d / "cpulist").write_text(cpulist + "\n")
    monkeypatch.setattr(os, "sched_getaffinity",
                        lambda pid: {0, 1, 2, 3, 4, 5, 6, 7, 8, 9})
    m = socket_core_map(2, sysfs=str(tmp_path))
    assert m == {0: [0, 1, 2, 3, 8, 9], 1: [4, 5, 6, 7]}
    # more modelled sockets than nodes: wrap around the nodes
    m4 = socket_core_map(4, sysfs=str(tmp_path))
    assert m4[0] == m4[2] == [0, 1, 2, 3, 8, 9]
    assert m4[1] == m4[3] == [4, 5, 6, 7]
    # affinity mask hides node1 entirely -> single visible node -> fallback
    monkeypatch.setattr(os, "sched_getaffinity", lambda pid: {0, 1, 8})
    assert socket_core_map(2, sysfs=str(tmp_path)) == {0: [0, 8], 1: [1]}
    # absent tree -> plain round-robin over the affinity set
    monkeypatch.setattr(os, "sched_getaffinity", lambda pid: {3, 5})
    assert socket_core_map(2, sysfs=str(tmp_path / "missing")) == \
        {0: [3], 1: [5]}
    # explicit cores= always bypasses topology
    assert socket_core_map(2, cores=[1, 2, 3], sysfs=str(tmp_path)) == \
        {0: [1, 3], 1: [2]}
