"""Async device-dispatch pipeline (ISSUE 8).

The contract: ``device=True`` operators enqueue their (lazy) kernel result
into a bounded in-flight window of ``dispatch_depth`` and materialize
results FIFO — overlapping host ingest with device compute — while staying
*invisible to results*: depth 1 and depth N are byte-identical under
deterministic replay, watermarks never overtake the batches they trail
(retire-before-mark), and the planner/DES price the overlap as
``max(host, device/depth)`` so modeled throughput moves with depth in the
measured direction.  The jitted-predictor end-to-end tests run on CPU-only
hosts (XLA host platform) and skip cleanly without jax.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import ExecutionGraph, server_a
from repro.streaming.api import Topology, TopologyError
from repro.streaming.apps import inf_model_weights, streaming_inference
from repro.streaming.runtime import resolve_offsets, run_app
from repro.streaming.simulator import des_simulate, fluid_solve
from repro.streaming.state import StateSpec, WindowSpec, segmented


def _src(batch, seed):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(batch, 4))


# ---------------------------------------------------------------------------
# declaration + validation
# ---------------------------------------------------------------------------

def _topo(**op_kw):
    return (Topology("t")
            .spout("s", _src, exec_ns=100.0)
            .op("d", lambda b, st: [b], exec_ns=500.0, **op_kw)
            .sink("k", lambda b, st: [], exec_ns=100.0)
            .build())


def test_device_op_declaration_carries_through():
    app = _topo(device=True, device_ns=4000.0, dispatch_depth=3)
    sp = app.graph.operators["d"]
    assert sp.device and sp.device_ns == 4000.0 and sp.dispatch_depth == 3
    assert app.device_ops() == {"d": 3}
    assert _topo().device_ops() == {}


def test_device_validation_rejects_bad_declarations():
    with pytest.raises(TopologyError, match="dispatch_depth"):
        _topo(device=True, dispatch_depth=0)
    with pytest.raises(TopologyError, match="dispatch_depth"):
        _topo(device=True, dispatch_depth=2.5)
    with pytest.raises(TopologyError, match="dispatch_depth"):
        _topo(device=True, dispatch_depth=True)
    # device knobs without device=True are declaration bugs, not defaults
    with pytest.raises(TopologyError, match="device"):
        _topo(device_ns=1000.0)
    with pytest.raises(TopologyError, match="device"):
        _topo(dispatch_depth=2)
    with pytest.raises(TopologyError, match="device_ns"):
        _topo(device=True, device_ns=-1.0)


def test_device_excludes_windowed_and_segmented_kernels():
    win_state = StateSpec("value", item_bytes=16.0,
                          window=WindowSpec.time_sliding(16.0, 8.0,
                                                         time_by=0))
    with pytest.raises(TopologyError, match="window"):
        (Topology("t")
         .spout("s", _src, exec_ns=100.0, event_time=0, watermark_every=2)
         .op("d", lambda b, st: [b], exec_ns=500.0, device=True,
             state=win_state)
         .sink("k", lambda b, st: [], exec_ns=100.0).build())

    @segmented
    def k_seg(stack, state):
        return [stack]

    with pytest.raises(TopologyError, match="segmented"):
        (Topology("t")
         .spout("s", _src, exec_ns=100.0)
         .op("d", k_seg, exec_ns=500.0, device=True)
         .sink("k", lambda b, st: [], exec_ns=100.0).build())


# ---------------------------------------------------------------------------
# planner/DES pricing: exec_s = max(host, device/depth)
# ---------------------------------------------------------------------------

def test_exec_s_prices_the_overlap_window():
    sync = _topo(device=True, device_ns=4000.0).graph.operators["d"]
    assert sync.exec_s == pytest.approx((500.0 + 4000.0) * 1e-9)
    d4 = _topo(device=True, device_ns=4000.0,
               dispatch_depth=4).graph.operators["d"]
    assert d4.exec_s == pytest.approx(max(500.0, 4000.0 / 4) * 1e-9)
    host_bound = _topo(device=True, device_ns=400.0,
                       dispatch_depth=8).graph.operators["d"]
    assert host_bound.exec_s == pytest.approx(500.0 * 1e-9)
    assert _topo().graph.operators["d"].exec_s == pytest.approx(500e-9)


@pytest.mark.parametrize("oracle", ["fluid", "des"])
def test_modeled_throughput_moves_with_dispatch_depth(oracle):
    """The measured direction: deeper dispatch windows raise the device
    operator's service rate, so modeled saturation throughput rises."""
    def capacity(depth):
        app = _topo(device=True, device_ns=4000.0, dispatch_depth=depth)
        g = ExecutionGraph(app.graph, {n: 1 for n in app.graph.operators})
        if oracle == "fluid":
            return fluid_solve(g, server_a(), [0] * g.n_units,
                               input_rate=None).R
        return des_simulate(g, server_a(), [0] * g.n_units,
                            input_rate=2e6, horizon=0.02).R

    r1, r4 = capacity(1), capacity(4)
    assert r4 > r1 * 1.5, (r1, r4)


def test_des_depth_direction_on_inference_app():
    def cap(depth):
        app = streaming_inference(dispatch_depth=depth)
        g = ExecutionGraph(app.graph, {n: 1 for n in app.graph.operators})
        return des_simulate(g, server_a(), [0] * g.n_units,
                            input_rate={"spout": 1e6, "model_spout": 10.0},
                            horizon=0.02).R

    assert cap(4) > cap(1) * 1.2


# ---------------------------------------------------------------------------
# executor semantics (no jax needed: device flag == async window + FIFO
# materialization; a numpy kernel exercises the exact same code path)
# ---------------------------------------------------------------------------

def _fingerprint(res):
    sink = res.states["k"][0]
    return (res.spout_tuples, res.sink_tuples,
            {k: v for k, v in sink.items() if np.isscalar(v)})


def test_depth_is_invisible_to_results():
    def make(depth):
        return (Topology("t")
                .spout("s", _src, exec_ns=100.0)
                .op("d", lambda b, st: [b * 2.0], exec_ns=500.0,
                    device=True, device_ns=2000.0, dispatch_depth=depth)
                .sink("k", lambda b, st: st.__setitem__(
                    "sum", st.get("sum", 0.0) + float(b.sum())) or [],
                    exec_ns=100.0)
                .build())

    fps = [_fingerprint(run_app(make(d), {}, batch=32, max_batches=25))
           for d in (1, 2, 5)]
    assert fps[0] == fps[1] == fps[2]
    # the run_app override wins over the declared depth
    fp = _fingerprint(run_app(make(1), {}, batch=32, max_batches=25,
                              dispatch_depth=4))
    assert fp == fps[0]


def test_watermarks_never_overtake_inflight_batches():
    """Retire-before-mark: a device op upstream of an event-time window
    must flush its in-flight window before forwarding a watermark, or
    panes would see their tuples arrive 'late'.  Pane contents and late
    drops must be depth-invariant."""
    def source(batch, seed):
        ets = np.abs(seed) * batch + np.arange(batch, dtype=np.float64)
        vals = np.full(batch, float(seed % 7))
        return np.stack([ets, vals], axis=1)

    @segmented
    def k_panes(stack, state):
        seg = state.segments
        tot = np.add.reduceat(stack[:, 1], seg.starts)
        return [np.stack([seg.spans[:, 1], tot], axis=1)]

    def make(depth):
        return (Topology("t")
                .spout("s", source, exec_ns=100.0, event_time=0,
                       watermark_every=2)
                .op("d", lambda b, st: [b], exec_ns=300.0, device=True,
                    device_ns=1500.0, dispatch_depth=depth)
                .op("w", k_panes, exec_ns=500.0,
                    state=StateSpec("value", item_bytes=16.0,
                                    window=WindowSpec.time_sliding(
                                        32.0, 16.0, time_by=0)))
                .sink("k", lambda b, st: st.__setitem__(
                    "tot", st.get("tot", 0.0) + float(b[:, 1].sum())) or [],
                    exec_ns=100.0)
                .build())

    runs = [run_app(make(d), {}, batch=16, max_batches=30) for d in (1, 4)]
    assert runs[0].late_drops == runs[1].late_drops == 0
    assert runs[0].panes_fired == runs[1].panes_fired > 0
    assert _fingerprint(runs[0]) == _fingerprint(runs[1])


# ---------------------------------------------------------------------------
# jitted predictor end to end (CPU-only XLA host platform)
# ---------------------------------------------------------------------------

def test_inference_depth_parity_and_oracle():
    pytest.importorskip("jax")
    from repro.kernels.ref import mlp_ref

    app = streaming_inference(model_versions=1)
    r1 = run_app(app, {}, batch=16, max_batches=25, dispatch_depth=1)
    r3 = run_app(app, {}, batch=16, max_batches=25, dispatch_depth=3)
    s1, s3 = r1.states["sink"][0], r3.states["sink"][0]
    assert s1["seen"] == s3["seen"] == 25 * 16
    assert s1["score"] == s3["score"]          # byte-identical accumulation
    assert r1.spout_offsets == {"spout#0": 25, "model_spout#0": 25}

    # oracle: recompute every deterministic sensor batch through the
    # *un-jitted* reference the predictor jits
    w = inf_model_weights(0)
    total = 0.0
    for b in range(25):
        rng = np.random.default_rng(b)
        x = rng.normal(size=(16, 32)).astype(np.float32)
        total += float(np.asarray(mlp_ref(x, w), np.float64).sum())
    assert s1["score"] == pytest.approx(total, rel=1e-9)


def test_process_backend_requires_jax_clean_parent():
    pytest.importorskip("jax")            # this import *is* the hazard
    from repro.streaming.procexec import run_app_processes
    with pytest.raises(RuntimeError, match="[Jj][Aa][Xx]"):
        run_app_processes(streaming_inference(model_versions=1), {},
                          batch=16, max_batches=2)


def test_process_backend_device_parity_in_clean_subprocess():
    pytest.importorskip("jax")
    child = (
        "import json, sys\n"
        "from repro.streaming.apps import streaming_inference\n"
        "from repro.streaming.procexec import run_app_processes\n"
        "from repro.streaming.runtime import run_app\n"
        "out = []\n"
        "# processes first: the guard demands a jax-clean parent, and the\n"
        "# threads run imports jax into this process\n"
        "for runner, depth in [(run_app_processes, 2), (run_app, 1)]:\n"
        "    r = runner(streaming_inference(model_versions=1), {},\n"
        "               batch=16, max_batches=10, dispatch_depth=depth)\n"
        "    s = r.states['sink'][0]\n"
        "    out.append([r.spout_tuples, r.sink_tuples, int(s['seen']),\n"
        "                float(s['score']).hex()])\n"
        "print(json.dumps(out))\n")
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + \
        env.get("PYTHONPATH", "")
    cp = subprocess.run([sys.executable, "-c", child], capture_output=True,
                        text=True, env=env, timeout=240)
    assert cp.returncode == 0, cp.stderr[-2000:]
    import json
    threads, procs = json.loads(cp.stdout.strip().splitlines()[-1])
    assert threads == procs


# ---------------------------------------------------------------------------
# spout offset hand-off (ROADMAP 1b)
# ---------------------------------------------------------------------------

def test_spout_offsets_resume_prefix_continuation():
    """run(10) then resume run(5) from its offsets+states == run(15)."""
    from repro.streaming.apps import word_count
    from repro.streaming.state import KeyedStore, merge_keyed

    par = {"splitter": 2, "counter": 4}

    def counter_bytes(res):
        return merge_keyed([s.managed for s in res.states["counter"]
                            if isinstance(s.managed, KeyedStore)]).tobytes()

    first = run_app(word_count(), par, batch=64, max_batches=10)
    assert first.spout_offsets == {"spout#0": 10}
    # hand the first run's replica states straight in (the migrate_states
    # path would re-shard them; here parallelism is unchanged)
    resumed = run_app(word_count(), par, batch=64, max_batches=5,
                      initial_offsets=first.spout_offsets,
                      initial_states=first.states)
    whole = run_app(word_count(), par, batch=64, max_batches=15)
    assert resumed.spout_offsets == whole.spout_offsets == {"spout#0": 15}
    assert counter_bytes(resumed) == counter_bytes(whole)
    assert first.spout_tuples + resumed.spout_tuples == whole.spout_tuples


def test_resolve_offsets_accepts_names_and_replica_uids():
    lg = streaming_inference().graph
    par = {n: 1 for n in lg.operators}
    par["spout"] = 2
    out = resolve_offsets(lg, par, {"spout": 7, "model_spout#0": 3})
    assert out == {("spout", 0): 7, ("spout", 1): 7, ("model_spout", 0): 3}
    # replica uid overrides the operator-wide default
    out = resolve_offsets(lg, par, {"spout": 7, "spout#1": 2})
    assert out == {("spout", 0): 7, ("spout", 1): 2}
    assert resolve_offsets(lg, par, None) == {}


def test_resolve_offsets_validation():
    lg = streaming_inference().graph
    par = {n: 1 for n in lg.operators}
    with pytest.raises(ValueError, match="not a spout"):
        resolve_offsets(lg, par, {"predictor": 1})
    with pytest.raises(ValueError, match="not a spout"):
        resolve_offsets(lg, par, {"nope": 1})
    with pytest.raises(ValueError, match="int >= 0"):
        resolve_offsets(lg, par, {"spout": -1})
    with pytest.raises(ValueError, match="int >= 0"):
        resolve_offsets(lg, par, {"spout": True})
    with pytest.raises(ValueError, match="parallelism"):
        resolve_offsets(lg, par, {"spout#1": 4})
