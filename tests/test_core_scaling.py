"""Algorithm 1 (joint replication + placement) behaviour tests."""
import dataclasses

import pytest

from repro.core import (ExecutionGraph, LogicalGraph, OperatorSpec, evaluate,
                        rlas_optimize, server_a, subset)


def pipeline(te_spout, *te_ops, nbytes=64.0):
    ops = {"spout": OperatorSpec("spout", te_spout, nbytes, nbytes,
                                 is_spout=True)}
    edges = []
    prev = "spout"
    for i, te in enumerate(te_ops):
        name = f"op{i}"
        ops[name] = OperatorSpec(name, te, nbytes, nbytes)
        edges.append((prev, name))
        prev = name
    return LogicalGraph(ops, edges)


def small_machine(n_sockets=2, cores=4):
    return dataclasses.replace(subset(server_a(), n_sockets),
                               cores_per_socket=cores)


def test_scaling_removes_bottleneck():
    # sink is 4x slower than spout -> needs ~4 replicas
    m = small_machine(n_sockets=2, cores=6)
    lg = pipeline(100.0, 400.0)
    res = rlas_optimize(lg, m, input_rate=None)
    assert res.parallelism["op0"] >= 4
    # scaling must at least reach the single-spout rate, and keep the
    # replication ratio near the 4x service-time ratio
    assert res.R >= 1e7 * 0.95
    assert res.parallelism["op0"] >= 3 * res.parallelism["spout"]


def test_scaling_scales_spout_when_input_unbounded():
    # spout is the slow stage; ops are fast
    m = small_machine(n_sockets=2, cores=6)
    lg = pipeline(800.0, 100.0)
    res = rlas_optimize(lg, m, input_rate=None)
    assert res.parallelism["spout"] >= 2
    assert res.R > 1.25e6                     # better than 1-replica 1/800ns


def test_scaling_respects_thread_budget():
    m = small_machine(n_sockets=1, cores=4)
    lg = pipeline(100.0, 1000.0)              # would want 10 sink replicas
    res = rlas_optimize(lg, m, input_rate=None)
    assert res.graph.total_threads() <= m.total_cores
    assert res.placement.feasible


def test_scaling_bounded_input_stops_at_ingress():
    m = small_machine(n_sockets=2, cores=8)
    lg = pipeline(100.0, 100.0)
    res = rlas_optimize(lg, m, input_rate=5e5)
    # system easily keeps up with 5e5 t/s; no scaling needed
    assert res.R == pytest.approx(5e5)
    assert all(k == 1 for k in res.parallelism.values())


def test_history_monotone_best_kept():
    m = small_machine(n_sockets=2, cores=6)
    lg = pipeline(100.0, 400.0, 200.0)
    res = rlas_optimize(lg, m, input_rate=None)
    best_seen = max(r for _, r in res.history)
    assert res.R == pytest.approx(best_seen)


def test_compression_ratio_speeds_up_search():
    m = server_a()
    lg = pipeline(50.0, 500.0, 500.0)
    fine = rlas_optimize(lg, m, input_rate=None, compress_ratio=1,
                         max_threads=40, bestfit=True)
    coarse = rlas_optimize(lg, m, input_rate=None, compress_ratio=5,
                           max_threads=40, bestfit=True)
    assert coarse.R > 0
    # coarse search visits far fewer nodes in its final placement
    assert coarse.placement.nodes_explored <= fine.placement.nodes_explored
