"""Blockwise-jnp kernel paths vs. naive oracles (shape/dtype sweeps)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import compiled_flops
from repro.kernels import ops, ref


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize("b,hq,hkv,sq,skv,d", [
    (1, 4, 4, 64, 64, 32),
    (2, 8, 2, 128, 128, 64),       # GQA 4:1
    (1, 4, 1, 64, 256, 32),        # MQA, kv longer than q (prefill tail)
])
@pytest.mark.parametrize("causal,window", [
    (True, None), (False, None), (True, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_jnp_matches_ref(b, hq, hkv, sq, skv, d, causal, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    offset = skv - sq
    q = rand(ks[0], (b, hq, sq, d), dtype)
    k = rand(ks[1], (b, hkv, skv, d), dtype)
    v = rand(ks[2], (b, hkv, skv, d), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              offset=offset, impl="jnp", q_chunk=32,
                              kv_chunk=64)
    exp = ref.attention_ref(q, k, v, causal=causal, window=window,
                            offset=offset)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol, rtol=tol)


def test_flash_jnp_block_skipping_reduces_flops():
    """Causal block skipping must show up in compiled FLOPs (~2x saving)."""
    b, h, s, d = 1, 4, 512, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q, k, v = (rand(ks[i], (b, h, s, d)) for i in range(3))

    def cost(causal):
        fn = lambda q, k, v: ops.flash_attention(
            q, k, v, causal=causal, impl="jnp", q_chunk=64, kv_chunk=64)
        return compiled_flops(jax.jit(fn).lower(q, k, v).compile())

    assert cost(True) < 0.65 * cost(False)


def test_decode_attention_matches_ref_lengths():
    b, hq, hkv, s, d = 4, 8, 2, 128, 64
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = rand(ks[0], (b, hq, d))
    k = rand(ks[1], (b, hkv, s, d))
    v = rand(ks[2], (b, hkv, s, d))
    length = jnp.array([128, 64, 1, 100], jnp.int32)
    out = ops.decode_attention(q, k, v, length=length)
    exp = ref.decode_attention_ref(q, k, v, length=length)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mamba_scan_matches_ref(dtype):
    bt, t, d_in, n = 2, 16, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    u = rand(ks[0], (bt, t, d_in), dtype)
    dt = jax.nn.softplus(rand(ks[1], (bt, t, d_in), dtype))
    A = -jax.nn.softplus(rand(ks[2], (d_in, n)))
    B = rand(ks[3], (bt, t, n), dtype)
    C = rand(ks[4], (bt, t, n), dtype)
    D = jnp.ones((d_in,))
    y, h = ops.mamba_scan(u, dt, A, B, C, D, impl="jnp")
    y_ref, h_ref = ref.mamba_scan_ref(u, dt, A, B, C, D)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), atol=tol,
                               rtol=tol)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), atol=1e-4)


def test_mamba_step_consistent_with_scan():
    bt, t, d_in, n = 2, 8, 4, 4
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    u = rand(ks[0], (bt, t, d_in))
    dt = jax.nn.softplus(rand(ks[1], (bt, t, d_in)))
    A = -jax.nn.softplus(rand(ks[2], (d_in, n)))
    B = rand(ks[3], (bt, t, n))
    C = rand(ks[4], (bt, t, n))
    D = jnp.ones((d_in,))
    y_scan, h_scan = ops.mamba_scan(u, dt, A, B, C, D)
    h = jnp.zeros((bt, d_in, n), jnp.float32)
    ys = []
    for i in range(t):
        y, h = ops.mamba_step(u[:, i], dt[:, i], A, B[:, i], C[:, i], D, h)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                               np.asarray(y_scan), atol=1e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_scan), atol=1e-5)


def test_rmsnorm_shapes_dtypes():
    for shape in [(4, 8), (2, 16, 32)]:
        for dtype in [jnp.float32, jnp.bfloat16]:
            x = rand(jax.random.PRNGKey(0), shape, dtype)
            s = jnp.ones(shape[-1])
            out = ops.rmsnorm(x, s)
            assert out.shape == shape and out.dtype == dtype


def test_cp_flash_attention_matches_ref():
    """Ring context-parallel attention == naive oracle (1-device mesh uses
    the same code path structure; multi-shard covered by the dry-run)."""
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    b, h, s, d = 2, 4, 128, 32
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q, k, v = (rand(ks[i], (b, h, s, d)) for i in range(3))
    for window in [None, 48]:
        out = ops.cp_flash_attention(q, k, v, mesh, causal=True,
                                     window=window, q_chunk=32, kv_chunk=32)
        exp = ref.attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   atol=2e-5, rtol=2e-5)
