"""Segmented pane execution: one vectorized window engine for every kind.

The ISSUE 5 acceptance contract: when a watermark releases N panes, the
engine builds ONE stacked buffer + segment index and the kernel runs once —
byte-identical to driving the same math one pane at a time, across window
kinds (count tumbling/sliding, time tumbling/sliding), keyed and unkeyed
panes, shuffled-within-lateness input, and parallelism 1 vs k.  Keyed
event-time windows extend the PR 3 store-union invariant to panes: the
union of a replicated run's (key, span) panes equals the single-replica
run's, byte for byte.
"""
import math

import numpy as np
import pytest

from repro.core import server_a
from repro.streaming import Job
from repro.streaming.api import Topology, TopologyError
from repro.streaming.apps import (shuffle_within_skew,
                                  spike_detection_eventtime,
                                  spike_detection_keyed)
from repro.streaming.routing import VEC_CROSSOVER, RouteSpec, auto_vectorized
from repro.streaming.runtime import Executor, run_app
from repro.streaming.simulator import des_simulate, probe_et_spacing
from repro.streaming.state import (EventTimeWindowState, PaneBatch,
                                   PaneSegments, StateSpec, WindowSpec,
                                   WindowState, gather_segments, segmented)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# the substrate: gather_segments + PaneBatch/PaneSegments
# ---------------------------------------------------------------------------

def test_gather_segments_contiguous_is_zero_copy():
    rows = np.arange(12.0)
    stacked, offsets = gather_segments(rows, np.array([2, 5, 8]),
                                       np.array([5, 8, 11]))
    assert stacked.base is rows or stacked.base is rows.base  # a view
    assert np.array_equal(stacked, rows[2:11])
    assert offsets.tolist() == [0, 3, 6, 9]


def test_gather_segments_overlapping_gathers_once():
    rows = np.arange(10.0)
    los, his = np.array([0, 2, 4]), np.array([6, 8, 10])
    stacked, offsets = gather_segments(rows, los, his)
    assert offsets.tolist() == [0, 6, 12, 18]
    for i, (lo, hi) in enumerate(zip(los, his)):
        assert np.array_equal(stacked[offsets[i]:offsets[i + 1]],
                              rows[lo:hi])


def test_gather_segments_empty():
    stacked, offsets = gather_segments(np.arange(4.0), np.zeros(0, np.int64),
                                       np.zeros(0, np.int64))
    assert len(stacked) == 0 and offsets.tolist() == [0]


def test_pane_batch_iteration_is_the_segment_view():
    """Iterating a PaneBatch recovers exactly the per-segment slices — the
    compat contract and the segmented contract cannot drift apart."""
    st_ = EventTimeWindowState(WindowSpec.time_sliding(6.0, 3.0))
    rng = np.random.default_rng(0)
    st_.insert(rng.uniform(0, 50, size=200), 0.0)
    batch = st_.on_watermark(40.0)
    assert isinstance(batch, PaneBatch) and batch.n > 1
    off = batch.segments.offsets
    for i, (rows, t0, span) in enumerate(batch):
        assert np.array_equal(rows, batch.rows[off[i]:off[i + 1]])
        assert span == batch.segments.span(i)
        assert t0 == batch.t0s[i]
    assert batch.t0 == batch.t0s.min()
    # spans ascend (canonical pane order) and reduceat starts line up
    assert np.all(np.diff(batch.segments.spans[:, 1]) > 0)
    assert np.array_equal(batch.segments.starts, off[:-1])


def test_count_tumble_is_the_degenerate_segmented_case():
    """WindowState.tumble is a split of tumble_segments — same windows as
    the seed loop, spans labelled with arrival indices."""
    spec = WindowSpec(size=5, slide=2)
    a, b = WindowState(spec), WindowState(spec)
    rng = np.random.default_rng(1)
    base = 0
    for n in (3, 7, 1, 12, 4):
        batch = rng.normal(size=n)
        wins = a.tumble(batch)
        stacked, seg = b.tumble_segments(batch)
        assert len(wins) == seg.n
        for i, w in enumerate(wins):
            assert np.array_equal(
                w, stacked[seg.offsets[i]:seg.offsets[i + 1]])
            lo, hi = seg.span(i)
            assert hi - lo == 5 and lo >= base
        base = seg.spans[-1, 0] if seg.n else base


def test_seed_tumble_semantics_preserved():
    """The re-expressed tumble matches the seed while-loop byte for byte."""
    spec = WindowSpec(size=4, slide=4)
    w = WindowState(spec)
    out = w.tumble(np.arange(10.0))
    assert [o.tolist() for o in out] == [[0, 1, 2, 3], [4, 5, 6, 7]]
    out = w.tumble(np.arange(10.0, 14.0))
    assert [o.tolist() for o in out] == [[8, 9, 10, 11]]


# ---------------------------------------------------------------------------
# byte-identity: segmented call == pane-at-a-time drive (the tentpole)
# ---------------------------------------------------------------------------

def _pane_math_single(vals):
    """Per-pane aggregates in the exact reduction order the segmented
    kernel's reduceat uses, so bit-level comparison is meaningful."""
    s = float(np.add.reduceat(vals, np.array([0]))[0])
    mx = float(np.maximum.reduceat(vals, np.array([0]))[0])
    return s / len(vals), mx


def _et_app(spec: WindowSpec, seg: bool, skew: float = 6.0,
            keyed_route: bool = False):
    """A sensor topology over [et, key, val] rows whose window kernel runs
    either segmented (one stacked call) or single-span (the compat shim)."""
    def source(batch, seed):
        rng = np.random.default_rng(seed)
        ets = np.abs(seed) * batch + np.arange(batch, dtype=np.float64)
        keys = rng.integers(0, 5, size=batch).astype(np.float64)
        vals = rng.normal(size=batch)
        rows = np.stack([ets, keys, vals], axis=1)
        return rows[shuffle_within_skew(ets, skew, rng)]

    @segmented
    def k_seg(stack, state):
        sgs = state.segments
        vals = stack[:, 2]
        avg = np.add.reduceat(vals, sgs.starts) / sgs.lengths
        mx = np.maximum.reduceat(vals, sgs.starts)
        keys = sgs.keys.astype(np.float64) if sgs.keys is not None \
            else np.zeros(sgs.n)
        return [np.stack([sgs.spans[:, 1], keys, avg, mx], axis=1)]

    def k_one(pane, state):
        avg, mx = _pane_math_single(pane[:, 2])
        key = float(pane[0, 1]) if spec.keyed else 0.0
        return [np.array([[state.pane[1], key, avg, mx]])]

    def k_sink(batch, state):
        state.setdefault("rows", []).append(batch.copy())
        return []

    t = (Topology("seg-vs-one")
         .spout("s", source, exec_ns=100.0, event_time=0)
         .op("w", k_seg if seg else k_one, exec_ns=100.0,
             partition="key" if keyed_route else "shuffle",
             key_by=1 if keyed_route else None,
             state=StateSpec("value", window=spec))
         .sink("sink", k_sink, exec_ns=50.0))
    return t.build()


def _sink_rows(app, parallelism=None, batches=5, batch=48, seed=2):
    res = run_app(app, parallelism or {n: 1 for n in app.graph.operators},
                  batch=batch, max_batches=batches, seed=seed)
    chunks = [c for st_ in res.states["sink"]
              for c in st_.get("rows", [])]
    return (np.concatenate(chunks) if chunks else np.zeros((0, 4))), res


@pytest.mark.parametrize("spec", [
    WindowSpec.time_tumbling(16.0, lateness=6.0, time_by=0),
    WindowSpec.time_sliding(24.0, 8.0, lateness=6.0, time_by=0),
    WindowSpec.time_tumbling(16.0, lateness=6.0, time_by=0, keyed=True),
    WindowSpec.time_sliding(24.0, 8.0, lateness=6.0, time_by=0, keyed=True),
], ids=["tumbling", "sliding", "keyed-tumbling", "keyed-sliding"])
def test_segmented_byte_identical_to_pane_at_a_time(spec):
    """One stacked kernel call emits exactly the bytes the single-span
    shim emits pane by pane — tumbling/sliding, keyed/unkeyed, over
    shuffled-within-lateness input."""
    keyed_route = spec.keyed
    a, ra = _sink_rows(_et_app(spec, seg=True, keyed_route=keyed_route))
    b, rb = _sink_rows(_et_app(spec, seg=False, keyed_route=keyed_route))
    assert len(a) > 0
    assert a.tobytes() == b.tobytes()
    assert ra.panes_fired == rb.panes_fired > 0


def test_segmented_byte_identical_across_parallelism():
    """Keyed panes shard by the route: a replicated run fires the same
    (key, span) panes as the single-replica run (multiset of rows — jumbo
    arrival order at the sink is nondeterministic)."""
    spec = WindowSpec.time_tumbling(16.0, lateness=6.0, time_by=0,
                                    keyed=True)
    a, _ = _sink_rows(_et_app(spec, seg=True, keyed_route=True))
    b, _ = _sink_rows(_et_app(spec, seg=True, keyed_route=True),
                      parallelism={"w": 3})
    assert len(a) > 0 and len(a) == len(b)
    assert np.array_equal(a[np.lexsort(a.T[::-1])],
                          b[np.lexsort(b.T[::-1])])


def test_keyed_pane_union_invariant_under_plan_execute():
    """The PR 3 store-union invariant extended to panes, through the full
    Plan.execute replication path: sd_key replicated by the planner fires
    the same pane multiset as a single-replica run."""
    app = spike_detection_keyed()

    def capture(app_):
        rows = []
        k = app_.kernels["sink"]

        def spy(batch, state):
            rows.append(batch.copy())
            return k(batch, state)

        app_.kernels["sink"] = spy
        return rows

    rows1 = capture(app)
    res1 = run_app(app, {n: 1 for n in app.graph.operators}, batch=64,
                   max_batches=5, seed=7)
    app2 = spike_detection_keyed()
    rows2 = capture(app2)
    plan = Job(app2).plan(server_a(), optimizer="ff",
                          parallelism={"device_stats": 3, "parser": 2})
    res2 = plan.execute(batches=5, batch=64, seed=7,
                        parallelism={"device_stats": 3, "parser": 2}).raw
    assert plan.parallelism["device_stats"] == 3     # clamp lifted: keyed
    a = np.concatenate(rows1)
    b = np.concatenate(rows2)
    assert res1.panes_fired == res2.panes_fired == len(a) == len(b) > 0
    assert np.array_equal(a[np.lexsort(a.T[::-1])],
                          b[np.lexsort(b.T[::-1])])


def test_keyed_panes_contain_single_keys():
    """Every fired pane of a keyed window holds one key's rows only, and
    the segment index labels it."""
    spec = WindowSpec.time_tumbling(8.0, time_by=0, keyed=True)
    st_ = EventTimeWindowState(spec, key_by=1)
    rng = np.random.default_rng(3)
    ets = rng.uniform(0, 40, size=120)
    keys = rng.integers(0, 4, size=120).astype(np.float64)
    st_.insert(np.stack([ets, keys, rng.normal(size=120)], axis=1))
    batch = st_.on_watermark(np.inf)
    assert batch.n > 4                       # several (key, span) panes
    assert batch.segments.keys is not None
    seen = set()
    for i, (rows, _, span) in enumerate(batch):
        k = int(batch.segments.keys[i])
        assert np.all(rows[:, 1] == k)
        assert np.all((rows[:, 0] >= span[0]) & (rows[:, 0] < span[1]))
        seen.add((k, span))
    assert len(seen) == batch.n              # (key, span) is the pane unit
    # canonical order: ascending (end, key)
    sk = np.stack([batch.segments.spans[:, 1], batch.segments.keys], axis=1)
    assert np.array_equal(sk, sk[np.lexsort((sk[:, 1], sk[:, 0]))])


def test_keyed_panes_match_unkeyed_per_key_runs():
    """A keyed window's (key, span) panes equal running each key's rows
    through its own unkeyed window — grouping changes nothing else."""
    spec_k = WindowSpec.time_sliding(12.0, 4.0, time_by=0, keyed=True)
    spec_u = WindowSpec.time_sliding(12.0, 4.0, time_by=0)
    rng = np.random.default_rng(4)
    ets = rng.uniform(0, 60, size=150)
    keys = rng.integers(0, 3, size=150).astype(np.float64)
    rows = np.stack([ets, keys, rng.normal(size=150)], axis=1)
    st_k = EventTimeWindowState(spec_k, key_by=1)
    st_k.insert(rows)
    batch = st_k.on_watermark(50.0)
    keyed_panes = {(int(batch.segments.keys[i]), span): rows_i.tobytes()
                   for i, (rows_i, _, span) in enumerate(batch)}
    expected = {}
    for k in (0, 1, 2):
        st_u = EventTimeWindowState(spec_u)
        st_u.insert(rows[keys == k])
        for rows_i, _, span in st_u.on_watermark(50.0):
            expected[(k, span)] = rows_i.tobytes()
    assert keyed_panes == expected and len(expected) > 0


# ---------------------------------------------------------------------------
# build-time / run-time validation
# ---------------------------------------------------------------------------

def test_keyed_panes_require_time_window():
    with pytest.raises(ValueError, match="time=True"):
        WindowSpec(8, keyed=True)


def test_keyed_panes_require_keyed_partition():
    t = (Topology("bad")
         .spout("s", lambda b, sd: np.arange(b, dtype=np.float64),
                exec_ns=100.0, event_time=0)
         .op("w", lambda p, st_: [p], exec_ns=100.0))
    with pytest.raises(TopologyError, match="keyed route"):
        t.op("w2", lambda p, st_: [p], exec_ns=100.0, inputs="w",
             state=StateSpec("value",
                             window=WindowSpec.time_tumbling(8.0,
                                                             keyed=True)))


def test_run_app_rejects_keyed_panes_on_shuffled_route():
    """partition= overrides can strip the keyed route out from under a
    keyed window — rejected at run_app, not silently regrouped."""
    app = spike_detection_keyed()
    with pytest.raises(ValueError, match="keyed event-time panes"):
        run_app(app, {n: 1 for n in app.graph.operators}, batch=64,
                max_batches=1, partition={"device_stats": "shuffle"})


def test_migrated_event_time_windows_start_fresh():
    """A *drained* run's +inf flush closed every window frontier and fired
    every pane; carrying that frontier through migrate_states would mark
    the whole resumed stream late — so fully-drained event-time windows
    still start fresh.  (Suspended runs — ``final_watermark=False`` — do
    carry their buffers and frontier now; see test_checkpoint.py.)"""
    from repro.streaming.state import migrate_states
    app = spike_detection_keyed()
    par1 = {n: 1 for n in app.graph.operators}
    r1 = run_app(app, par1, batch=64, max_batches=3, seed=5)
    assert r1.panes_fired > 0
    par2 = dict(par1, device_stats=2, parser=2)
    seeded = migrate_states(app, r1.states, par2)
    win = seeded["device_stats"][0].window
    assert isinstance(win, EventTimeWindowState)
    assert win._fired_bound == -math.inf          # frontier reopened
    r2 = run_app(app, par2, batch=64, max_batches=3, seed=8,
                 initial_states=seeded)
    assert r2.late_drops == 0 and r2.panes_fired > 0
    # count-window history still carries best-effort (seed behaviour)
    from repro.streaming.apps import spike_detection
    sd = spike_detection()
    rs = run_app(sd, {n: 1 for n in sd.graph.operators}, batch=64,
                 max_batches=2, seed=1)
    carried = migrate_states(sd, rs.states,
                             {n: 1 for n in sd.graph.operators})
    assert carried["moving_avg"][0].window is \
        rs.states["moving_avg"][0].window


def test_planner_occupancy_scales_with_window_kind():
    """Count-window history is per-replica (replication multiplies the
    resident bytes); event-time pane buffers shard the stream (a plan's
    total occupancy is parallelism-independent)."""
    from repro.streaming.apps import SD_WINDOW, spike_detection
    sd = spike_detection()
    spec = sd.graph.operators["moving_avg"]
    assert spec.state_resident_tuples == SD_WINDOW
    assert not spec.state_resident_shared

    def resident(app, par):
        ev = Job(app).plan(server_a(), optimizer="ff",
                           parallelism=par).estimate(input_rate=1e5).raw
        return float(ev.state_resident_bytes.sum())

    r1 = resident(spike_detection(), {"moving_avg": 1})
    r4 = resident(spike_detection(), {"moving_avg": 4})
    assert r1 == pytest.approx(SD_WINDOW * 64.0)
    assert r4 == pytest.approx(4 * r1)            # per-replica history
    k1 = resident(spike_detection_keyed(), {"device_stats": 1})
    k4 = resident(spike_detection_keyed(), {"device_stats": 4})
    assert k1 == pytest.approx(k4) and k1 > 0     # sharded pane buffer


# ---------------------------------------------------------------------------
# watermark cadence (satellite)
# ---------------------------------------------------------------------------

def _cadence_app(**spout_kw):
    def source(batch, seed):
        return seed * batch + np.arange(batch, dtype=np.float64)

    def k_pane(pane, state):
        return [np.array([float(len(pane))])]

    return (Topology("cadence")
            .spout("s", source, exec_ns=100.0, event_time=0, **spout_kw)
            .op("w", k_pane, exec_ns=100.0,
                state=StateSpec("value",
                                window=WindowSpec.time_tumbling(32.0)))
            .sink("sink", lambda b, st_: [], exec_ns=50.0)
            .build())


def _count_watermarks(app, monkeypatch_cls=None, batches=8):
    marks = []
    orig = Executor._on_watermark

    def spy(self, msg):
        marks.append(msg.value)
        return orig(self, msg)

    Executor._on_watermark = spy
    try:
        res = run_app(app, {n: 1 for n in app.graph.operators}, batch=32,
                      max_batches=batches, seed=0)
    finally:
        Executor._on_watermark = orig
    return marks, res


def test_watermark_cadence_batches_amortizes_marks():
    """watermark_every=4 sends ~1/4 the marks but the +inf end-of-stream
    flush makes the fired panes identical."""
    m1, r1 = _count_watermarks(_cadence_app())
    m4, r4 = _count_watermarks(_cadence_app(watermark_every=4))
    assert r1.panes_fired == r4.panes_fired > 0
    assert r1.sink_tuples == r4.sink_tuples
    # the "w" executor sees 8 batch marks + inf vs 2 + inf
    assert len(m4) < len(m1)
    assert m4[-1] == math.inf


def test_watermark_cadence_interval():
    """watermark_interval=T marks on event-time advance: 8 batches of 32
    ticks with T=64 -> a mark roughly every other batch."""
    mi, ri = _count_watermarks(_cadence_app(watermark_interval=64.0))
    m1, r1 = _count_watermarks(_cadence_app())
    assert ri.panes_fired == r1.panes_fired > 0
    assert len(mi) < len(m1)


def test_watermark_cadence_validation():
    src = lambda b, sd: np.arange(b, dtype=np.float64)       # noqa: E731
    with pytest.raises(TopologyError, match="watermark_every"):
        Topology("x").spout("s", src, exec_ns=1.0, event_time=0,
                            watermark_every=0)
    with pytest.raises(TopologyError, match="watermark_interval"):
        Topology("x").spout("s", src, exec_ns=1.0, event_time=0,
                            watermark_interval=0.0)
    with pytest.raises(TopologyError, match="not both"):
        Topology("x").spout("s", src, exec_ns=1.0, event_time=0,
                            watermark_every=2, watermark_interval=8.0)
    with pytest.raises(TopologyError, match="requires"):
        Topology("x").spout("s", src, exec_ns=1.0, watermark_every=2)


# ---------------------------------------------------------------------------
# per-edge keyed-split selection (satellite)
# ---------------------------------------------------------------------------

def test_auto_vectorized_calibration():
    """The recalibrated threshold reproduces the BENCH micro grid's
    winners: per-mask only at small rows x low fan-out, vectorized once
    the radix sort amortizes.  The refit (rows * k**3 > 8192) moved the
    k=4 crossover down to ~256 rows — the old fit kept LR's 1024-row k=4
    edge on masks, where the fresh grid shows vectorized wins 1.3x."""
    assert not auto_vectorized(256, 2)
    assert not auto_vectorized(1024, 2)
    assert auto_vectorized(10240, 2)
    assert not auto_vectorized(128, 4)           # near-tie, masks by default
    assert auto_vectorized(256, 4)
    assert auto_vectorized(1024, 4)              # old rule's LR miss: vec wins 1.3x
    assert auto_vectorized(2048, 8)
    assert auto_vectorized(128, 8)               # old rule misclassified this
    assert VEC_CROSSOVER == 8192


def test_route_auto_split_matches_both_overrides():
    """Whatever implementation auto picks, the split is row-for-row what
    both forced paths produce."""
    rng = np.random.default_rng(5)
    spec = RouteSpec("u", "v", 0, "key")
    for rows, k in [(64, 4), (4096, 4), (512, 8)]:
        arr = rng.integers(0, 1000, size=rows).astype(np.int64)
        outs = [spec.bind(k, vectorized=v).split(arr)
                for v in (None, True, False)]
        for o in outs[1:]:
            assert len(o) == len(outs[0])
            for (j1, p1), (j2, p2) in zip(outs[0], o):
                assert j1 == j2 and np.array_equal(p1, p2)


def test_run_app_auto_vectorized_default_conserves():
    from repro.streaming.apps import word_count
    app = word_count()
    res = run_app(app, {"splitter": 2, "counter": 4}, batch=64,
                  max_batches=3)                 # vectorized=None default
    assert res.sink_tuples == res.spout_tuples * 10


# ---------------------------------------------------------------------------
# DES event-time fidelity (satellite): empirical et_spacing
# ---------------------------------------------------------------------------

def _bursty_app(ticks_per_tuple: float):
    def source(batch, seed):
        ets = (seed * batch + np.arange(batch, dtype=np.float64)) \
            * ticks_per_tuple
        return np.stack([ets, np.ones(batch)], axis=1)

    def k_pane(pane, state):
        return [np.array([float(len(pane))])]

    return (Topology("bursty")
            .spout("s", source, exec_ns=100.0, event_time=0)
            .op("w", k_pane, exec_ns=100.0,
                state=StateSpec("value",
                                window=WindowSpec.time_tumbling(64.0)))
            .sink("sink", lambda b, st_: [], exec_ns=50.0)
            .build())


def test_probe_et_spacing_measures_the_source():
    assert probe_et_spacing(spike_detection_eventtime())["spout"] == \
        pytest.approx(1.0, rel=1e-6)
    assert probe_et_spacing(_bursty_app(5.0))["s"] == \
        pytest.approx(5.0, rel=1e-6)
    assert probe_et_spacing(_bursty_app(0.25))["s"] == \
        pytest.approx(0.25, rel=1e-6)


def test_des_paces_panes_at_probed_spacing():
    """A source advancing 5 ticks/tuple fires ~5x the panes of the
    1-tick default over the same horizon — the probe feeds the DES
    through Plan.simulate automatically."""
    app = _bursty_app(5.0)
    plan = Job(app).plan(server_a(), optimizer="ff")
    des = plan.simulate(input_rate=2e5, horizon=0.03).raw
    g = plan.graph
    des_flat = des_simulate(g, server_a(), plan.placement, input_rate=2e5,
                            horizon=0.03, time_windows=plan.job.time_windows,
                            et_spacing=1.0)
    assert des.panes_fired > 3 * des_flat.panes_fired > 0
    assert des.pane_batches > 0
    with pytest.raises(ValueError, match="non-spout"):
        des_simulate(g, server_a(), plan.placement, input_rate=2e5,
                     time_windows=plan.job.time_windows,
                     et_spacing={"w": 1.0})


# ---------------------------------------------------------------------------
# property tests (hypothesis; skipped when unavailable)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(size_n=st.integers(2, 12), slide_n=st.integers(1, 12),
           lateness_n=st.integers(0, 4), skew_n=st.integers(0, 4),
           keyed=st.booleans(), par=st.sampled_from([1, 2]),
           seed=st.integers(0, 2**16))
    def test_segmented_equals_pane_at_a_time_property(
            size_n, slide_n, lateness_n, skew_n, keyed, par, seed):
        """Across random window shapes, skew within lateness, keyed and
        unkeyed panes, parallelism 1 vs k: the segmented engine's sink
        bytes equal the single-span shim's (multiset at parallelism > 1,
        byte-exact at 1)."""
        size = size_n * 4.0
        slide = min(slide_n, size_n) * 4.0
        lateness = lateness_n * 2.0
        skew = min(skew_n * 2.0, lateness) if lateness else 0.0
        spec = WindowSpec.time_sliding(size, slide, lateness=lateness,
                                       time_by=0, keyed=keyed)
        par_map = {"w": par if keyed else 1}
        a, ra = _sink_rows(_et_app(spec, seg=True, skew=skew,
                                   keyed_route=keyed),
                           parallelism=par_map, batches=3, batch=32,
                           seed=seed % 64)
        b, rb = _sink_rows(_et_app(spec, seg=False, skew=skew,
                                   keyed_route=keyed),
                           parallelism=par_map, batches=3, batch=32,
                           seed=seed % 64)
        assert ra.panes_fired == rb.panes_fired > 0
        assert ra.late_drops == rb.late_drops == 0
        if par == 1 or not keyed:
            assert a.tobytes() == b.tobytes()
        else:
            assert np.array_equal(a[np.lexsort(a.T[::-1])],
                                  b[np.lexsort(b.T[::-1])])

    @settings(max_examples=40, deadline=None)
    @given(size=st.integers(2, 10), hop=st.integers(1, 10),
           chunks=st.lists(st.integers(0, 17), min_size=1, max_size=8),
           seed=st.integers(0, 2**16))
    def test_count_tumble_segments_property(size, hop, chunks, seed):
        """Count windows through the segmented substrate equal the seed
        while-loop semantics for any (size, hop, arrival chunking)."""
        hop = min(hop, size)
        rng = np.random.default_rng(seed)
        spec = WindowSpec(size=size, slide=hop)
        w = WindowState(spec)
        stream = rng.normal(size=sum(chunks))
        got, pos = [], 0
        for n in chunks:
            got.extend(w.tumble(stream[pos:pos + n]))
            pos += n
        expected = [stream[i:i + size]
                    for i in range(0, max(len(stream) - size + 1, 0), hop)]
        assert len(got) == len(expected)
        for g, e in zip(got, expected):
            assert np.array_equal(g, e)
