"""Operator fusion: 1:1 pipeline segments compile into single executors.

The contract (ISSUE 10): maximal chains of fusion-eligible edges —
shuffle-routed, fan-in 1 / fan-out 1, no device or event-time-window
endpoint, no ``fuse=False`` opt-out, matching replica counts — run as one
``FusedExecutor`` calling the member kernels back-to-back with no
intermediate queue, while outputs, managed state, checkpoints and
``migrate_states`` stay byte-identical to the unfused plan on both
backends.  The planner prices a fused chain as one operator (summed
selectivity-weighted service time, zero intra-chain comm), and
``Plan.execute`` hands the chains to the runtime so what was priced is
what runs.
"""
import queue

import numpy as np
import pytest

from repro.core import server_b
from repro.streaming.api import Job, Topology, TopologyError
from repro.streaming.apps import (ALL_APPS, chain_pipeline, spike_detection,
                                  spike_detection_eventtime,
                                  streaming_inference, word_count)
from repro.streaming.checkpoint import checkpoint_uids
from repro.streaming.fusion import (detect_chains, expand_parallelism,
                                    fuse_graph, fuse_parallelism, fused_name,
                                    validate_chains)
from repro.streaming.procexec import _FanIn, run_app_processes
from repro.streaming.runtime import Executor, _Watermark, run_app
from repro.streaming.state import merge_keyed, migrate_states, state_payload

_RUNNERS = {"threads": run_app, "processes": run_app_processes}


def _chains(app, **kw):
    kw.setdefault("no_fuse", getattr(app, "no_fuse", frozenset()))
    kw.setdefault("time_windows", set(app.time_windows()))
    return detect_chains(app.graph, app.routes(), **kw)


def _fp(rt):
    """Byte fingerprint of every replica's state, keyed by operator."""
    return {op: [repr(state_payload(s)) for s in sts]
            for op, sts in sorted(rt.states.items())}


# ---------------------------------------------------------------------------
# chain detection
# ---------------------------------------------------------------------------

def test_detect_full_linear_chain():
    # sd is one straight 1:1 shuffle pipeline after the spout; the count
    # window (moving_avg) lives inside the kernel and fuses fine
    assert _chains(spike_detection()) == [
        ["parser", "moving_avg", "spike", "sink"]]


def test_detect_keyed_edge_breaks_chain():
    # wc's splitter->counter edge repartitions by key: it must stay a
    # queue crossing, leaving two chains on either side
    assert _chains(word_count()) == [["parser", "splitter"],
                                     ["counter", "sink"]]


def test_detect_fan_in_and_broadcast_break_chain():
    # fd's predictor has two producers (data + broadcast model sync), so
    # nothing fuses into it; its 1:1 shuffle edge to the sink still does
    assert _chains(ALL_APPS["fd"]()) == [["predictor", "sink"]]


def test_detect_device_operator_excluded():
    # v1 keeps the async dispatch window at a queue boundary
    assert _chains(streaming_inference()) == []


def test_detect_event_time_window_excluded():
    # pane firing is driven by the merged watermark at a lane boundary
    assert _chains(spike_detection_eventtime()) == []


def test_detect_parallelism_mismatch_breaks_chain():
    app = spike_detection()
    par = {"parser": 2, "moving_avg": 2, "spike": 1, "sink": 1}
    assert _chains(app, parallelism=par) == [["parser", "moving_avg"],
                                             ["spike", "sink"]]


def test_detect_fuse_false_opt_out():
    app = spike_detection()
    assert _chains(app, no_fuse={"spike"}) == [["parser", "moving_avg"]]


def test_topology_fuse_flag():
    def src(batch, seed):
        return np.zeros(batch)

    t = (Topology("t")
         .spout("s", src, exec_ns=100.0)
         .op("a", lambda b, st: [b], exec_ns=100.0)
         .op("b", lambda b, st: [b], exec_ns=100.0, fuse=False)
         .sink("k", lambda b, st: [], exec_ns=100.0))
    assert t.no_fuse == frozenset({"b"})
    app = t.build()
    assert app.no_fuse == frozenset({"b"})
    # a->b and b->k are both poisoned by the opt-out; nothing fuses
    assert _chains(app) == []
    with pytest.raises(TopologyError, match="fuse"):
        Topology("t2").op("x", lambda b, st: [b], exec_ns=1.0, fuse="yes")


def test_validate_chains_errors():
    app = word_count()
    lg, routes = app.graph, app.routes()
    with pytest.raises(ValueError, match=">= 2"):
        validate_chains(lg, routes, [["parser"]])
    with pytest.raises(ValueError, match="not an operator"):
        validate_chains(lg, routes, [["parser", "nope"]])
    with pytest.raises(ValueError, match="more than one"):
        validate_chains(lg, routes, [["parser", "splitter"],
                                     ["splitter", "counter"]])
    with pytest.raises(ValueError, match="not.*edge"):
        validate_chains(lg, routes, [["parser", "counter"]])
    with pytest.raises(ValueError, match="not fusion-eligible"):
        validate_chains(lg, routes, [["splitter", "counter"]])  # keyed
    with pytest.raises(ValueError, match="not fusion-eligible"):
        validate_chains(lg, routes, [["spout", "parser"]])      # spout head
    ok = validate_chains(lg, routes, [["counter", "sink"]])
    assert ok == [["counter", "sink"]]


# ---------------------------------------------------------------------------
# planner rewrite: fused pricing
# ---------------------------------------------------------------------------

def test_fuse_graph_pricing():
    app = word_count()
    lg, routes = app.graph, app.routes()
    chains = [["parser", "splitter"], ["counter", "sink"]]
    flg, froutes = fuse_graph(lg, routes, chains)
    ps, cs = fused_name(chains[0]), fused_name(chains[1])
    assert set(flg.operators) == {"spout", ps, cs}
    assert list(flg.edges) == [("spout", ps), (ps, cs)]
    # selectivity-weighted service-time sum: parser (sel 1.0) feeds every
    # tuple to the splitter
    spec = flg.operators[ps]
    assert spec.exec_ns == pytest.approx(
        lg.operators["parser"].exec_ns + lg.operators["splitter"].exec_ns)
    assert spec.selectivity == pytest.approx(10.0)
    # counter+sink: the counter sees 10 words per upstream tuple... but
    # per *its own* input tuple cost is just counter + sink
    cspec = flg.operators[cs]
    assert cspec.exec_ns == pytest.approx(
        lg.operators["counter"].exec_ns + lg.operators["sink"].exec_ns)
    # the keyed inbound route of the old chain head survives verbatim
    assert froutes.strategy(ps, cs) == "key"
    # outbound rate of the fused producer = tail rate x tail edge sel
    assert flg.sel(ps, cs) == pytest.approx(10.0)


def test_parallelism_fuse_expand_roundtrip():
    chains = [["a", "b"], ["c", "d"]]
    par = {"s": 1, "a": 3, "b": 3, "c": 2, "d": 2}
    fused = fuse_parallelism(par, chains)
    assert fused == {"s": 1, "a+b": 3, "c+d": 2}
    assert expand_parallelism(fused, chains) == par


# ---------------------------------------------------------------------------
# runtime parity: fused == unfused, byte for byte
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["threads", "processes"])
def test_fused_parity_chain_app(backend):
    app = chain_pipeline()
    base = _RUNNERS[backend](app, {}, max_batches=30, batch=64, seed=3)
    fused = _RUNNERS[backend](chain_pipeline(), {}, max_batches=30, batch=64,
                              seed=3, fuse="auto")
    assert _fp(fused) == _fp(base)
    assert fused.spout_tuples == base.spout_tuples


def test_fused_parity_stateful_single_replica():
    # the count-window moving average is order-sensitive: byte parity at
    # one replica pins the chain buffer's batch-boundary semantics exactly
    base = run_app(spike_detection(), {}, max_batches=24, batch=64, seed=5)
    fused = run_app(spike_detection(), {}, max_batches=24, batch=64, seed=5,
                    fuse="auto")
    assert _fp(fused) == _fp(base)


@pytest.mark.parametrize("backend", ["threads", "processes"])
def test_replicated_chain_forwarding_contract(backend):
    # a replicated fused chain forwards replica-locally (any distribution
    # is a valid shuffle): global counts are conserved and the fused plan
    # is deterministic against itself, but per-replica window contents
    # are NOT promised to match the unfused round-robin — see fusion.py
    par = {"spout": 1, "parser": 2, "moving_avg": 2, "spike": 2, "sink": 2}
    base = _RUNNERS[backend](spike_detection(), par, max_batches=24,
                             batch=64, seed=5)
    fused = _RUNNERS[backend](spike_detection(), par, max_batches=24,
                              batch=64, seed=5, fuse="auto")
    assert fused.spout_tuples == base.spout_tuples
    seen = lambda rt: sum(st.get("seen", 0) for st in rt.states["sink"])
    assert seen(fused) == seen(base)
    again = _RUNNERS[backend](spike_detection(), par, max_batches=24,
                              batch=64, seed=5, fuse="auto")
    assert _fp(again) == _fp(fused)


def test_fused_parity_per_tuple_mode():
    app = chain_pipeline()
    base = run_app(app, {}, max_batches=10, batch=32, seed=2, jumbo=False)
    fused = run_app(chain_pipeline(), {}, max_batches=10, batch=32, seed=2,
                    jumbo=False, fuse="auto")
    assert _fp(fused) == _fp(base)


def test_explicit_chain_and_mismatch_drop():
    app = chain_pipeline()
    base = run_app(app, {}, max_batches=10, batch=32, seed=2)
    part = run_app(chain_pipeline(), {}, max_batches=10, batch=32, seed=2,
                   fuse=[["f1", "f2"], ["f3", "f4"]])
    assert _fp(part) == _fp(base)
    # mismatched replica counts silently unfuse (the chain may come from a
    # plan that was elastically rescaled since)
    from repro.streaming.runtime import prepare_app
    par = dict({n: 1 for n in app.graph.operators}, f2=2)
    prep = prepare_app(chain_pipeline(), par, fuse=[["f1", "f2"]])
    assert prep.chains == []
    prep = prepare_app(chain_pipeline(), par, fuse=[["f3", "f4"]])
    assert prep.chains == [["f3", "f4"]]
    # structurally invalid explicit chains still raise
    with pytest.raises(ValueError, match="not fusion-eligible"):
        run_app(word_count(), {}, max_batches=2,
                fuse=[["splitter", "counter"]])


def test_fused_keyed_store_parity():
    # counter+sink fuses with the keyed inbound route intact: each counter
    # replica receives exactly the unfused shards, so its store is
    # byte-identical per replica (only the sink's intra-chain distribution
    # changes, and its total is conserved)
    app = word_count()
    par = {"spout": 1, "parser": 1, "splitter": 1, "counter": 2, "sink": 2}
    base = run_app(app, par, max_batches=12, batch=64, seed=7)
    fused = run_app(word_count(), par, max_batches=12, batch=64, seed=7,
                    fuse="auto")
    assert _fp(fused)["counter"] == _fp(base)["counter"]
    want = merge_keyed([st.managed for st in base.states["counter"]])
    got = merge_keyed([st.managed for st in fused.states["counter"]])
    assert got.tobytes() == want.tobytes()
    seen = lambda rt: sum(st.get("seen", 0) for st in rt.states["sink"])
    assert seen(fused) == seen(base)


# ---------------------------------------------------------------------------
# exec_stats (satellite: per-replica runtime counters)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["threads", "processes"])
def test_exec_stats_counters(backend):
    rt = _RUNNERS[backend](spike_detection(), {}, max_batches=10, batch=32,
                           seed=1)
    st = rt.exec_stats
    assert set(st) == {"spout#0", "parser#0", "moving_avg#0", "spike#0",
                       "sink#0"}
    assert st["spout#0"]["batches"] == 10
    assert st["spout#0"]["tuples_out"] == 320
    assert st["parser#0"]["tuples_in"] == 320
    assert st["parser#0"]["tuples_out"] == 320
    assert st["sink#0"]["tuples_in"] == 320
    assert st["sink#0"]["tuples_out"] == 0
    for uid, s in st.items():
        assert s["queue_wait_s"] >= 0.0
        assert s["kernel_s"] > 0.0, uid


def test_exec_stats_fused_members():
    rt = run_app(spike_detection(), {}, max_batches=10, batch=32, seed=1,
                 fuse="auto")
    st = rt.exec_stats
    # every member still reports under its own uid
    assert set(st) == {"spout#0", "parser#0", "moving_avg#0", "spike#0",
                       "sink#0"}
    assert st["parser#0"]["tuples_in"] == 320
    assert st["sink#0"]["tuples_in"] == 320
    assert st["sink#0"]["tuples_out"] == 0
    # queue wait is a chain-level quantity: it lands on the head
    assert st["moving_avg#0"]["queue_wait_s"] == 0.0


# ---------------------------------------------------------------------------
# single-lane fast path (satellite: skip the merge when there is one lane)
# ---------------------------------------------------------------------------

def test_single_lane_watermark_fast_path():
    ex = Executor("v#0", [], 64, True, {}, expected_poisons=1)
    assert ex._single_lane
    ex._on_watermark(_Watermark("u#0", 5.0))
    assert ex._wm_fwd == 5.0
    # the merger was never touched — the lane value IS the merged value
    assert ex._wm_merge._lanes == {}
    assert ex._aux_payload() == {"wm_lanes": {"u#0": 5.0}, "wm_fwd": 5.0}
    # regressions are caught by the frontier check, like the merged path
    ex._on_watermark(_Watermark("u#0", 4.0))
    assert ex._wm_fwd == 5.0


def test_multi_lane_still_merges():
    ex = Executor("v#0", [], 64, True, {}, expected_poisons=2)
    assert not ex._single_lane
    ex._on_watermark(_Watermark("u#0", 5.0))
    # one of two lanes reported: the min-merge cannot advance yet
    assert ex._wm_fwd == float("-inf")
    ex._on_watermark(_Watermark("u#1", 3.0))
    assert ex._wm_fwd == 3.0


def test_fanin_solo_fast_path():
    q1 = queue.Queue()
    q1.put("a")
    f = _FanIn([q1])
    assert f._solo is q1
    assert f.get() == "a"
    q2 = queue.Queue()
    f2 = _FanIn([q1, q2])
    assert f2._solo is None
    q2.put("b")
    assert f2.get() == "b"


# ---------------------------------------------------------------------------
# checkpoints: fused and unfused snapshots are interchangeable
# ---------------------------------------------------------------------------

def _resume_batches(total, ck):
    off = set(ck.spout_offsets.values())
    assert len(off) == 1
    return total - off.pop()


def test_checkpoint_roundtrip_through_fused_chain():
    app = spike_detection()
    total = 24
    base = run_app(app, {}, batch=64, max_batches=total, seed=5)
    want = _fp(base)
    fused = run_app(spike_detection(), {}, batch=64, max_batches=total,
                    seed=5, checkpoint_every=6, fuse="auto")
    assert [c.ckpt_id for c in fused.checkpoints] == [1, 2, 3, 4]
    # a fused run deposits per MEMBER uid — the snapshot schema is plan-
    # agnostic, so an unfused resume reads it directly
    for ck in fused.checkpoints:
        assert set(ck.states) == checkpoint_uids(app, {})
        rt = run_app(spike_detection(), batch=64, seed=5,
                     max_batches=_resume_batches(total, ck),
                     from_checkpoint=ck)
        assert _fp(rt) == want, f"unfused resume from fused ckpt {ck.ckpt_id}"
    # and the reverse: a fused resume of an unfused snapshot
    plain = run_app(spike_detection(), {}, batch=64, max_batches=total,
                    seed=5, checkpoint_every=6)
    for ck in plain.checkpoints:
        rt = run_app(spike_detection(), batch=64, seed=5,
                     max_batches=_resume_batches(total, ck),
                     from_checkpoint=ck, fuse="auto")
        assert _fp(rt) == want, f"fused resume from plain ckpt {ck.ckpt_id}"


def test_checkpoint_fused_processes_to_threads():
    total = 16
    base = run_app(chain_pipeline(), {}, batch=64, max_batches=total, seed=9)
    fused = run_app_processes(chain_pipeline(), {}, batch=64,
                              max_batches=total, seed=9, checkpoint_every=4,
                              fuse="auto")
    assert fused.checkpoints
    ck = fused.checkpoints[-1]
    rt = run_app(chain_pipeline(), batch=64, seed=9,
                 max_batches=_resume_batches(total, ck), from_checkpoint=ck)
    assert _fp(rt) == _fp(base)


# ---------------------------------------------------------------------------
# state migration across a fuse/unfuse replan
# ---------------------------------------------------------------------------

def test_migrate_states_across_fuse_replan():
    """First half fused, replan to a wider unfused layout, migrate, resume:
    the keyed store unions to the uninterrupted run's bytes."""
    total, cut, seed = 8, 3, 42
    app = word_count()
    ref = run_app(word_count(), {}, batch=64, max_batches=total, seed=seed)
    ref_counts = ref.states["counter"][0].managed.table

    r1 = run_app(word_count(), {}, batch=64, max_batches=cut, seed=seed,
                 fuse="auto")
    par2 = {"spout": 1, "parser": 1, "splitter": 1, "counter": 2, "sink": 1}
    seeded = migrate_states(app, r1.states, par2)
    # counter now runs 2 replicas while sink runs 1: fuse="auto" keeps the
    # parser+splitter chain and drops counter+sink on its own
    r2 = run_app(word_count(), par2, batch=64, max_batches=total - cut,
                 seed=seed, initial_states=seeded,
                 initial_offsets=r1.spout_offsets, fuse="auto")
    merged = merge_keyed([st.managed for st in r2.states["counter"]])
    assert merged.tobytes() == ref_counts.tobytes()
    assert r1.spout_tuples + r2.spout_tuples == ref.spout_tuples


# ---------------------------------------------------------------------------
# Job.plan / Plan.execute integration
# ---------------------------------------------------------------------------

def test_plan_fuse_auto_end_to_end():
    job = Job(spike_detection())
    m = server_b()
    # single-replica chain: byte parity with the unfused plan end-to-end
    par = {"spout": 1, "parser": 1, "moving_avg": 1, "spike": 1, "sink": 1}
    p_off = job.plan(m, "ff", input_rate=1e6, parallelism=par)
    p_on = job.plan(m, "bnb", input_rate=1e6, parallelism=par, fuse="auto")
    assert p_on.chains == [["parser", "moving_avg", "spike", "sink"]]
    fused = fused_name(p_on.chains[0])
    assert fused in p_on.graph.parallelism
    # plan.parallelism speaks member names so execute()/migrate can use it
    assert p_on.parallelism == par
    assert p_on.options["fuse"] == "auto"
    assert p_on.estimate().throughput > 0
    assert p_on.simulate("des", batch=64, horizon=0.005).throughput > 0
    assert fused in p_on.describe()
    r_off = p_off.execute(batches=16, batch=64, seed=3).raw
    r_on = p_on.execute(batches=16, batch=64, seed=3).raw
    assert _fp(r_on) == _fp(r_off)
    r_proc = p_on.execute(batches=16, batch=64, seed=3,
                          backend="processes").raw
    assert _fp(r_proc) == _fp(r_off)


def test_plan_fuse_explicit_and_validation():
    job = Job(spike_detection())
    m = server_b()
    par = {"spout": 1, "parser": 2, "moving_avg": 2, "spike": 2, "sink": 2}
    p = job.plan(m, "ff", input_rate=1e6, parallelism=par,
                 fuse=[["parser", "moving_avg"]])
    assert p.chains == [["parser", "moving_avg"]]
    # a parallelism mismatch drops the explicit chain instead of planning
    # an unrealizable fusion
    p_mm = job.plan(m, "ff", input_rate=1e6,
                    parallelism=dict(par, moving_avg=3),
                    fuse=[["parser", "moving_avg"]])
    assert p_mm.chains == []
    with pytest.raises(ValueError, match="not fusion-eligible"):
        Job(word_count()).plan(m, "ff", input_rate=1e6,
                               fuse=[["splitter", "counter"]])


def test_plan_fuse_rlas_scaling():
    # the optimizer scales the fused unit as one operator; every member
    # inherits its replica count, so the chain survives down-scaling
    job = Job(chain_pipeline())
    plan = job.plan(server_b(), "rlas", input_rate=2e5, fuse="auto")
    assert plan.chains == [["f1", "f2", "f3", "f4", "sink"]]
    ks = {plan.parallelism[m] for m in plan.chains[0]}
    assert len(ks) == 1
    r = plan.execute(batches=8, batch=64, seed=1, max_threads=4).raw
    assert r.spout_tuples == 8 * 64 * sum(
        plan.parallelism[s] for s in ["spout"])
