"""End-to-end launch-layer tests: train/resume determinism, batched serving,
and a real dry-run cell in a 512-device subprocess."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get
from repro.launch.serve import Request, serve_batch
from repro.launch.train import train
from repro.models import model_api

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _leaves_allclose(a, b, tol=1e-5):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32), atol=tol,
                                   rtol=tol)


def test_train_resume_is_deterministic(tmp_path):
    """crash/restart mid-run == uninterrupted run (fault tolerance)."""
    d1 = str(tmp_path / "run_ab")
    out_a = train("smollm_360m", steps=6, batch=2, seq=32,
                  ckpt_dir=d1, ckpt_every=3, log_every=100)
    # second process: resume from step 3's checkpoint... simulate by a fresh
    # train() pointed at a dir holding only the step-3 checkpoint
    d2 = str(tmp_path / "run_b")
    train("smollm_360m", steps=3, batch=2, seq=32,
          ckpt_dir=d2, ckpt_every=3, log_every=100)
    out_b = train("smollm_360m", steps=6, batch=2, seq=32,
                  ckpt_dir=d2, ckpt_every=3, log_every=100)
    _leaves_allclose(out_a["params"], out_b["params"], tol=5e-3)


def test_serve_batch_generates():
    cfg = get("h2o_danube_1_8b", smoke=True)
    api = model_api(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab, 4, dtype=np.int32), 6)
            for i in range(3)]
    reqs, dt = serve_batch(cfg, params, reqs, max_len=16)
    for r in reqs:
        assert r.out.shape == (6,)
        assert np.all((0 <= r.out) & (r.out < cfg.vocab))


def test_serve_greedy_matches_decode_loop():
    """serve_batch's generation equals a hand-rolled greedy loop."""
    cfg = get("smollm_360m", smoke=True)
    api = model_api(cfg)
    params = api.init(jax.random.PRNGKey(1), cfg)
    prompt = np.array([5, 9, 2], np.int32)
    reqs, _ = serve_batch(cfg, params, [Request(0, prompt, 4)], max_len=16)
    # manual loop
    cache = api.init_cache(cfg, 1, max_len=16)
    toks = list(prompt)
    for t in range(len(prompt)):
        logits, cache = api.decode_step(
            params, cache, jnp.asarray([toks[t]], jnp.int32), jnp.int32(t),
            cfg)
    out = []
    cur = int(jnp.argmax(logits[0]))
    for t in range(len(prompt), len(prompt) + 4):
        out.append(cur)
        logits, cache = api.decode_step(
            params, cache, jnp.asarray([cur], jnp.int32), jnp.int32(t), cfg)
        cur = int(jnp.argmax(logits[0]))
    np.testing.assert_array_equal(reqs[0].out, np.asarray(out, np.int32))


@pytest.mark.slow
def test_dryrun_cell_subprocess():
    """One real dry-run cell on the 512-device production mesh."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "smollm_360m", "--shape", "decode_32k"],
        capture_output=True, text=True, timeout=560, env=env, cwd=REPO)
    assert "-> ok" in out.stdout, out.stdout + out.stderr


def test_gradient_int8_cross_pod_allreduce_single_device():
    """shard_map int8 exchange compiles + is unbiased on a 1x1x1 mesh."""
    from repro.launch.mesh import make_mesh
    from repro.optim.compress import cross_pod_allreduce_int8
    mesh = make_mesh((1, 1, 1), ("pod", "data", "model"))
    grads = {"w": jnp.linspace(-1, 1, 64).reshape(8, 8)}
    out = cross_pod_allreduce_int8(grads, mesh, jax.random.PRNGKey(0))
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.asarray(grads["w"]), atol=2e-2)


@pytest.mark.slow
def test_cp_attention_multishard_subprocess():
    """Ring CP attention numerics on a real 8-shard mesh."""
    code = '''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "%s")
import jax, numpy as np
from repro.kernels import ops, ref
from repro.launch.mesh import make_mesh
mesh = make_mesh((1, 8), ("data", "model"))
ks = jax.random.split(jax.random.PRNGKey(5), 3)
q, k, v = (jax.random.normal(ks[i], (2, 4, 256, 32)) for i in range(3))
for window in [None, 64, 100]:
    out = ops.cp_flash_attention(q, k, v, mesh, causal=True, window=window,
                                 q_chunk=32, kv_chunk=32)
    exp = ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=3e-5, rtol=3e-5)
print("OK")
''' % os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=540)
    assert "OK" in out.stdout, out.stdout + out.stderr
