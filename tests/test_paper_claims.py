"""Integration tests asserting the paper's claims hold in this reproduction
(EXPERIMENTS.md §Reproduction). Uses reduced sample counts to stay fast."""
import numpy as np
import pytest

from repro.core import evaluate, rlas_optimize, server_a, server_b, subset
from repro.core.baselines import random_plan
from repro.streaming.apps import ALL_APPS
from repro.streaming.simulator import measure_capacity


@pytest.fixture(scope="module")
def plans():
    out = {}
    for name, make in ALL_APPS.items():
        app = make()
        res = rlas_optimize(app.graph, server_a(), input_rate=None,
                            compress_ratio=5, bestfit=True, max_nodes=5000)
        out[name] = (app, res)
    return out


def test_model_accuracy_within_paper_band(plans):
    """Paper Table 4: relative error 0.02-0.14; we require <= 0.2."""
    for name, (app, res) in plans.items():
        des = measure_capacity(res.graph, server_a(),
                               res.placement.placement, horizon=0.006)
        rel = abs(des.R - res.R) / max(des.R, 1e-9)
        assert rel < 0.2, (name, rel)


def test_rlas_beats_fixed_capability(plans):
    """Paper Fig. 12: RLAS > fix(L), fix(U) on every app."""
    for name, (app, res) in plans.items():
        for mode in ["worst", "zero"]:
            alt = rlas_optimize(app.graph, server_a(), input_rate=None,
                                compress_ratio=5, bestfit=True,
                                max_nodes=5000, tf_mode=mode)
            assert res.R >= alt.R * 0.99, (name, mode, res.R, alt.R)


def test_no_random_plan_beats_rlas(plans):
    """Paper Fig. 14 (reduced to 100 samples per app)."""
    rng = np.random.default_rng(7)
    for name in ["wc", "lr"]:
        app, res = plans[name]
        for _ in range(100):
            _, _, ev = random_plan(app.graph, server_a(), rng)
            r = ev.R if ev.feasible else 0.0
            assert r <= res.R * (1 + 1e-9), name


def test_scaling_sublinear_beyond_four_sockets(plans):
    """Paper Fig. 9: near-linear to 4 sockets, sublinear at 8."""
    app = ALL_APPS["wc"]()
    rs = {}
    for ns in [1, 4, 8]:
        res = rlas_optimize(app.graph, subset(server_a(), ns),
                            input_rate=None, compress_ratio=5, bestfit=True,
                            max_nodes=5000)
        rs[ns] = res.R
    assert rs[4] > 2.0 * rs[1]               # scales well to 4
    assert rs[8] < 8.0 * rs[1]               # but not linearly to 8
    assert rs[8] > rs[4]                     # still improves


def test_server_b_capacity_insight(plans):
    """Paper §6.4: Server A has more aggregate compute but RLAS plans can
    reach comparable throughput on Server B thanks to flat remote bw."""
    app = ALL_APPS["wc"]()
    res_b = rlas_optimize(app.graph, server_b(), input_rate=None,
                          compress_ratio=5, bestfit=True, max_nodes=5000)
    assert res_b.placement.feasible
    assert res_b.R > 0
