"""Event-time windows with watermarks, hardened by an out-of-order harness.

The determinism contract (ISSUE 4 acceptance): for any skew within the
lateness bound, event-time pane contents are byte-identical between ordered
and shuffled input; watermarks are monotone per lane; late tuples beyond the
bound are counted, never silently dropped; and the runtime and the DES
assign tuples to panes with the same arithmetic.
"""
import math

import numpy as np
import pytest

from repro.core import ExecutionGraph, server_a
from repro.streaming import Job
from repro.streaming.api import Topology, TopologyError
from repro.streaming.apps import (SD_ET_SIZE, SD_ET_SLIDE,
                                  shuffle_within_skew,
                                  spike_detection_eventtime)
from repro.streaming.routing import WatermarkMerger, extract_event_times
from repro.streaming.runtime import Executor, run_app
from repro.streaming.simulator import des_simulate
from repro.streaming.state import (EventTimeWindowState, StateSpec,
                                   UndeclaredStateError, WindowSpec,
                                   grid_pane_ends, migrate_states,
                                   pane_range)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# the out-of-order harness itself
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bound", [0.0, 1.0, 4.0, 16.0])
def test_shuffle_within_skew_respects_bound(bound):
    """The seeded shuffler's promise: in the permuted stream, the running
    max event time never exceeds a pending tuple's by more than ``bound``."""
    rng = np.random.default_rng(7)
    ets = np.arange(500, dtype=np.float64)
    perm = shuffle_within_skew(ets, bound, rng)
    assert sorted(perm) == list(range(500))            # a permutation
    shuffled = ets[perm]
    disorder = np.maximum.accumulate(shuffled) - shuffled
    assert float(disorder.max()) <= bound + 1e-9
    if bound >= 4.0:
        assert float(disorder.max()) > 0               # actually shuffles


def _sd_et_sink_rows(skew, lateness, batches=6, seed=3, parallelism=None):
    """Run sd_et and capture the exact bytes the sink receives."""
    app = spike_detection_eventtime(skew=skew, lateness=lateness)
    rows = []
    k = app.kernels["sink"]

    def spy(batch, state):
        rows.append(batch.copy())
        return k(batch, state)

    app.kernels["sink"] = spy
    res = run_app(app, parallelism or {n: 1 for n in app.graph.operators},
                  batch=64, max_batches=batches, seed=seed)
    return (np.concatenate(rows) if rows else np.zeros((0, 4))), res


# ---------------------------------------------------------------------------
# determinism contract (CI acceptance)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("skew", [1.0, 4.0, 8.0])
def test_pane_bytes_identical_ordered_vs_shuffled(skew):
    """Any skew within the lateness bound cannot change pane contents:
    shuffled input produces byte-identical sink rows to ordered input."""
    ordered, r0 = _sd_et_sink_rows(skew=0.0, lateness=8.0)
    shuffled, r1 = _sd_et_sink_rows(skew=skew, lateness=8.0)
    assert len(ordered) > 0
    assert ordered.tobytes() == shuffled.tobytes()
    assert r1.late_drops == 0                          # within the bound
    assert r0.panes_fired == r1.panes_fired


def test_pane_bytes_identical_across_parallelism():
    """The watermark min-merge across replica fan-in preserves the same
    panes when the pipeline runs wider (sink rows arrive jumbo-reordered,
    so compare as multisets of rows)."""
    a, _ = _sd_et_sink_rows(skew=4.0, lateness=8.0)
    b, _ = _sd_et_sink_rows(skew=4.0, lateness=8.0,
                            parallelism={"parser": 3})
    assert np.array_equal(a[np.lexsort(a.T[::-1])],
                          b[np.lexsort(b.T[::-1])])


def test_watermarks_monotone_per_lane(monkeypatch):
    """Every lane's watermark sequence observed at every merging executor
    is non-decreasing (the substrate's monotonicity invariant)."""
    seen = {}
    orig = Executor._on_watermark

    def spy(self, msg):
        seen.setdefault((self.name, msg.lane), []).append(msg.value)
        return orig(self, msg)

    monkeypatch.setattr(Executor, "_on_watermark", spy)
    _sd_et_sink_rows(skew=4.0, lateness=8.0, parallelism={"parser": 2})
    assert seen                                        # watermarks flowed
    for (consumer, lane), values in seen.items():
        assert values == sorted(values), (consumer, lane)
        assert values[-1] == math.inf                  # end-of-stream flush


def test_late_tuples_counted_not_silently_dropped():
    """Stragglers that cross watermark emissions beyond the lateness bound
    are tallied per replica and surfaced on the RuntimeResult — never
    silently discarded.  (Intra-batch skew can never be late: the spout
    emits its watermark after the batch, so only cross-batch disorder
    races the frontier.)"""
    batch, batches = 64, 8

    def straggler_source(n, seed):
        ets = seed * n + np.arange(n, dtype=np.float64)
        if seed >= 3:
            ets[0] = (seed - 3) * n     # 3 batches stale: beyond any pane
        return ets

    def k_pane(pane, state):
        return [np.array([float(len(pane))])]

    app = (Topology("straggler")
           .spout("s", straggler_source, exec_ns=100.0, event_time=0)
           .op("w", k_pane, exec_ns=100.0,
               state=StateSpec("value",
                               window=WindowSpec.time_sliding(
                                   8.0, 4.0, lateness=4.0)))
           .sink("sink", lambda b, st_: [], exec_ns=50.0)
           .build())
    res = run_app(app, {n: 1 for n in app.graph.operators}, batch=batch,
                  max_batches=batches, seed=0)
    assert res.late_drops == batches - 3               # one per stale batch
    assert res.states["w"][0].window.late_drops == res.late_drops
    # within the bound nothing is late
    _, res_ok = _sd_et_sink_rows(skew=8.0, lateness=8.0, batches=8)
    assert res_ok.late_drops == 0


# ---------------------------------------------------------------------------
# EventTimeWindowState unit contract
# ---------------------------------------------------------------------------

def _brute_force_panes(ets, rows, size, slide, bound):
    """Independent pane assignment: tuple t is in pane k iff
    k*slide <= t < k*slide + size; pane fires iff its end <= bound."""
    out = {}
    for k in range(0, int(max(ets) / slide) + 1):
        end = k * slide + size
        if not end <= bound:
            continue
        mask = (ets >= end - size) & (ets < end)
        if mask.any():
            out[round(end, 9)] = np.sort(rows[mask])
    return out


def test_window_state_matches_brute_force():
    rng = np.random.default_rng(5)
    ets = rng.uniform(0, 100, size=300)
    st_ = EventTimeWindowState(WindowSpec.time_sliding(7.0, 3.0))
    st_.insert(ets, 0.0)
    fired = st_.on_watermark(80.0)
    expected = _brute_force_panes(ets, ets, 7.0, 3.0, 80.0)
    assert {round(span[1], 9) for _, _, span in fired} == set(expected)
    for rows, _, span in fired:
        assert np.array_equal(np.sort(rows), expected[round(span[1], 9)])


def test_window_state_skips_empty_panes_and_flushes_on_inf():
    st_ = EventTimeWindowState(WindowSpec.time_tumbling(4.0))
    st_.insert(np.array([1.0, 2.0, 100.0]), 0.0)
    fired = st_.on_watermark(np.inf)
    spans = [span for _, _, span in fired]
    assert spans == [(0.0, 4.0), (100.0, 104.0)]       # no empty panes
    assert st_.panes_fired == 2
    # the frontier is closed: everything later is late, and counted
    assert st_.insert(np.array([3.0]), 0.0) == 1
    assert st_.late_drops == 1


def test_window_state_rejects_negative_event_times():
    st_ = EventTimeWindowState(WindowSpec.time_tumbling(4.0))
    with pytest.raises(ValueError, match=">= 0"):
        st_.insert(np.array([-1.0]), 0.0)


def test_window_pane_t0_is_oldest_arrival():
    st_ = EventTimeWindowState(WindowSpec.time_tumbling(4.0))
    st_.insert(np.array([0.5]), t0=10.0)
    st_.insert(np.array([1.5]), t0=3.0)
    [(rows, t0, span)] = st_.on_watermark(4.0)
    assert t0 == 3.0 and span == (0.0, 4.0) and len(rows) == 2


def test_time_windowspec_validation():
    with pytest.raises(ValueError, match="time window size"):
        WindowSpec.time_tumbling(0.0)
    with pytest.raises(ValueError, match="time window slide"):
        WindowSpec.time_sliding(4.0, 5.0)
    with pytest.raises(ValueError, match="lateness"):
        WindowSpec.time_sliding(4.0, 2.0, lateness=-1.0)
    with pytest.raises(ValueError, match="time=True"):
        WindowSpec(8, lateness=1.0)                    # count + lateness
    with pytest.raises(ValueError, match="time=True"):
        WindowSpec(8, time_by=0)                       # count + time_by
    assert WindowSpec.time_tumbling(4.0).is_tumbling


def test_runtime_rejects_shuffled_parallel_time_window():
    """Panes fire per replica from per-replica buffers, so replicating an
    event-time windowed operator behind a shuffle route would shatter
    every pane into partial aggregates — rejected, not silently wrong."""
    app = spike_detection_eventtime()
    with pytest.raises(ValueError, match="partial panes"):
        run_app(app, {"pane_stats": 2}, batch=64, max_batches=1)
    # keyed inputs shard panes by key ownership — a coherent semantic
    def k_pane(pane, state):
        return [np.array([float(len(pane))])]

    def src(b, sd):
        ets = sd * b + np.arange(b, dtype=np.float64)
        keys = np.arange(b, dtype=np.float64) % 7
        return np.stack([ets, keys], axis=1)

    keyed = (Topology("keyed-panes")
             .spout("s", src, exec_ns=100.0, event_time=0)
             .op("w", k_pane, exec_ns=100.0, partition="key", key_by=1,
                 state=StateSpec("value",
                                 window=WindowSpec.time_tumbling(
                                     16.0, time_by=0)))
             .sink("sink", lambda b, st_: [], exec_ns=50.0)
             .build())
    res = run_app(keyed, {"w": 2}, batch=64, max_batches=4)
    assert res.panes_fired > 0


def test_plan_execute_clamps_auto_parallelism_for_time_windows():
    """Plan.execute's host down-mapping must not replicate a shuffled
    event-time windowed operator behind the user's back."""
    plan = Job(spike_detection_eventtime()).plan(
        server_a(), optimizer="rlas", compress_ratio=5, bestfit=True,
        max_nodes=2000)
    assert plan.parallelism["pane_stats"] > 1       # the model wants more
    res = plan.execute(batches=2, batch=64).raw     # ...the host clamps
    assert res.panes_fired == res.sink_tuples > 0


def test_build_rejects_time_window_without_watermark_source():
    """The classic stuck-watermark deadlock is a build error, not a hang:
    a silent spout pins the merged watermark at -inf forever."""
    t = (Topology("stuck")
         .spout("s", lambda b, sd: np.arange(b, dtype=np.float64),
                exec_ns=100.0)                          # no event_time=
         .op("w", lambda p, st_: [p], exec_ns=100.0,
             state=StateSpec("value", window=WindowSpec.time_tumbling(8.0))))
    with pytest.raises(TopologyError, match="never fire"):
        t.build()


# ---------------------------------------------------------------------------
# watermark merge (runtime) — monotone lanes, min fan-in
# ---------------------------------------------------------------------------

def test_watermark_merger_min_and_monotone():
    m = WatermarkMerger(expected=2)
    assert m.update("a", 5.0) == -math.inf             # lane b unheard
    assert m.update("b", 3.0) == 3.0                   # min over lanes
    assert m.update("b", 1.0) == 3.0                   # regressions ignored
    assert m.lane("b") == 3.0
    assert m.update("a", 7.0) == 3.0
    assert m.update("b", 9.0) == 7.0


# ---------------------------------------------------------------------------
# planner + DES integration
# ---------------------------------------------------------------------------

def test_planner_prices_pane_buffer_and_occupancy():
    app = spike_detection_eventtime()
    spec = app.graph.operators["pane_stats"]
    w = app.state["pane_stats"].window
    # one buffered write + one gathered read per pane joined; the segmented
    # engine sorts once per watermark, so no per-pane straggler re-scan term
    expected_state = 16.0 * (1.0 + w.size / w.slide)
    assert spec.state_bytes == pytest.approx(expected_state)
    assert spec.mem_bytes == pytest.approx(64.0 + expected_state)
    # residency is occupancy in TUPLES (size + lateness event-time units at
    # one tick per tuple), not wall seconds — rate-independent
    assert spec.state_resident_tuples == pytest.approx(w.size + w.lateness)
    ev = Job(app).plan(server_a(), optimizer="ff").estimate(
        input_rate=1e5).raw
    assert ev.state_resident_bytes is not None
    assert ev.state_resident_bytes.sum() == pytest.approx(
        (w.size + w.lateness) * 64.0)
    # the retired wall-seconds Little's-law form would have priced this at
    # rate x residency x bytes — over-charging by orders of magnitude
    assert ev.state_resident_bytes.sum() < 1e5 * (w.size + w.lateness) * 64.0
    # WC declares no window at all: nothing pinned resident
    from repro.streaming.apps import word_count
    ev_wc = Job(word_count()).plan(server_a(), optimizer="ff").estimate(
        input_rate=1e5).raw
    assert ev_wc.state_resident_bytes.sum() == 0


def test_des_reports_pane_firing_latency():
    """Plan.simulate hands the declared time windows to the DES, which
    fires panes on watermark passage along the delivery tables and reports
    the completeness-wait latency no other layer models."""
    plan = Job(spike_detection_eventtime()).plan(server_a(), optimizer="ff")
    des = plan.simulate(input_rate=2e5, horizon=0.03).raw
    assert des.panes_fired > 0
    assert des.pane_latency_p99 >= des.pane_latency_p50 > 0
    # an explicit empty mapping disables pane pacing
    des_off = plan.simulate(input_rate=2e5, horizon=0.03,
                            time_windows=None).raw
    assert des_off.panes_fired == 0
    assert math.isnan(des_off.pane_latency_p50)


def test_des_rejects_bad_time_windows():
    app = spike_detection_eventtime()
    g = ExecutionGraph(app.graph, {n: 1 for n in app.graph.operators},
                       routes=app.routes())
    with pytest.raises(ValueError, match="unknown operators"):
        des_simulate(g, server_a(), [0] * g.n_units, input_rate=1e5,
                     time_windows={"ghost": WindowSpec.time_tumbling(4.0)})
    with pytest.raises(ValueError, match="count window"):
        des_simulate(g, server_a(), [0] * g.n_units, input_rate=1e5,
                     time_windows={"pane_stats": WindowSpec(8)})


def test_runtime_and_des_agree_on_pane_pacing():
    """Same ingest volume -> same pane cadence: the runtime's fired pane
    count matches the grid arithmetic the DES walks (up to the end-of-
    stream flush, which the runtime's +inf watermark completes and the
    finite-horizon DES does not see)."""
    batches, batch, seed = 8, 64, 3
    _, res = _sd_et_sink_rows(skew=0.0, lateness=0.0, batches=batches,
                              seed=seed)
    # the sd_et source ticks once per reading starting at seed*batch
    ets = np.arange(seed * batch, (seed + batches) * batch,
                    dtype=np.float64)
    ends = grid_pane_ends(-math.inf, ets[-1] + SD_ET_SIZE,
                          SD_ET_SIZE, SD_ET_SLIDE)
    k_lo, k_hi = pane_range(ets, SD_ET_SIZE, SD_ET_SLIDE)
    non_empty = {e for e in ends
                 if np.any((k_lo <= (e - SD_ET_SIZE) / SD_ET_SLIDE)
                           & ((e - SD_ET_SIZE) / SD_ET_SLIDE <= k_hi))}
    assert res.panes_fired == len(non_empty)


# ---------------------------------------------------------------------------
# migration audit mode (ROADMAP follow-on)
# ---------------------------------------------------------------------------

def _forgetful_app():
    """An app whose counter mutates undeclared dict scratch state."""
    def k_count(batch, state):
        c = state.setdefault("counts", np.zeros(32, np.int64))
        np.add.at(c, batch.astype(np.int64) % 32, 1)
        return [batch]

    return (Topology("forgetful")
            .spout("s", lambda b, sd: np.random.default_rng(sd)
                   .integers(0, 32, size=b).astype(np.float64),
                   exec_ns=100.0)
            .op("count", k_count, exec_ns=100.0)
            .sink("sink", lambda b, st_: [], exec_ns=50.0)
            .build())


def test_migration_audit_catches_forgetful_app():
    app = _forgetful_app()
    res = run_app(app, {n: 1 for n in app.graph.operators}, batch=64,
                  max_batches=2)
    # default: silent best-effort (seed behaviour, scratch left behind)
    migrate_states(app, res.states, {n: 1 for n in app.graph.operators})
    with pytest.raises(UndeclaredStateError, match="count#0.*counts"):
        migrate_states(app, res.states, {n: 1 for n in app.graph.operators},
                       audit=True)


def test_migration_audit_passes_declared_only_states():
    from repro.streaming.apps import word_count
    app = word_count()
    res = run_app(app, {n: 1 for n in app.graph.operators}, batch=64,
                  max_batches=2)
    for st_ in res.states["sink"]:
        st_.pop("seen", None)          # metric counters count as state too
    out = migrate_states(app, res.states,
                         {n: 1 for n in app.graph.operators}, audit=True)
    assert int(out["counter"][0].managed.table.sum()) > 0


# ---------------------------------------------------------------------------
# property tests (hypothesis; skipped when unavailable)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(size_n=st.integers(1, 40), slide_n=st.integers(1, 40),
           lateness_n=st.integers(0, 10), wm=st.floats(0.0, 300.0),
           skew=st.floats(0.0, 20.0), seed=st.integers(0, 2**16))
    def test_pane_assignment_equivalence_runtime_vs_des(
            size_n, slide_n, lateness_n, wm, skew, seed):
        """For random tumbling/sliding (size, slide) pairs, the runtime's
        fired panes are exactly the non-empty panes of the grid the DES
        walks (same `grid_pane_ends` arithmetic), and membership matches
        the pane definition — under shuffled arrival order."""
        slide = min(slide_n, size_n) * 0.5
        size = size_n * 0.5
        lateness = lateness_n * 0.5
        rng = np.random.default_rng(seed)
        ets = rng.uniform(0, 200, size=80)
        perm = shuffle_within_skew(ets, skew, rng)
        spec = WindowSpec.time_sliding(size, slide, lateness=lateness)
        st_ = EventTimeWindowState(spec)
        for chunk in np.array_split(ets[perm], 5):
            st_.insert(chunk, 0.0)
        fired = st_.on_watermark(wm)
        grid = set(np.round(grid_pane_ends(-math.inf, wm - lateness,
                                           size, slide), 9))
        k_lo, k_hi = pane_range(ets, size, slide)
        for rows, _, (start, end) in fired:
            assert round(end, 9) in grid               # DES grid == runtime
            k = round((end - size) / slide)
            member = ets[(k_lo <= k) & (k <= k_hi)]
            assert np.array_equal(np.sort(rows), np.sort(member))
        # completeness: every non-empty grid pane fired
        ends_fired = {round(end, 9) for _, _, (s0, end) in fired}
        for e in grid:
            k = round((e - size) / slide)
            if np.any((k_lo <= k) & (k <= k_hi)):
                assert round(e, 9) in ends_fired

    @settings(max_examples=80, deadline=None)
    @given(updates=st.lists(
        st.tuples(st.sampled_from(["a", "b", "c", "d"]),
                  st.floats(-100, 100)), min_size=4, max_size=40),
        seed=st.integers(0, 2**16))
    def test_watermark_merge_associativity(updates, seed):
        """Min-merge across replica fan-in is order- and grouping-
        independent: any interleaving of lane updates and any two-level
        merge tree yield the same final watermark."""
        lanes = {"a", "b", "c", "d"}
        if {u[0] for u in updates} != lanes:
            updates = updates + [(ln, -50.0) for ln in lanes]
        rng = np.random.default_rng(seed)
        flat = WatermarkMerger(expected=4)
        for lane, v in updates:
            flat.update(lane, v)
        shuffled = WatermarkMerger(expected=4)
        for i in rng.permutation(len(updates)):
            shuffled.update(*updates[i])
        # two-level tree: merge {a,b} and {c,d} then min the groups
        g1, g2 = WatermarkMerger(2), WatermarkMerger(2)
        for lane, v in updates:
            (g1 if lane in ("a", "b") else g2).update(lane, v)
        assert flat.merged == shuffled.merged == min(g1.merged, g2.merged)


# ---------------------------------------------------------------------------
# ISSUE 6 satellites: adaptive cadence, keyed DES pane multiplicity,
# probed residency pricing
# ---------------------------------------------------------------------------

def test_auto_watermark_cadence_resolution():
    """``watermark_every="auto"`` derives the cadence from the declared
    window grid; at the bench batch of 256 it reproduces the previously
    hand-calibrated 8 for sd_et, scales with batch size, and explicit int
    declarations stay as overrides."""
    from repro.streaming.apps import spike_detection_keyed
    from repro.streaming.runtime import prepare_app

    sd_et = spike_detection_eventtime         # default cadence is "auto"
    assert sd_et().watermark_every == {"spout": "auto"}
    assert prepare_app(sd_et(), batch=256).wm_every == {"spout": 8}
    assert prepare_app(sd_et(), batch=64).wm_every == {"spout": 32}
    # keyed pane groups fire ~one pane per occupied device per span:
    # far more panes per batch -> tighter cadence
    assert prepare_app(spike_detection_keyed(), batch=256).wm_every \
        == {"spout": 2}
    assert prepare_app(sd_et(watermark_every=5), batch=256).wm_every \
        == {"spout": 5}


def test_auto_cadence_pane_contents_invariant():
    """Cadence changes amortization, never pane contents: auto vs pinned
    cadence agree on every counter under deterministic replay."""
    kw = dict(batch=64, max_batches=6, seed=3)
    r_auto = run_app(spike_detection_eventtime(), **kw)          # every 32
    r_pin = run_app(spike_detection_eventtime(watermark_every=8), **kw)
    assert r_auto.panes_fired == r_pin.panes_fired > 0
    assert r_auto.late_drops == r_pin.late_drops
    assert [dict(s) for s in r_auto.states["sink"]] \
        == [dict(s) for s in r_pin.states["sink"]]


def test_runtime_pane_counts_match_replay_ledger():
    """The exact pane ledger (distinct non-empty (key, span) pairs over the
    replayed spout draws) equals the runtime's fired-pane count — the
    keyed-multiplicity acceptance check on sd_key, plus sd_et as the
    unkeyed degenerate case."""
    from repro.streaming.apps import spike_detection_keyed
    from repro.streaming.simulator import replay_pane_counts

    for make_app, op in [(spike_detection_keyed, "device_stats"),
                         (spike_detection_eventtime, "pane_stats")]:
        r = run_app(make_app(), batch=128, max_batches=6, seed=3)
        ledger = replay_pane_counts(make_app(), batches=6, batch=128, seed=3)
        assert r.panes_fired == ledger[op] > 0, op


def test_des_keyed_pane_multiplicity():
    """des_simulate scales pane firing by the probed per-span (key, span)
    multiplicity: sd_key fires ~one pane per occupied device per span, not
    one per span — the plumbed default matches the probe, and pane_keys=1.0
    reproduces the old bare grid walk for comparison."""
    from repro.streaming.apps import spike_detection_keyed
    from repro.streaming.simulator import probe_pane_keys

    mult = probe_pane_keys(spike_detection_keyed())["device_stats"]
    assert 4.0 < mult <= 8.0                  # 8 devices, dense occupancy

    plan = Job(spike_detection_keyed()).plan(server_a(), optimizer="ff")
    bare = plan.simulate(backend="des", horizon=0.004,
                         pane_keys={"device_stats": 1.0}).raw
    keyed = plan.simulate(backend="des", horizon=0.004).raw
    assert bare.panes_fired > 0
    assert keyed.panes_fired == pytest.approx(bare.panes_fired * mult,
                                              rel=0.05)
    with pytest.raises(ValueError, match="pane_keys"):
        plan.simulate(backend="des", horizon=0.004,
                      pane_keys={"nope": 2.0})


def _sparse_clock_app(stride):
    """An event-time app whose source clock advances ``stride`` ticks per
    tuple — the window then holds 1/stride as many rows resident."""
    def source(batch, seed):
        ets = (np.abs(seed) * batch
               + np.arange(batch, dtype=np.float64)) * stride
        return np.stack([ets, np.ones(batch)], axis=1)

    def k_win(rows, state):
        return [rows[:1]]

    return (Topology("sparse")
            .spout("s", source, exec_ns=100.0, tuple_bytes=16.0,
                   event_time=0)
            .op("win", k_win, exec_ns=100.0, tuple_bytes=16.0,
                selectivity=1.0 / 16.0,
                state=StateSpec("value", item_bytes=16.0,
                                reads_per_tuple=0, writes_per_tuple=0,
                                window=WindowSpec.time_sliding(
                                    64.0, 16.0, lateness=8.0, time_by=0)))
            .sink("k", lambda b, st: [], exec_ns=50.0)
            .build())


def test_probed_spacing_prices_window_residency():
    """Job construction reprices ``state_resident_tuples`` from the probed
    event-clock spacing: a stride-4 source holds a quarter of the declared
    one-tick-per-reading occupancy resident; the benchmark apps (spacing
    exactly 1.0) keep their declared value to the byte."""
    declared = WindowSpec.time_sliding(64.0, 16.0, lateness=8.0,
                                       time_by=0).resident_tuples()
    assert Job(_sparse_clock_app(1.0)).graph.operators["win"] \
        .state_resident_tuples == pytest.approx(declared)
    assert Job(_sparse_clock_app(4.0)).graph.operators["win"] \
        .state_resident_tuples == pytest.approx(declared / 4.0)
    # repricing flows into the planner's per-socket memory ledger
    ev_dense = Job(_sparse_clock_app(1.0)).plan(
        server_a(), optimizer="ff").estimate().raw
    ev_sparse = Job(_sparse_clock_app(4.0)).plan(
        server_a(), optimizer="ff").estimate().raw
    assert ev_sparse.state_resident_bytes.sum() \
        < ev_dense.state_resident_bytes.sum()
    # sd_et's source advances exactly one tick per reading: unchanged
    app = spike_detection_eventtime()
    assert Job(app).graph.operators["pane_stats"].state_resident_tuples \
        == app.graph.operators["pane_stats"].state_resident_tuples
