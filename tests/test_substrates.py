"""Optimizers, data pipeline, checkpointing, compression, autoshard."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.ckpt import checkpoint as ckpt
from repro.data.pipeline import BinTokenSource, Prefetcher, SyntheticLM
from repro.optim.compress import (dequantize_int8, flatten_bucket,
                                  quantize_int8, unflatten_bucket)
from repro.optim.optimizers import (adafactor, adamw, clip_by_global_norm,
                                    warmup_cosine)


# ---------------------------- optimizers ----------------------------------

def quad_problem(opt, steps=200):
    target = jnp.array([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros((3, 3)), "b": jnp.zeros(3)}
    state = opt.init(params)

    def loss_fn(p):
        pred = jnp.ones(3) @ p["w"] + p["b"]
        return jnp.sum((pred - target) ** 2)

    for _ in range(steps):
        grads = jax.grad(loss_fn)(params)
        params, state = opt.update(grads, state, params)
    return float(loss_fn(params))


def test_adamw_converges():
    assert quad_problem(adamw(1e-1)) < 1e-3


def test_adafactor_converges():
    # sign-SGD-like updates oscillate at ~lr without decay -> use a schedule
    sched = warmup_cosine(1e-1, warmup=5, total=600, floor=0.01)
    assert quad_problem(adafactor(sched), steps=600) < 1e-2


def test_adafactor_handles_stacked_3d_params():
    opt = adafactor(1e-2)
    params = {"experts": jnp.ones((4, 8, 16))}
    state = opt.init(params)
    grads = {"experts": jnp.ones((4, 8, 16)) * 0.1}
    new_p, state = opt.update(grads, state, params)
    assert new_p["experts"].shape == (4, 8, 16)
    assert np.all(np.isfinite(np.asarray(new_p["experts"])))


def test_clip_by_global_norm():
    tree = {"a": jnp.ones(4) * 10.0}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(20.0)
    _, norm2 = clip_by_global_norm(clipped, 1.0)
    assert float(norm2) == pytest.approx(1.0, rel=1e-5)


def test_warmup_cosine_shape():
    lr = warmup_cosine(1e-3, warmup=10, total=100)
    assert float(lr(jnp.int32(0))) < 1e-3 * 0.2
    assert float(lr(jnp.int32(10))) == pytest.approx(1e-3, rel=0.1)
    assert float(lr(jnp.int32(100))) < 1e-3 * 0.2


# ---------------------------- data pipeline -------------------------------

def test_synthetic_deterministic_and_resumable():
    a = SyntheticLM(4, 16, 100, seed=1)
    b1 = a.next_batch()
    b2 = a.next_batch()
    st = a.state()
    b3 = a.next_batch()
    b = SyntheticLM(4, 16, 100, seed=1)
    b.restore(st)
    b3b = b.next_batch()
    np.testing.assert_array_equal(b3["inputs"], b3b["inputs"])
    assert not np.array_equal(b1["inputs"], b2["inputs"])


def test_synthetic_shards_disjoint_streams():
    s0 = SyntheticLM(8, 16, 100, seed=1, shard_id=0, n_shards=2)
    s1 = SyntheticLM(8, 16, 100, seed=1, shard_id=1, n_shards=2)
    b0, b1 = s0.next_batch(), s1.next_batch()
    assert b0["inputs"].shape == (4, 16)
    assert not np.array_equal(b0["inputs"], b1["inputs"])


def test_bin_token_source(tmp_path):
    toks = np.arange(10_000, dtype=np.int32) % 97
    path = tmp_path / "corpus.bin"
    toks.tofile(path)
    src = BinTokenSource(str(path), batch=4, seq=32, seed=0)
    b = src.next_batch()
    assert b["inputs"].shape == (4, 32)
    # label shift property: labels are inputs shifted by one
    np.testing.assert_array_equal(b["inputs"][:, 1:], b["labels"][:, :-1])


def test_prefetcher_delivers_in_order():
    src = SyntheticLM(2, 8, 50, seed=3)
    ref = SyntheticLM(2, 8, 50, seed=3)
    pf = Prefetcher(src, prefetch=2)
    for _ in range(4):
        got = pf.next_batch()
        exp = ref.next_batch()
        np.testing.assert_array_equal(got["inputs"], exp["inputs"])
    pf.close()


# ---------------------------- checkpointing -------------------------------

def test_checkpoint_roundtrip_and_gc(tmp_path):
    tree = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones(4, jnp.bfloat16)}}
    for step in [1, 2, 3, 4]:
        ckpt.save(str(tmp_path), step, tree, extra={"step": step}, keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 4
    restored, extra = ckpt.restore(str(tmp_path), 4, tree)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))
    assert restored["nested"]["b"].dtype == jnp.bfloat16
    assert extra["step"] == 4
    # gc kept only 2
    kept = [n for n in os.listdir(tmp_path) if n.startswith("step_")]
    assert len(kept) == 2


def test_checkpoint_detects_corruption(tmp_path):
    tree = {"w": jnp.ones((8, 8))}
    path = ckpt.save(str(tmp_path), 1, tree)
    fn = os.path.join(path, "leaf_00000.npy")
    arr = np.load(fn)
    arr[0, 0] = 999.0
    np.save(fn, arr)
    with pytest.raises(AssertionError, match="corrupt"):
        ckpt.restore(str(tmp_path), 1, tree)


def test_checkpoint_ignores_uncommitted(tmp_path):
    tree = {"w": jnp.ones(3)}
    ckpt.save(str(tmp_path), 1, tree)
    # fake a crashed save
    os.makedirs(tmp_path / "step_00000002", exist_ok=True)
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_async_checkpointer(tmp_path):
    tree = {"w": jnp.ones((4, 4))}
    saver = ckpt.AsyncCheckpointer()
    saver.save(str(tmp_path), 7, tree)
    saver.join()
    assert ckpt.latest_step(str(tmp_path)) == 7


# ---------------------------- compression ---------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.floats(0.01, 100.0))
def test_int8_quantization_bounded_error(seed, scale):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (256,)) * scale
    q, s = quantize_int8(x, jax.random.fold_in(key, 1))
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) * 1.01          # within one quantum


def test_int8_stochastic_rounding_unbiased():
    key = jax.random.PRNGKey(0)
    x = jnp.full((512,), 0.3) * 1.7              # not on the int8 grid
    acc = np.zeros(512)
    n = 200
    for i in range(n):
        q, s = quantize_int8(x, jax.random.fold_in(key, i))
        acc += np.asarray(dequantize_int8(q, s))
    bias = np.abs(acc / n - np.asarray(x)).mean()
    assert bias < 5e-3


def test_bucket_roundtrip():
    tree = {"a": jnp.ones((3, 2), jnp.bfloat16), "b": jnp.zeros(5)}
    flat, meta = flatten_bucket(tree)
    assert flat.shape == (11,)
    back = unflatten_bucket(flat, meta)
    assert back["a"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(back["b"]),
                                  np.asarray(tree["b"]))


# ---------------------------- autoshard / elastic --------------------------

def test_autoshard_plans_and_elastic_degrades():
    from repro.configs import get
    from repro.launch.elastic import simulate_pod_failure
    cfg = get("granite_3_2b")
    before, after = simulate_pod_failure(cfg, 2, 1)
    assert before.est_throughput > 0
    assert after.est_throughput > 0
    # losing a pod cannot improve modeled throughput
    assert after.est_throughput <= before.est_throughput * 1.001
    assert set(after.stage_assignment.values()) <= {0}


def test_autoshard_prefers_collocating_pipeline_intra_pod():
    """Activation hops are cheap vs DCN; RLAS should not scatter adjacent
    stages across pods when one pod has capacity."""
    from repro.configs import get
    from repro.core.autoshard import plan_stages
    plan = plan_stages(get("smollm_360m"), n_pods=2, chips_per_pod=64,
                       microbatch=8, seq=1024)
    assert plan.throughput > 0
