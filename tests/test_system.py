"""End-to-end system behaviour: the full paper pipeline in one test."""
import numpy as np

from repro.core import rlas_optimize, server_a
from repro.streaming.apps import word_count
from repro.streaming.runtime import run_app
from repro.streaming.simulator import measure_capacity


def test_end_to_end_wordcount_pipeline():
    """Profile -> RLAS optimize -> model vs DES -> real execution, verified."""
    app = word_count()
    machine = server_a()
    res = rlas_optimize(app.graph, machine, input_rate=None,
                        compress_ratio=5, bestfit=True, max_nodes=5000)
    assert res.placement.feasible
    assert res.R > 2e7                              # tens of millions words/s
    des = measure_capacity(res.graph, machine, res.placement.placement,
                           horizon=0.006)
    assert abs(des.R - res.R) / des.R < 0.2         # model tracks measurement
    rt = run_app(app, {"splitter": 2, "counter": 2}, batch=256, duration=0.3)
    counted = sum(int(st.managed.table.sum())
                  for st in rt.states["counter"])
    assert counted == 10 * rt.spout_tuples           # exact semantics
