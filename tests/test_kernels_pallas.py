"""Pallas kernels (interpret=True on CPU) vs pure-jnp oracles.

Shape/dtype sweeps per the brief; hypothesis drives randomised GQA/window
combinations for the attention kernels.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.mamba_scan import mamba_scan_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize("b,hq,hkv,sq,skv,d", [
    (1, 2, 2, 128, 128, 64),
    (2, 4, 1, 64, 128, 32),          # MQA, q shorter than kv
    (1, 8, 2, 128, 128, 128),        # GQA 4:1
])
@pytest.mark.parametrize("causal,window", [
    (True, None), (False, None), (True, 48),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_pallas_matches_ref(b, hq, hkv, sq, skv, d, causal, window,
                                  dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    offset = skv - sq
    q = rand(ks[0], (b, hq, sq, d), dtype)
    k = rand(ks[1], (b, hkv, skv, d), dtype)
    v = rand(ks[2], (b, hkv, skv, d), dtype)
    out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 offset=offset, q_blk=32, kv_blk=32)
    exp = ref.attention_ref(q, k, v, causal=causal, window=window,
                            offset=offset)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol,
                               rtol=tol)


@settings(max_examples=10, deadline=None)
@given(st.sampled_from([1, 2]), st.sampled_from([1, 2, 4]),
       st.sampled_from([64, 128]), st.booleans(),
       st.sampled_from([None, 32, 64]))
def test_flash_pallas_hypothesis_sweep(b, group, s, causal, window):
    hkv = 2
    hq = hkv * group
    d = 32
    ks = jax.random.split(jax.random.PRNGKey(42), 3)
    q = rand(ks[0], (b, hq, s, d))
    k = rand(ks[1], (b, hkv, s, d))
    v = rand(ks[2], (b, hkv, s, d))
    out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 q_blk=32, kv_blk=32)
    exp = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=3e-5,
                               rtol=3e-5)


@pytest.mark.parametrize("b,hq,hkv,s,d", [
    (2, 4, 4, 256, 64),
    (3, 8, 2, 128, 32),
    (1, 16, 1, 512, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_pallas_matches_ref(b, hq, hkv, s, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q = rand(ks[0], (b, hq, d), dtype)
    k = rand(ks[1], (b, hkv, s, d), dtype)
    v = rand(ks[2], (b, hkv, s, d), dtype)
    length = jax.random.randint(ks[3], (b,), 1, s + 1)
    out = decode_attention_pallas(q, k, v, length=length, kv_blk=64)
    exp = ref.decode_attention_ref(q, k, v, length=length)
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("rows,d", [(64, 128), (100, 256), (8, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_pallas_matches_ref(rows, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 2)
    x = rand(ks[0], (rows, d), dtype)
    s = rand(ks[1], (d,))
    out = rmsnorm_pallas(x, s, rows_blk=32)
    exp = ref.rmsnorm_ref(x, s)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("bt,t,d_in,n,d_blk", [
    (2, 16, 64, 8, 32),
    (1, 32, 128, 16, 64),
    (3, 8, 32, 4, 32),
])
def test_mamba_pallas_matches_ref(bt, t, d_in, n, d_blk):
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    u = rand(ks[0], (bt, t, d_in))
    dt = jax.nn.softplus(rand(ks[1], (bt, t, d_in)))
    A = -jax.nn.softplus(rand(ks[2], (d_in, n)))
    B = rand(ks[3], (bt, t, n))
    C = rand(ks[4], (bt, t, n))
    D = jnp.ones((d_in,))
    y, hT = mamba_scan_pallas(u, dt, A, B, C, D, d_blk=d_blk)
    y_ref, h_ref = ref.mamba_scan_ref(u, dt, A, B, C, D)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(h_ref), atol=1e-4,
                               rtol=1e-4)


def test_mamba_pallas_carries_initial_state():
    bt, t, d_in, n = 1, 8, 32, 4
    ks = jax.random.split(jax.random.PRNGKey(4), 6)
    u = rand(ks[0], (bt, t, d_in))
    dt = jax.nn.softplus(rand(ks[1], (bt, t, d_in)))
    A = -jax.nn.softplus(rand(ks[2], (d_in, n)))
    B = rand(ks[3], (bt, t, n))
    C = rand(ks[4], (bt, t, n))
    D = jnp.ones((d_in,))
    h0 = rand(ks[5], (bt, d_in, n))
    y, hT = mamba_scan_pallas(u, dt, A, B, C, D, h0=h0, d_blk=32)
    y_ref, h_ref = ref.mamba_scan_ref(u, dt, A, B, C, D, h0=h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(h_ref), atol=1e-4)
