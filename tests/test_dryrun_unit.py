"""Unit tests for dry-run machinery that doesn't need 512 devices:
collective HLO parsing, sharding rules, roofline term math, input specs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import all_archs, get
from repro.launch.dryrun import _first_shape_bytes, collective_bytes
from repro.launch.specs import SHAPES, cell_plan, input_specs
from repro.models.config import ModelConfig


def test_shape_bytes_parser():
    line = ("  %all-reduce.7 = bf16[16,1024,2048]{2,1,0} "
            "all-reduce(%x), replica_groups={}")
    assert _first_shape_bytes(line) == 16 * 1024 * 2048 * 2
    tup = ("  %all-to-all.2 = (f32[8,64]{1,0}, f32[8,64]{1,0}) "
           "all-to-all(%a, %b)")
    assert _first_shape_bytes(tup, "all-to-all") == 2 * 8 * 64 * 4


def test_collective_bytes_classification():
    hlo = "\n".join([
        "HloModule m",
        "  %all-gather.1 = bf16[4,4]{1,0} all-gather(%p), dimensions={0}",
        "  %x.2 = f32[2]{0} add(%a, %b)",
        "  %reduce-scatter.3 = f32[8]{0} reduce-scatter(%y), dimensions={0}",
        "  ROOT %all-reduce.9 = f32[16]{0} all-reduce(%z)",
    ])
    c = collective_bytes(hlo)
    assert c["all-gather"] == 32
    assert c["reduce-scatter"] == 32
    assert c["all-reduce"] == 64
    assert c["all-to-all"] == 0
    assert c["count"] == 3


def test_collective_parser_ignores_fused_names():
    hlo = "  %my-all-reduce-fusion = f32[4]{0} fusion(%x), kind=kLoop"
    c = collective_bytes(hlo)
    assert c["count"] == 0


@pytest.mark.parametrize("arch", all_archs())
def test_input_specs_all_cells_defined(arch):
    cfg = get(arch)
    for shape in SHAPES:
        skip = cell_plan(cfg, shape)
        if skip:
            assert shape == "long_500k"
            continue
        spec = input_specs(cfg, shape)
        leaves = jax.tree.leaves(spec)
        assert leaves, (arch, shape)
        for leaf in leaves:
            assert isinstance(leaf, jax.ShapeDtypeStruct)


def test_long500k_applicability_matches_design():
    runnable = {a for a in all_archs()
                if cell_plan(get(a), "long_500k") is None}
    assert runnable == {"h2o_danube_1_8b", "xlstm_125m",
                        "jamba_1_5_large_398b"}


def test_param_pspec_rules_smoke():
    """Sharding rules produce valid specs for every arch's param tree."""
    from repro.launch.mesh import make_mesh
    from repro.launch.shardings import param_shardings
    from repro.models import model_api
    mesh = make_mesh((1, 1), ("data", "model"))
    for arch in ["smollm_360m", "jamba_1_5_large_398b", "deepseek_v3_671b",
                 "whisper_small", "xlstm_125m"]:
        cfg = get(arch, smoke=True)
        api = model_api(cfg)
        shapes = jax.eval_shape(lambda k: api.init(k, cfg),
                                jax.random.PRNGKey(0))
        shards = param_shardings(cfg, shapes, mesh, fsdp=True)
        n = len(jax.tree.leaves(shapes))
        assert len(jax.tree.leaves(
            shards, is_leaf=lambda x: hasattr(x, "spec"))) == n


def test_cache_pspec_rules_smoke():
    from repro.launch.mesh import make_mesh
    from repro.launch.shardings import cache_shardings
    from repro.models import model_api
    mesh = make_mesh((1, 1), ("data", "model"))
    for arch in ["h2o_danube_1_8b", "jamba_1_5_large_398b",
                 "deepseek_v3_671b", "whisper_small", "xlstm_125m"]:
        cfg = get(arch, smoke=True)
        api = model_api(cfg)
        cache = jax.eval_shape(lambda: api.init_cache(cfg, 2, max_len=8))
        shards = cache_shardings(cfg, cache, mesh)
        assert len(jax.tree.leaves(
            shards, is_leaf=lambda x: hasattr(x, "spec"))) == \
            len(jax.tree.leaves(cache))


def test_roofline_terms_math():
    from benchmarks.roofline import terms
    rec = {
        "status": "ok", "arch": "granite-3-2b", "shape": "train_4k",
        "mesh": "16x16", "flops": 1e14, "extra_flops": 0.0,
        "bytes_accessed": 1e12,
        "coll": {"all-gather": 5e9, "all-reduce": 5e9, "count": 10},
        "n_params": 2.6e9, "n_active": 2.6e9,
        "peak_bytes_per_device": 2**34, "param_bytes_per_device": 2e7,
        "opt_bytes_per_device": 4e7, "cache_bytes_per_device": 0.0,
    }
    t = terms(rec)
    assert t["t_compute"] == pytest.approx(1e14 / 197e12)
    assert t["t_collective"] == pytest.approx(1e10 / 50e9)
    model = 6 * 2.6e9 * 4096 * 256
    assert t["model_flops"] == pytest.approx(model)
    assert t["useful_ratio"] == pytest.approx(model / (1e14 * 256))
    assert t["dominant"] in ("compute", "memory", "collective")


def test_variants_registered_and_distinct():
    base = get("smollm_360m")
    var = get("smollm_360m_padheads")
    assert var.n_heads == 16 and base.n_heads == 15
    assert get("qwen3_moe_235b_a22b_cap1").capacity_factor == 1.0
    assert get("smollm_360m_padheads_fsdp").force_fsdp
