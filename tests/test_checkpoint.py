"""Aligned-barrier checkpointing: kill-mid-stream restore is byte-identical.

The contract (ISSUE 9): spouts inject numbered barriers on the declared
cadence, every executor snapshots its state at the aligned cut (device
dispatch windows drained first, so spout offsets never cover unretired
batches), and resuming a killed run from any completed checkpoint produces
the same sink counters, keyed state bytes, pane multiset and late drops as
never having stopped — on the threads and the processes backend alike.
Satellites pinned here: event-time pane buffers survive ``migrate_states``
across a mid-run replan (suspend mode), and a kernel crash mid-batch
releases every pooled-buffer lease back to its arena.
"""
import glob
import os
import signal
import subprocess
import sys

import numpy as np
import pytest

from repro.streaming.api import Topology, TopologyError
from repro.streaming.apps import (spike_detection_eventtime,
                                  spike_detection_keyed, word_count)
from repro.streaming.checkpoint import (Checkpoint, checkpoint_uids,
                                        list_checkpoints, restore_checkpoint,
                                        save_checkpoint)
from repro.streaming.procexec import run_app_processes
from repro.streaming.runtime import _Arena, run_app
from repro.streaming.state import merge_keyed, migrate_states

WC_PAR = {"spout": 2, "parser": 1, "splitter": 2, "counter": 2, "sink": 1}

_RUNNERS = {"threads": run_app, "processes": run_app_processes}


def _run(backend, app, parallelism=None, **kw):
    return _RUNNERS[backend](app, parallelism, **kw)


def _wc_sig(rt):
    """Order-insensitive word-count fingerprint: sink rows + keyed bytes."""
    seen = sum(st.get("seen", 0) for st in rt.states["sink"])
    keyed = merge_keyed([st.managed for st in rt.states["counter"]])
    return seen, keyed.tobytes()


def _et_sig(rt, win_op):
    """Event-time fingerprint: sink accumulators + pane/late counters."""
    sink = {}
    for st in rt.states["sink"]:
        for k, v in st.items():
            if np.isscalar(v):
                sink[k] = sink.get(k, 0) + v
    return tuple(sorted(sink.items())), rt.panes_fired, rt.late_drops


def _resume_batches(total, ckpt):
    off = set(ckpt.spout_offsets.values())
    assert len(off) == 1, "aligned barriers cut every spout at one offset"
    return total - off.pop()


# ---------------------------------------------------------------------------
# declaration + round structure
# ---------------------------------------------------------------------------

def test_topology_checkpoint_every_validation():
    for bad in (0, -3, 2.5, True):
        with pytest.raises(TopologyError, match="checkpoint_every"):
            Topology("t", checkpoint_every=bad)
    with pytest.raises(ValueError, match="checkpoint_every"):
        run_app(word_count(), WC_PAR, max_batches=2, checkpoint_every=0)


def test_declared_cadence_flows_from_topology():
    def src(batch, seed):
        rng = np.random.default_rng(seed)
        return rng.normal(size=(batch, 2))

    app = (Topology("tiny", checkpoint_every=2)
           .spout("s", src, exec_ns=100.0)
           .sink("k", lambda b, st: st.__setitem__(
               "seen", st.get("seen", 0) + len(b)) or [], exec_ns=100.0)
           .build())
    assert app.checkpoint_every == 2
    rt = run_app(app, {}, batch=16, max_batches=6, seed=1)
    assert [c.ckpt_id for c in rt.checkpoints] == [1, 2, 3]
    # the run_app argument overrides the declaration
    rt = run_app(app, {}, batch=16, max_batches=6, seed=1, checkpoint_every=3)
    assert [c.ckpt_id for c in rt.checkpoints] == [1, 2]


def test_checkpoint_round_structure():
    app = word_count()
    rt = run_app(app, WC_PAR, batch=64, max_batches=20, seed=3,
                 checkpoint_every=4)
    assert [c.ckpt_id for c in rt.checkpoints] == [1, 2, 3, 4, 5]
    expected = checkpoint_uids(app, WC_PAR)
    for ck in rt.checkpoints:
        # a completed round holds one snapshot per replica of EVERY operator
        assert set(ck.states) == expected
        assert set(ck.spout_offsets) == {"spout#0", "spout#1"}
        assert all(off == 4 * ck.ckpt_id
                   for off in ck.spout_offsets.values())
        assert ck.app == "wc" and ck.batch == 64 and ck.seed == 3
        assert "wc" in ck.describe() and str(ck.ckpt_id) in ck.describe()


# ---------------------------------------------------------------------------
# resume parity: every checkpoint is a byte-identical continuation point
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["threads", "processes"])
def test_resume_parity_word_count(backend):
    app = word_count()
    base = _run(backend, app, WC_PAR, batch=64, max_batches=20, seed=3,
                checkpoint_every=4)
    want = _wc_sig(base)
    assert len(base.checkpoints) == 5
    for ck in base.checkpoints:
        rt = _run(backend, app, batch=64, seed=3,
                  max_batches=_resume_batches(20, ck), from_checkpoint=ck)
        assert _wc_sig(rt) == want, f"divergence resuming from {ck.ckpt_id}"


@pytest.mark.parametrize("backend", ["threads", "processes"])
def test_resume_parity_event_time(backend):
    """Pane buffers + watermark frontier restore: the resumed run fires the
    same panes and classifies the same tuples late as the uninterrupted
    one, even with the input shuffled within the lateness bound."""
    app = spike_detection_eventtime()
    base = _run(backend, app, batch=64, max_batches=24, seed=5,
                checkpoint_every=3)
    want = _et_sig(base, "pane_stats")
    assert base.panes_fired > 0
    for ck in base.checkpoints:
        rt = _run(backend, spike_detection_eventtime(), batch=64, seed=5,
                  max_batches=_resume_batches(24, ck), from_checkpoint=ck)
        assert _et_sig(rt, "pane_stats") == want, \
            f"divergence resuming from {ck.ckpt_id}"


def test_resume_parity_keyed_event_time_replicated():
    """Keyed pane groups: snapshots are per-replica and restore shard-true
    under replicated keyed windows."""
    app = spike_detection_keyed()
    par = {"spout": 1, "parser": 2, "device_stats": 2, "sink": 1}
    base = run_app(app, par, batch=64, max_batches=18, seed=2,
                   checkpoint_every=3)
    want = _et_sig(base, "device_stats")
    assert base.panes_fired > 0 and len(base.checkpoints) >= 5
    for ck in base.checkpoints[::2]:
        rt = run_app(spike_detection_keyed(), batch=64, seed=2,
                     max_batches=_resume_batches(18, ck), from_checkpoint=ck)
        assert _et_sig(rt, "device_stats") == want


# ---------------------------------------------------------------------------
# kill-mid-stream property: sweep the kill point over batch indices
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend,kills", [
    ("threads", range(3, 12)),           # every batch index once
    ("processes", (4, 7, 11)),           # spot checks (forks are pricier)
])
def test_kill_point_sweep_recovery(backend, kills, tmp_path):
    """Stop the run at batch ``k`` (any k, aligned with a barrier or not),
    restore the last checkpoint that *completed and persisted* before the
    kill, and the continuation must match the uninterrupted run."""
    total, every, seed = 12, 3, 11
    app = word_count()
    want = _wc_sig(_run(backend, app, WC_PAR, batch=64, max_batches=total,
                        seed=seed))
    for k in kills:
        d = tmp_path / f"{backend}-{k}"
        _run(backend, word_count(), WC_PAR, batch=64, max_batches=k,
             seed=seed, checkpoint_every=every, checkpoint_dir=str(d))
        ids = list_checkpoints(str(d))
        assert ids == list(range(1, k // every + 1))
        ck = restore_checkpoint(str(d))
        assert ck.ckpt_id == ids[-1]
        rt = _run(backend, word_count(), batch=64, seed=seed,
                  max_batches=_resume_batches(total, ck), from_checkpoint=ck)
        assert _wc_sig(rt) == want, f"kill at batch {k} diverged"


def test_kill_point_sweep_recovery_event_time(tmp_path):
    total, every, seed = 16, 4, 9
    want = _et_sig(run_app(spike_detection_eventtime(), batch=64,
                           max_batches=total, seed=seed), "pane_stats")
    for k in (5, 9, 14):
        d = tmp_path / str(k)
        run_app(spike_detection_eventtime(), batch=64, max_batches=k,
                seed=seed, checkpoint_every=every, checkpoint_dir=str(d))
        ck = restore_checkpoint(str(d))
        rt = run_app(spike_detection_eventtime(), batch=64, seed=seed,
                     max_batches=_resume_batches(total, ck),
                     from_checkpoint=ck)
        assert _et_sig(rt, "pane_stats") == want, f"kill at {k} diverged"


def test_sigkill_worker_recovery(tmp_path, monkeypatch):
    """The real thing on the processes backend: a worker dies by SIGKILL
    mid-stream.  The parent must fail fast, leave zero shared-memory
    orphans, and the on-disk checkpoints must replay to parity."""
    total, every, seed = 12, 3, 4

    def src(batch, seed):
        rng = np.random.default_rng(seed)
        return rng.normal(size=(batch, 2))

    def stage(b, st):
        st["nb"] = st.get("nb", 0) + 1
        kill_at = os.environ.get("BSR_TEST_KILL_AT")
        if kill_at and st["nb"] >= int(kill_at):
            os.kill(os.getpid(), signal.SIGKILL)
        return [b * 2.0]

    def make():
        return (Topology("killable")
                .spout("s", src, exec_ns=100.0)
                .op("f", stage, exec_ns=100.0)
                .sink("k", lambda b, st: st.__setitem__(
                    "seen", st.get("seen", 0) + len(b)) or [],
                    exec_ns=100.0)
                .build())

    def sig(rt):
        return (sum(st.get("seen", 0) for st in rt.states["k"]),
                sum(st.get("nb", 0) for st in rt.states["f"]))

    want = sig(run_app_processes(make(), batch=32, max_batches=total,
                                 seed=seed))
    d = str(tmp_path / "ckpts")
    monkeypatch.setenv("BSR_TEST_KILL_AT", "8")
    with pytest.raises((RuntimeError, TimeoutError), match="died|deadline"):
        run_app_processes(make(), batch=32, max_batches=total, seed=seed,
                          checkpoint_every=every, checkpoint_dir=d,
                          timeout=60.0)
    assert glob.glob("/dev/shm/bsr*") == []   # kill leaked no segments
    monkeypatch.delenv("BSR_TEST_KILL_AT")
    ck = restore_checkpoint(d)
    assert ck.ckpt_id >= 1                    # a pre-kill round persisted
    rt = run_app_processes(make(), batch=32, seed=seed,
                           max_batches=_resume_batches(total, ck),
                           from_checkpoint=ck)
    assert sig(rt) == want


# ---------------------------------------------------------------------------
# device operators: dispatch windows drain before a snapshot (satellite 3)
# ---------------------------------------------------------------------------

def _device_app(depth):
    def src(batch, seed):
        rng = np.random.default_rng(seed)
        return rng.normal(size=(batch, 4))

    def k_dev(b, st):
        st["nb"] = st.get("nb", 0) + 1
        return [b * 2.0]

    return (Topology("dev")
            .spout("s", src, exec_ns=100.0)
            .op("d", k_dev, exec_ns=300.0, device=True, device_ns=2000.0,
                dispatch_depth=depth)
            .sink("k", lambda b, st: st.__setitem__(
                "seen", st.get("seen", 0) + len(b)) or [], exec_ns=100.0)
            .build())


def test_device_window_drains_before_snapshot():
    """With a deep dispatch window, a barrier must retire every in-flight
    batch before the snapshot: the recorded spout offset covers exactly
    the batches whose results reached the sink — never a batch still in
    flight (the offsets-at-emit-time bug)."""
    batch, total, every = 32, 12, 2
    rt = run_app(_device_app(3), {}, batch=batch, max_batches=total, seed=6,
                 checkpoint_every=every)
    assert len(rt.checkpoints) == total // every
    for ck in rt.checkpoints:
        b = ck.spout_offsets["s#0"]
        assert b == every * ck.ckpt_id
        # the device op dispatched exactly the emitted batches...
        assert ck.states["d#0"]["scratch"]["nb"] == b
        # ...and every one of them was retired through to the sink
        assert ck.states["k#0"]["scratch"]["seen"] == b * batch


@pytest.mark.parametrize("depth", [1, 3])
def test_device_crash_resume_parity(depth, tmp_path):
    """Kill a device run mid-stream (graceful cut between barriers) and
    resume: depth 1 and depth N restore to the same bytes."""
    batch, total, every, kill = 32, 14, 3, 8
    want = run_app(_device_app(depth), {}, batch=batch, max_batches=total,
                   seed=8).states["k"][0]["seen"]
    d = str(tmp_path / "ck")
    run_app(_device_app(depth), {}, batch=batch, max_batches=kill, seed=8,
            checkpoint_every=every, checkpoint_dir=d)
    ck = restore_checkpoint(d)
    rt = run_app(_device_app(depth), batch=batch, seed=8,
                 max_batches=_resume_batches(total, ck), from_checkpoint=ck)
    assert rt.states["k"][0]["seen"] == want


def test_jitted_device_checkpoint_parity_in_clean_subprocess():
    """streaming_inference (jitted predictor, broadcast weights) through
    kill/restore on the processes backend — in a jax-clean child."""
    pytest.importorskip("jax")
    child = (
        "import sys\n"
        "from repro.streaming.apps import streaming_inference\n"
        "from repro.streaming.procexec import run_app_processes\n"
        "def sig(rt):\n"
        "    st = rt.states['sink'][0]\n"
        "    return (st['seen'], st['score'])\n"
        "app = streaming_inference(model_versions=1)\n"
        "base = run_app_processes(app, {}, batch=16, max_batches=12,\n"
        "                         seed=0, checkpoint_every=4,\n"
        "                         dispatch_depth=3)\n"
        "assert len(base.checkpoints) == 3, base.checkpoints\n"
        "for ck in base.checkpoints:\n"
        "    rem = 12 - ck.spout_offsets['spout#0']\n"
        "    rt = run_app_processes(streaming_inference(model_versions=1),\n"
        "                           batch=16, max_batches=rem, seed=0,\n"
        "                           from_checkpoint=ck, dispatch_depth=3)\n"
        "    assert sig(rt) == sig(base), ck.ckpt_id\n"
        "print('OK')\n")
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + \
        env.get("PYTHONPATH", "")
    cp = subprocess.run([sys.executable, "-c", child], capture_output=True,
                        text=True, timeout=600, env=env)
    assert cp.returncode == 0, cp.stdout + cp.stderr
    assert "OK" in cp.stdout


# ---------------------------------------------------------------------------
# event-time pane buffers survive migrate_states (satellite 1)
# ---------------------------------------------------------------------------

def test_migrated_event_time_windows_carry_when_suspended():
    """Suspend an ET run mid-stream (final_watermark=False), migrate its
    states, and continue: the migrated run fires the same pane multiset as
    never having stopped — buffered panes and the watermark frontier ride
    along instead of being dropped (the lossy-replan bug)."""
    total, cut, seed = 24, 10, 5
    app = spike_detection_eventtime()
    base = run_app(spike_detection_eventtime(), batch=64, max_batches=total,
                   seed=seed)
    r1 = run_app(app, batch=64, max_batches=cut, seed=seed,
                 final_watermark=False)
    assert r1.panes_fired < base.panes_fired    # the cut left panes buffered
    seeded = migrate_states(app, r1.states,
                            {n: 1 for n in app.graph.operators})
    r2 = run_app(spike_detection_eventtime(), batch=64,
                 max_batches=total - cut, seed=seed + cut,
                 initial_states=seeded)
    # window counters are window state: migrated totals accumulate r1's
    assert r2.panes_fired == base.panes_fired
    assert r2.late_drops == base.late_drops
    sink = lambda rt: {k: sum(st.get(k, 0) for st in rt.states["sink"])
                       for k in ("seen", "spikes")}
    b, s1, s2 = sink(base), sink(r1), sink(r2)
    assert {k: s1[k] + s2[k] for k in b} == b


def test_migrated_keyed_event_time_windows_carry_across_replan():
    """The same carry across a replica-count change: keyed pane buffers
    reshard by key ownership, so a 1 -> 2 replan mid-stream stays
    pane-multiset-identical."""
    total, cut, seed = 18, 8, 3
    app = spike_detection_keyed()
    base_par = {n: 1 for n in app.graph.operators}
    new_par = dict(base_par, device_stats=2, parser=2)
    base = run_app(spike_detection_keyed(), dict(base_par), batch=64,
                   max_batches=total, seed=seed)
    r1 = run_app(app, dict(base_par), batch=64, max_batches=cut, seed=seed,
                 final_watermark=False)
    seeded = migrate_states(app, r1.states, new_par)
    r2 = run_app(spike_detection_keyed(), new_par, batch=64,
                 max_batches=total - cut, seed=seed + cut,
                 initial_states=seeded)
    assert r2.panes_fired == base.panes_fired
    assert r2.late_drops == base.late_drops
    sink = lambda rt: sum(st.get("seen", 0) for st in rt.states["sink"])
    assert sink(r1) + sink(r2) == sink(base)


# ---------------------------------------------------------------------------
# kernel crash mid-batch releases pooled-buffer leases (satellite 2)
# ---------------------------------------------------------------------------

def test_failed_run_releases_arena_leases():
    """A kernel raising with a non-empty device dispatch window must not
    strand arena buffers: the in-flight batches' leases and the crashing
    batch's own lease all release, returning the arena to baseline."""
    calls = []

    def boom(b, st):
        calls.append(len(b))
        if len(calls) >= 2:
            raise RuntimeError("injected kernel crash")
        return []

    def src(batch, seed):
        rng = np.random.default_rng(seed)
        return rng.normal(size=(batch, 2))

    app = (Topology("crashy")
           .spout("s", src, exec_ns=100.0)
           # halve each batch so jumbos aggregate through the arena —
           # full-batch passthrough would ride the zero-copy, lease-free path
           .op("h", lambda b, st: [b[: len(b) // 2]], exec_ns=100.0)
           .sink("d", boom, exec_ns=100.0, device=True, device_ns=500.0,
                 dispatch_depth=2)
           .build())
    baseline = _Arena.outstanding_total()
    rt = run_app(app, {}, batch=32, max_batches=4, seed=1)
    assert len(calls) == 2                      # crashed on the second jumbo
    assert _Arena.outstanding_total() == baseline
    assert rt.spout_tuples == 4 * 32            # the run itself completed


def test_clean_run_keeps_arena_at_baseline():
    baseline = _Arena.outstanding_total()
    run_app(word_count(), WC_PAR, batch=64, max_batches=6, seed=0,
            checkpoint_every=2)
    assert _Arena.outstanding_total() == baseline


# ---------------------------------------------------------------------------
# persistence + validation
# ---------------------------------------------------------------------------

def test_checkpoint_disk_round_trip(tmp_path):
    d = str(tmp_path)
    rt = run_app(word_count(), WC_PAR, batch=64, max_batches=8, seed=1,
                 checkpoint_every=2, checkpoint_dir=d)
    assert list_checkpoints(d) == [1, 2, 3, 4]
    ck = restore_checkpoint(d)
    assert isinstance(ck, Checkpoint) and ck.ckpt_id == 4
    ck2 = restore_checkpoint(d, ckpt_id=2)
    assert ck2.ckpt_id == 2
    assert ck2.spout_offsets == rt.checkpoints[1].spout_offsets
    # explicit save of an in-memory checkpoint lands loadable
    p = str(tmp_path / "again")
    save_checkpoint(rt.checkpoints[0], p)
    assert restore_checkpoint(p).ckpt_id == 1
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(str(tmp_path / "empty"))


def test_resume_validation_rejects_torn_requests():
    rt = run_app(word_count(), WC_PAR, batch=64, max_batches=4, seed=1,
                 checkpoint_every=2)
    ck = rt.checkpoints[-1]
    with pytest.raises(ValueError, match="seed"):
        run_app(word_count(), max_batches=2, batch=64, seed=2,
                from_checkpoint=ck)
    with pytest.raises(ValueError, match="batch"):
        run_app(word_count(), max_batches=2, batch=32, seed=1,
                from_checkpoint=ck)
    with pytest.raises(ValueError, match="parallelism|replica"):
        run_app(word_count(), dict(WC_PAR, counter=3), max_batches=2,
                batch=64, seed=1, from_checkpoint=ck)
    with pytest.raises(ValueError, match="initial_states|initial_offsets"):
        run_app(word_count(), max_batches=2, batch=64, seed=1,
                from_checkpoint=ck,
                initial_offsets={"spout": 2})
    with pytest.raises(ValueError, match="app"):
        run_app(spike_detection_eventtime(), max_batches=2, batch=64,
                seed=1, from_checkpoint=ck)
    with pytest.raises(ValueError, match="Checkpoint"):
        run_app(word_count(), max_batches=2, batch=64, seed=1,
                from_checkpoint={"not": "a checkpoint"})
