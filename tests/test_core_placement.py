"""B&B placement: optimality vs brute force, heuristics, bound validity."""
import math

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (ExecutionGraph, LogicalGraph, OperatorSpec, bnb_place,
                        brute_force_place, evaluate, server_a, server_b,
                        subset)
from repro.core.baselines import ff_place, random_plan, rr_place
from repro.core.perfmodel import UNPLACED
from repro.core.placement import _Search


def chain_graph(n_ops: int, te: float = 100.0, nbytes: float = 256.0,
                spout_te: float = 400.0, mem: float = 64.0) -> LogicalGraph:
    ops = {"spout": OperatorSpec("spout", spout_te, nbytes, mem,
                                 is_spout=True)}
    edges = []
    prev = "spout"
    for i in range(n_ops):
        name = f"op{i}"
        ops[name] = OperatorSpec(name, te, nbytes, mem)
        edges.append((prev, name))
        prev = name
    return LogicalGraph(ops, edges)


@st.composite
def random_dag(draw):
    """Small random layered DAGs with random profiles."""
    n = draw(st.integers(2, 5))
    ops = {"spout": OperatorSpec(
        "spout", draw(st.floats(50, 2000)), is_spout=True)}
    edges = []
    names = ["spout"]
    for i in range(n):
        name = f"op{i}"
        te = draw(st.floats(20, 3000))
        nbytes = draw(st.sampled_from([64.0, 256.0, 1024.0, 4096.0]))
        sel = draw(st.sampled_from([0.5, 1.0, 2.0]))
        ops[name] = OperatorSpec(name, te, nbytes, nbytes, sel)
        k = draw(st.integers(1, min(2, len(names))))
        prods = draw(st.permutations(names))[:k]
        for p in prods:
            edges.append((p, name))
        names.append(name)
    return LogicalGraph(ops, edges)


def tiny_machine(n_sockets=3, cores=2):
    base = subset(server_a(), n_sockets)
    import dataclasses
    return dataclasses.replace(base, cores_per_socket=cores,
                               name=f"tiny{n_sockets}x{cores}")


@settings(max_examples=25, deadline=None)
@given(random_dag())
def test_bnb_matches_brute_force(lg):
    """Exhaustive B&B (bestfit off, no infeasible pruning) is optimal."""
    m = tiny_machine()
    g = ExecutionGraph(lg, {name: 1 for name in lg.operators})
    bf = brute_force_place(g, m, input_rate=None)
    bb = bnb_place(g, m, input_rate=None, bestfit=False)
    assert bb.R == pytest.approx(bf.R, rel=1e-9)


@settings(max_examples=25, deadline=None)
@given(random_dag())
def test_bound_dominates_all_completions(lg):
    """The bounding function is a true upper bound on any completion."""
    m = tiny_machine(n_sockets=2, cores=4)
    g = ExecutionGraph(lg, {name: 1 for name in lg.operators})
    n = g.n_units
    order = g.topo_unit_order()
    search = _Search(g, m, None, False, 10**9, None)
    # place a random prefix, bound it, then check every completion
    rng = np.random.default_rng(0)
    depth = int(rng.integers(0, n))
    from repro.core.placement import _State
    stt = _State(n, m)
    for d in range(depth):
        search._apply(stt, order[d], int(rng.integers(m.n_sockets)))
    bound = search._bound(stt, depth)
    import itertools
    for tail in itertools.product(range(m.n_sockets), repeat=n - depth):
        placement = list(stt.placement)
        for d, s in zip(range(depth, n), tail):
            placement[order[d]] = s
        ev = evaluate(g, m, placement, None, mix="weighted")
        assert ev.R <= bound * (1 + 1e-9)


def test_bnb_prefers_collocation_for_fetch_heavy_ops():
    m = server_a()
    lg = chain_graph(2, te=100.0, nbytes=4096.0, spout_te=150.0)
    g = ExecutionGraph(lg, {n: 1 for n in lg.operators})
    res = bnb_place(g, m, input_rate=None)
    # fetch cost dwarfs exec cost -> everything lands on one socket
    assert len(set(res.placement)) == 1
    assert res.feasible


def test_bnb_spreads_when_cores_run_out():
    m = tiny_machine(n_sockets=2, cores=2)
    lg = chain_graph(3, te=100.0, nbytes=64.0, spout_te=100.0)
    g = ExecutionGraph(lg, {n: 1 for n in lg.operators})
    res = bnb_place(g, m, input_rate=None)
    assert res.feasible
    assert len(set(res.placement)) == 2          # 4 busy units, 2 cores/socket


def test_bestfit_fast_and_close():
    m = server_a()
    lg = chain_graph(4, te=200.0, nbytes=1024.0)
    g = ExecutionGraph(lg, {n: 1 for n in lg.operators})
    exact = bnb_place(g, m, input_rate=None, bestfit=False)
    fast = bnb_place(g, m, input_rate=None, bestfit=True)
    assert fast.nodes_explored <= exact.nodes_explored
    assert fast.R >= 0.8 * exact.R


def test_rlas_beats_ff_and_rr_on_numa_sensitive_graph():
    """Heterogeneous tuple sizes + tight cores: WHICH edge crosses matters.

    The chain must split across sockets (2 cores each).  Edges into A/B/D
    carry fat tuples (expensive to fetch remotely); the edge into C is thin.
    RLAS cuts at C; distance-blind strategies usually cut a fat edge.
    """
    m = tiny_machine(n_sockets=4, cores=2)
    fat, thin = 8192.0, 64.0
    ops = {
        "spout": OperatorSpec("spout", 450.0, 64.0, 64.0, is_spout=True),
        "A": OperatorSpec("A", 150.0, fat, 64.0),
        "B": OperatorSpec("B", 150.0, fat, 64.0),
        "C": OperatorSpec("C", 150.0, thin, 64.0),
        "D": OperatorSpec("D", 150.0, fat, 64.0),
    }
    lg = LogicalGraph(ops, [("spout", "A"), ("A", "B"), ("B", "C"),
                            ("C", "D")])
    g = ExecutionGraph(lg, {n: 1 for n in ops})
    rlas = bnb_place(g, m, input_rate=None)
    ff = ff_place(g, m, input_rate=None)
    rr = rr_place(g, m, input_rate=None)
    assert rlas.feasible
    # the only good plan cuts at the thin edge: {spout,A,B} | {C,D}
    pl = dict(zip(["spout", "A", "B", "C", "D"], rlas.placement))
    crossing = [(u, v) for u, v in lg.edges if pl[u] != pl[v]]
    assert crossing == [("B", "C")]
    # distance-blind strategies cut a fat edge -> order-of-magnitude worse
    assert rlas.R > rr.R * 10
    assert rlas.R > ff.R * 10


def test_symmetry_collapse_reduces_nodes():
    m = server_a()
    lg = chain_graph(3)
    g = ExecutionGraph(lg, {n: 1 for n in lg.operators})
    res = bnb_place(g, m, input_rate=None)
    # without collapse the root alone would branch 8 ways; with collapse the
    # whole search on a symmetric machine stays tiny
    assert res.nodes_explored < 2000


def test_infeasible_instance_reports_failure():
    m = tiny_machine(n_sockets=1, cores=1)
    lg = chain_graph(3)                          # 4 busy units on 1 core
    g = ExecutionGraph(lg, {n: 1 for n in lg.operators})
    res = bnb_place(g, m, input_rate=None)
    assert not res.feasible


@settings(max_examples=15, deadline=None)
@given(random_dag(), st.integers(0, 10_000))
def test_random_plans_never_beat_exact_bnb(lg, seed):
    """Monte-Carlo property (paper Fig. 14) on tiny instances."""
    m = tiny_machine(n_sockets=2, cores=3)
    g = ExecutionGraph(lg, {name: 1 for name in lg.operators})
    bb = bnb_place(g, m, input_rate=None, bestfit=False)
    rng = np.random.default_rng(seed)
    placement = [int(rng.integers(m.n_sockets)) for _ in range(g.n_units)]
    ev = evaluate(g, m, placement, None)
    if ev.feasible:
        assert ev.R <= bb.R * (1 + 1e-9)
