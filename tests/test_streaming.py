"""Streaming substrate: apps, simulators, and the real threaded runtime."""
import numpy as np
import pytest

from repro.core import ExecutionGraph, evaluate, server_a
from repro.streaming import Job
from repro.streaming.apps import (ALL_APPS, fraud_detection, linear_road,
                                  spike_detection, word_count)
from repro.streaming.runtime import run_app
from repro.streaming.simulator import (des_simulate, fluid_solve,
                                       measure_capacity)


@pytest.fixture(scope="module")
def wc():
    return word_count()


def test_all_apps_build_valid_dags():
    for name, make in ALL_APPS.items():
        app = make()
        order = app.graph.topo_order()
        assert len(order) == len(app.graph.operators)
        assert app.graph.spouts(), name
        assert app.graph.sinks(), name


def test_wc_model_throughput_order_of_magnitude():
    """On Server A the optimized WC plan should reach tens of millions of
    words/sec (paper Table 4: 96.4M measured, 104.8M estimated)."""
    plan = Job(word_count()).plan(server_a(), optimizer="rlas",
                                  compress_ratio=5, bestfit=True,
                                  max_nodes=5000)
    assert plan.feasible
    assert 2e7 <= plan.R <= 3e8


def test_fluid_matches_model_when_uncontended(wc):
    g = ExecutionGraph(wc.graph, {n: 1 for n in wc.graph.operators})
    placement = [0] * g.n_units
    model = evaluate(g, server_a(), placement, input_rate=None)
    fluid = fluid_solve(g, server_a(), placement, input_rate=None)
    assert fluid.converged
    assert fluid.R == pytest.approx(model.R, rel=0.01)


def test_fluid_degrades_oversubscribed_socket(wc):
    import dataclasses
    m = dataclasses.replace(server_a(), cores_per_socket=2)
    g = ExecutionGraph(wc.graph, {n: 2 for n in wc.graph.operators})
    placement = [0] * g.n_units          # 10 busy threads on 2 cores
    fluid = fluid_solve(g, m, placement, input_rate=None)
    ok = fluid_solve(g, server_a(), placement, input_rate=None)
    assert fluid.R < ok.R                # processor sharing hurts
    assert fluid.cpu_scale[0] < 1.0


def test_des_approaches_fluid_estimate(wc):
    g = ExecutionGraph(wc.graph, {n: 1 for n in wc.graph.operators})
    placement = [0] * g.n_units
    fluid = fluid_solve(g, server_a(), placement, input_rate=None)
    des = measure_capacity(g, server_a(), placement, batch=64, horizon=0.01)
    # DES includes batching and queueing effects; agree within 25%
    assert des.R == pytest.approx(fluid.R, rel=0.25)
    assert des.latency_p99 >= des.latency_p50 >= 0.0


def test_des_remote_plan_slower_than_local(wc):
    g = ExecutionGraph(wc.graph, {n: 1 for n in wc.graph.operators})
    local = measure_capacity(g, server_a(), [0] * g.n_units, horizon=0.01)
    remote = measure_capacity(g, server_a(), [0, 4, 0, 4, 0], horizon=0.01)
    assert remote.R < local.R


def test_des_underfed_tracks_ingress(wc):
    g = ExecutionGraph(wc.graph, {n: 1 for n in wc.graph.operators})
    des = des_simulate(g, server_a(), [0] * g.n_units, input_rate=1e5,
                       batch=64, horizon=0.05)
    # 1e5 sentences/s -> 1e6 words/s at the sink (selectivity 10)
    assert des.R == pytest.approx(1e6, rel=0.2)


# ---------------------------------------------------------------------------
# Real threaded runtime
# ---------------------------------------------------------------------------

def test_runtime_wc_counts_are_exact():
    app = word_count()
    res = run_app(app, {"splitter": 2, "counter": 2}, batch=128,
                  duration=0.4)
    assert res.spout_tuples > 0
    total_counted = sum(int(st.managed.table.sum())
                        for st in res.states["counter"])
    # every parsed sentence yields exactly 10 words, all of which are counted
    assert total_counted == 10 * res.spout_tuples
    # keyed partitioning: the two counters saw disjoint key ranges
    c0 = res.states["counter"][0].managed.table
    c1 = res.states["counter"][1].managed.table
    overlap = np.logical_and(c0 > 0, c1 > 0).sum()
    assert overlap == 0


def test_runtime_fd_flags_subset():
    app = fraud_detection()
    res = run_app(app, batch=128, duration=0.3)
    st = res.states["sink"][0]
    assert 0 <= st.get("flagged", 0) <= st.get("seen", 1)
    assert res.throughput > 0


def test_runtime_sd_runs():
    app = spike_detection()
    res = run_app(app, batch=128, duration=0.3)
    assert res.sink_tuples > 0


def test_runtime_lr_multi_stream():
    app = linear_road()
    res = run_app(app, batch=128, duration=0.4)
    assert res.sink_tuples > 0
    assert res.latency_p99 >= res.latency_p50


def test_runtime_lr_second_spout_feeds_history_keyed():
    """LR's historical-query stream: its own source, keyed on vehicle id."""
    app = linear_road()
    assert set(app.graph.spouts()) == {"spout", "hist_spout"}
    assert app.sources.keys() >= {"spout", "hist_spout"}
    res = run_app(app, {"toll_history": 2}, batch=128, duration=0.4)
    queries = sum(st.get("queries", 0) for st in res.states["toll_history"])
    assert queries > 0
    # keyed partitioning: the two history replicas own disjoint accounts
    a0 = res.states["toll_history"][0].managed.table
    a1 = res.states["toll_history"][1].managed.table
    assert a0.sum() + a1.sum() > 0
    assert np.logical_and(a0 > 0, a1 > 0).sum() == 0
    assert res.sink_tuples > 0


def test_des_lr_multi_spout_per_source_rates():
    """DES accepts per-spout ingress rates; history tuples reach the sink
    with selectivity one while the position stream keeps its own rate."""
    app = linear_road()
    g = ExecutionGraph(app.graph, {n: 1 for n in app.graph.operators},
                       routes=app.routes())
    rates = {"spout": 5e4, "hist_spout": 2e4}
    des = des_simulate(g, server_a(), [0] * g.n_units, input_rate=rates,
                       batch=64, horizon=0.05)
    # sink rate = toll (0.9 + 0.9 via its two inputs) + notification (0.1)
    # per position report, plus history at selectivity one
    expected = 5e4 * (0.9 + 0.9 + 0.1) + 2e4
    assert des.R == pytest.approx(expected, rel=0.25)


def test_runtime_jumbo_beats_per_tuple():
    """Fig. 16 factor analysis, for real: jumbo tuples amortise queue costs."""
    app = word_count()
    jumbo = run_app(app, batch=256, duration=0.4, jumbo=True)
    single = run_app(app, batch=256, duration=0.4, jumbo=False)
    assert jumbo.throughput > single.throughput


# ---------------------------------------------------------------------------
# the refcounted jumbo arena: flush views, release discipline, recycling
# ---------------------------------------------------------------------------

def test_jumbo_flush_is_read_only_view_recycled_on_release():
    from repro.streaming.runtime import _JumboBuffer
    buf = _JumboBuffer(4)
    assert buf.add(np.arange(3, dtype=np.int64), 1.0) == []
    ((view, t0, lease),) = buf.add(np.arange(1, dtype=np.int64), 2.0)
    assert not view.flags.writeable          # views are read-only...
    assert t0 == 1.0                         # oldest buffered t0 wins
    assert lease is not None and np.shares_memory(view, lease.buf)
    assert np.array_equal(view, [0, 1, 2, 0])
    store = lease.buf
    lease.release()                          # ...until released -> recycled
    buf.add(np.arange(2, dtype=np.int64), 3.0)
    assert buf._store is store               # same pooled buffer, no alloc


def test_lease_refcount_gates_recycling():
    from repro.streaming.runtime import _Arena
    arena = _Arena(cap=4)
    buf, lease = arena.acquire((), np.dtype(np.int64))
    lease.retain(2)                          # fan-out: 3 consumers total
    lease.release()
    lease.release()
    assert arena._free == []                 # live references pin the buffer
    lease.release()
    assert len(arena._free) == 1 and arena._free[0] is buf


def test_jumbo_zero_copy_passthrough_and_boundary_parity():
    """A full batch into an empty lane passes through by reference (no
    lease, no copy); the overflow path still concatenates so flush
    boundaries land exactly where the copying implementation put them."""
    from repro.streaming.runtime import _JumboBuffer
    buf = _JumboBuffer(4)
    a = np.arange(5, dtype=np.float64)
    ((out, t0, lease),) = buf.add(a, 1.5)
    assert out is a and lease is None        # zero-copy fast path
    assert buf.add(np.zeros(3), 2.0) == []
    ((out, t0, lease),) = buf.add(np.ones(3), 3.0)   # 3 + 3 > 4: overflow
    assert len(out) == 6 and t0 == 2.0 and lease is None
    assert out.flags.owndata                 # fresh concatenate, old boundary


def test_broadcast_shared_flush_parity():
    """Broadcast fan-out delivers one shared flush view per jumbo (lease
    refcounted across lanes) — every replica still sees the exact stream,
    byte-identical to fanout=1, under deterministic replay."""
    from repro.streaming.api import Topology

    def recorder(batch, state):
        state.setdefault("rows", []).append(
            np.ascontiguousarray(batch).tobytes())
        return []

    def build():
        return (Topology("bc")
                .spout("s", lambda b, sd: np.random.default_rng(sd)
                       .integers(0, 50, size=b).astype(np.int64),
                       exec_ns=100.0)
                .op("fan", recorder, exec_ns=100.0, partition="broadcast")
                .build())

    kw = dict(batch=64, max_batches=6, seed=7)
    solo = run_app(build(), {"fan": 1}, **kw)
    fan = run_app(build(), {"fan": 3}, **kw)
    ref = solo.states["fan"][0]["rows"]
    assert ref and all(st["rows"] == ref for st in fan.states["fan"])
