"""Managed keyed state contract: declaration validation, mem_bytes
derivation, keyed-store union invariance under parallelism sweeps, elastic
replan/migration round-trips (byte-identical state), window determinism vs
the seed moving_avg, broadcast model-sync, and the satellite plumbing
(fluid per-spout rates, bottleneck-aware down-mapping, DES state charge)."""
import dataclasses

import numpy as np
import pytest

from repro.core import ExecutionGraph, server_a, subset
from repro.streaming.api import Job, Topology, TopologyError, \
    _scale_parallelism
from repro.streaming.apps import (ALL_APPS, LR_VEHICLES, SD_WINDOW, WC_VOCAB,
                                  fd_model_weights, linear_road, word_count)
from repro.streaming.runtime import run_app
from repro.streaming.simulator import des_simulate, fluid_solve
from repro.streaming.state import (BroadcastTable, KeyedStore, OperatorState,
                                   StateSpec, WindowSpec, WindowState,
                                   make_operator_state, merge_keyed,
                                   migrate_states, repartition_keyed)


# ---------------------------------------------------------------------------
# declaration validation + derived planner weights
# ---------------------------------------------------------------------------

def test_statespec_validation():
    with pytest.raises(ValueError, match="unknown state kind"):
        StateSpec("sharded")
    with pytest.raises(ValueError, match="requires key_space"):
        StateSpec("keyed")
    with pytest.raises(ValueError, match="window size"):
        WindowSpec(0)
    with pytest.raises(ValueError, match="window slide"):
        WindowSpec(4, slide=5)
    assert WindowSpec.tumbling(8).is_tumbling


def test_topology_rejects_state_plus_hand_tuned_mem_bytes():
    t = Topology("t").spout("s", lambda b, sd: np.arange(b), exec_ns=100.0)
    with pytest.raises(TopologyError, match="derived from the state"):
        t.op("a", lambda b, st: [b], exec_ns=100.0, mem_bytes=96.0,
             partition="key",
             state=StateSpec("keyed", key_space=16))


def test_topology_rejects_keyed_state_without_keyed_route():
    t = Topology("t").spout("s", lambda b, sd: np.arange(b), exec_ns=100.0)
    with pytest.raises(TopologyError, match="sharded\n?.*by the operator"):
        t.op("a", lambda b, st: [b], exec_ns=100.0,
             state=StateSpec("keyed", key_space=16))


def test_mem_bytes_derived_from_state_declarations():
    """The paper's M is tuple_bytes + declared state traffic — the seed's
    hand-tuned constants, now derived."""
    expected = {
        "wc": ("counter", 32.0 + 64.0, 64.0),
        "sd": ("moving_avg", 64.0 + 128.0, 128.0),
        "lr": ("toll_history", 64.0 + 96.0, 96.0),
        "fd": ("predictor", 160.0 + 320.0, 320.0),
    }
    for name, (op, mem, state_bytes) in expected.items():
        spec = ALL_APPS[name]().graph.operators[op]
        assert spec.mem_bytes == pytest.approx(mem), (name, op)
        assert spec.state_bytes == pytest.approx(state_bytes), (name, op)


def test_planner_reports_state_usage_share():
    app = word_count()
    g = ExecutionGraph(app.graph, {n: 1 for n in app.graph.operators},
                       routes=app.routes())
    ev = Job(app).plan(server_a(), optimizer="ff").estimate().raw
    assert ev.state_usage is not None
    assert ev.state_usage.sum() > 0                 # counter state traffic
    assert np.all(ev.state_usage <= ev.mem_usage + 1e-9)
    del g


# ---------------------------------------------------------------------------
# keyed store: union invariant under parallelism sweeps
# ---------------------------------------------------------------------------

def _wc_counts(parallelism, batches, seed=11, **kw):
    res = run_app(word_count(), parallelism, batch=64,
                  max_batches=batches, **kw)
    return res, merge_keyed([st.managed
                             for st in res.states["counter"]])


@pytest.mark.parametrize("k", [2, 3, 5])
def test_keyed_union_invariant_across_parallelism(k):
    """Deterministic replay: the ownership-union of k counter shards equals
    the single-replica table byte for byte — keyed conservation extended to
    state."""
    _, ref = _wc_counts({"counter": 1}, batches=6)
    res, merged = _wc_counts({"counter": k, "splitter": 2}, batches=6)
    assert int(merged.sum()) == 10 * res.spout_tuples
    assert merged.tobytes() == ref.tobytes()
    # and each shard only ever touched the keys its route delivers
    for st in res.states["counter"]:
        store = st.managed
        foreign = store.table[~store.owned_mask()]
        assert not foreign.any()


def test_merge_and_repartition_round_trip():
    spec = StateSpec("keyed", key_space=97, dtype=np.int64)
    rng = np.random.default_rng(3)
    full = rng.integers(0, 50, size=97)
    for k in (1, 2, 4, 7):
        shards = repartition_keyed(spec, full, k)
        assert all(s.n_shards == k for s in shards)
        merged = merge_keyed(shards)
        assert merged.tobytes() == full.tobytes()


# ---------------------------------------------------------------------------
# elastic migration: interrupted + replanned == uninterrupted (CI acceptance)
# ---------------------------------------------------------------------------

def test_wc_migration_conservation_through_replan():
    """A WC run interrupted mid-stream, replanned onto a smaller machine via
    Plan.replan and resumed with migrated state yields byte-identical keyed
    state to an uninterrupted single-replica run."""
    total, cut, seed = 8, 3, 42
    app = word_count()
    ref = run_app(word_count(), {n: 1 for n in app.graph.operators},
                  batch=64, max_batches=total, seed=seed)
    ref_counts = ref.states["counter"][0].managed.table

    job = Job(app)
    par1 = {"spout": 1, "parser": 1, "splitter": 2, "counter": 3, "sink": 1}
    plan1 = job.plan(server_a(), optimizer="ff", parallelism=par1)
    r1 = plan1.execute(batches=cut, batch=64, seed=seed,
                       parallelism=par1).raw

    plan2 = plan1.replan(subset(server_a(), 2))     # elastic: lose 6 sockets
    assert plan2.machine.n_sockets == 2
    par2 = {"spout": 1, "parser": 1, "splitter": 1, "counter": 2, "sink": 1}
    seeded = migrate_states(app, r1.states, par2)
    r2 = plan2.execute(batches=total - cut, batch=64, seed=seed + cut,
                       parallelism=par2, initial_states=seeded).raw

    merged = merge_keyed([st.managed for st in r2.states["counter"]])
    assert merged.tobytes() == ref_counts.tobytes()
    # tuple conservation survives the cut too
    assert r1.spout_tuples + r2.spout_tuples == ref.spout_tuples
    assert int(merged.sum()) == 10 * ref.spout_tuples


def test_lr_account_balances_survive_replan():
    """LR: account balances (keyed toll_history store) survive a mid-run
    replan onto a different replica count, byte for byte."""
    total, cut, seed = 6, 2, 7
    app = linear_road()
    base = {n: 1 for n in app.graph.operators}
    ref = run_app(linear_road(), dict(base), batch=64,
                  max_batches=total, seed=seed)
    ref_acct = ref.states["toll_history"][0].managed.table

    r1 = run_app(app, dict(base, toll_history=3), batch=64,
                 max_batches=cut, seed=seed)
    seeded = migrate_states(app, r1.states, dict(base, toll_history=2))
    r2 = run_app(app, dict(base, toll_history=2), batch=64,
                 max_batches=total - cut, seed=seed + cut,
                 initial_states=seeded)
    merged = merge_keyed([st.managed for st in r2.states["toll_history"]])
    assert merged.tobytes() == ref_acct.tobytes()


def test_migrate_states_broadcast_and_value_semantics():
    spec_b = StateSpec("broadcast", init=lambda: np.arange(4.0))
    spec_v = StateSpec("value", init=lambda: np.zeros(2))

    class _App:
        pass

    t = (Topology("m")
         .spout("s", lambda b, sd: np.arange(b), exec_ns=100.0)
         .op("bc", lambda b, st: [b], exec_ns=100.0,
             partition="broadcast", state=spec_b)
         .op("val", lambda b, st: [b], exec_ns=100.0, state=spec_v))
    app = t.build()
    old = {"s": [make_operator_state(None)],
           "bc": [make_operator_state(spec_b)],
           "val": [make_operator_state(spec_v), make_operator_state(spec_v)]}
    old["bc"][0].managed.load(np.full(4, 9.0), version=5)
    old["val"][0].managed.value[:] = 3.0
    out = migrate_states(app, old, {"s": 1, "bc": 3, "val": 1})
    for st in out["bc"]:            # broadcast: every new replica synced
        assert st.managed.version == 5
        assert np.array_equal(st.managed.data, np.full(4, 9.0))
    # value: per-replica, best-effort carry of the surviving replicas
    assert np.array_equal(out["val"][0].managed.value, np.full(2, 3.0))


# ---------------------------------------------------------------------------
# windows: declarative sliding == seed moving_avg; tumbling chunks
# ---------------------------------------------------------------------------

def test_sliding_window_matches_seed_moving_avg():
    rng = np.random.default_rng(0)
    batches = [rng.normal(10.0, 2.0, size=n) for n in (64, 7, 128, 1)]
    win = WindowState(WindowSpec(SD_WINDOW))
    hist = np.zeros(SD_WINDOW)                      # the seed's hand-rolled path
    kernel = np.ones(SD_WINDOW) / SD_WINDOW
    for batch in batches:
        vals_seed = np.concatenate([hist, batch])
        avg_seed = np.convolve(vals_seed, kernel, "valid")[-len(batch):]
        hist = vals_seed[-SD_WINDOW:]
        vals_win = win.slide(batch)
        avg_win = np.convolve(vals_win, kernel, "valid")[-len(batch):]
        assert np.array_equal(avg_win, avg_seed)


def test_tumbling_window_emits_complete_chunks():
    win = WindowState(WindowSpec.tumbling(8), dtype=np.int64)
    out = win.tumble(np.arange(5))
    assert out == []
    out = win.tumble(np.arange(5, 20))
    assert [w.tolist() for w in out] == [list(range(0, 8)),
                                         list(range(8, 16))]
    out = win.tumble(np.arange(20, 24))
    assert [w.tolist() for w in out] == [list(range(16, 24))]


def test_sliding_path_rejects_hopping_window():
    win = WindowState(WindowSpec(8, slide=4))
    with pytest.raises(ValueError, match="tumble"):
        win.slide(np.arange(4))
    # hop-4 windows advance by 4
    out = win.tumble(np.arange(12))
    assert [w.tolist() for w in out] == [list(range(0, 8)),
                                         list(range(4, 12))]


# ---------------------------------------------------------------------------
# broadcast state: FD's model-sync stream keeps replicas identical
# ---------------------------------------------------------------------------

def test_fd_broadcast_model_sync_keeps_replicas_identical():
    app = ALL_APPS["fd"]()
    assert set(app.graph.spouts()) == {"spout", "model_spout"}
    assert app.routes().strategy("model_spout", "predictor") == "broadcast"
    assert app.routes().strategy("parser", "predictor") == "shuffle"
    n_upd = 4
    res = run_app(app, {"predictor": 3}, batch=64, max_batches=n_upd,
                  seed=2)
    tables = [st.managed for st in res.states["predictor"]]
    # every replica applied the same final update (lane-FIFO broadcast)
    last = fd_model_weights(2 + n_upd - 1)
    for t in tables:
        assert t.version == 2 + n_upd - 1
        assert np.array_equal(t.data, last)
    seen = sum(st.get("seen", 0) for st in res.states["sink"])
    assert seen == res.spout_tuples - n_upd * 64    # updates emit no scores


# ---------------------------------------------------------------------------
# satellites: fluid per-spout rates, down-mapping, DES state charge
# ---------------------------------------------------------------------------

def test_fluid_accepts_per_spout_rate_dicts_like_des():
    app = linear_road()
    g = ExecutionGraph(app.graph, {n: 1 for n in app.graph.operators},
                       routes=app.routes())
    m = server_a()
    rates = {"spout": 5e4, "hist_spout": 2e4}
    fl = fluid_solve(g, m, [0] * g.n_units, input_rate=rates)
    assert fl.converged
    expected = 5e4 * (0.9 + 0.9 + 0.1) + 2e4
    assert fl.R == pytest.approx(expected, rel=0.01)
    des = des_simulate(g, m, [0] * g.n_units, input_rate=rates,
                       batch=64, horizon=0.05)
    assert des.R == pytest.approx(fl.R, rel=0.25)    # uniform across backends
    with pytest.raises(ValueError, match="non-spout operators"):
        fluid_solve(g, m, [0] * g.n_units, input_rate={"ghost": 1e4})


def test_fluid_rate_dict_matches_scalar_when_uniform():
    app = word_count()
    g = ExecutionGraph(app.graph, {n: 1 for n in app.graph.operators},
                       routes=app.routes())
    m = server_a()
    a = fluid_solve(g, m, [0] * g.n_units, input_rate=1e5)
    b = fluid_solve(g, m, [0] * g.n_units, input_rate={"spout": 1e5})
    assert a.R == pytest.approx(b.R)


def test_scale_parallelism_respects_bottleneck_ratios():
    plan = Job(word_count()).plan(server_a(), optimizer="rlas",
                                  compress_ratio=5, bestfit=True,
                                  max_nodes=5000)
    budget = max(len(plan.parallelism) + 2, plan.total_threads // 4)
    smart = _scale_parallelism(plan.parallelism, budget, plan.eval,
                               plan.graph)
    uniform = _scale_parallelism(plan.parallelism, budget)
    assert sum(smart.values()) <= budget
    assert all(v >= 1 for v in smart.values())
    assert all(smart[op] <= plan.parallelism[op] for op in smart)
    # the modelled bottleneck keeps the largest thread share under the
    # demand-aware rule (WC: the counter — 10 words per sentence x 612 ns)
    demand = {}
    for idx, rep in enumerate(plan.graph.replicas):
        demand[rep.op] = demand.get(rep.op, 0.0) + \
            float(plan.eval.utilization[idx])
    heaviest = max(plan.parallelism, key=lambda o: smart[o])
    assert heaviest == max(demand, key=demand.get) == "counter"
    # and the demand-aware allocation packs the budget at least as well
    assert sum(smart.values()) >= sum(
        min(u, plan.parallelism[o]) for o, u in uniform.items()) - len(smart)


def test_scale_parallelism_never_exceeds_budget_under_skew():
    """Regression: rounding sub-1 raw shares up to 1 each must not push the
    allocation past the thread budget."""
    from types import SimpleNamespace

    from repro.core import LogicalGraph, OperatorSpec

    lg = LogicalGraph({"a": OperatorSpec("a", 100.0, is_spout=True),
                       "b": OperatorSpec("b", 100.0),
                       "c": OperatorSpec("c", 100.0)},
                      [("a", "b"), ("b", "c")])
    par = {"a": 4, "b": 4, "c": 4}
    g = ExecutionGraph(lg, par)
    util = np.concatenate([np.full(4, 0.9 / 4), np.full(4, 0.05 / 4),
                           np.full(4, 0.05 / 4)])
    ev = SimpleNamespace(utilization=util)
    alloc = _scale_parallelism(par, 4, ev, g)
    assert sum(alloc.values()) == 4
    assert alloc == {"a": 2, "b": 1, "c": 1}        # skew goes to the hog


def test_broadcast_table_drops_stale_versions():
    """Regression: updates apply last-writer-wins by version, so replicas
    fed the same update set converge regardless of producer interleaving."""
    spec = StateSpec("broadcast", init=lambda: np.zeros(2))
    orders = [[(1, 10.0), (3, 30.0), (2, 20.0)],
              [(2, 20.0), (1, 10.0), (3, 30.0)]]
    finals = []
    for order in orders:
        t = BroadcastTable(spec)
        for v, x in order:
            t.load(np.full(2, x), version=v)
        finals.append((t.version, t.data.copy()))
    assert finals[0][0] == finals[1][0] == 3
    assert np.array_equal(finals[0][1], finals[1][1])
    # unversioned loads keep the local-bump convention
    t = BroadcastTable(spec)
    t.load(np.ones(2))
    assert t.version == 1


def test_des_charges_declared_state_bytes():
    """Squeezing local bandwidth stretches DES service times through the
    state-derived mem_bytes — the same spec the §3.3 constraint charges."""
    app = word_count()
    g = ExecutionGraph(app.graph, {n: 1 for n in app.graph.operators},
                       routes=app.routes())
    m = server_a()
    starved = dataclasses.replace(m, local_bw=m.local_bw / 5000.0)
    fast = des_simulate(g, m, [0] * g.n_units, input_rate=2e5,
                        batch=64, horizon=0.03)
    slow = des_simulate(g, starved, [0] * g.n_units, input_rate=2e5,
                        batch=64, horizon=0.03)
    assert fast.state_bytes > 0
    assert slow.R < 0.8 * fast.R


def test_operator_state_stays_dict_compatible():
    st = OperatorState()
    st["scratch"] = 1
    st.setdefault("x", []).append(2)
    assert dict(st) == {"scratch": 1, "x": [2]}
    assert st.managed is None and st.window is None


def test_keyed_store_rejects_size_mismatch():
    spec = StateSpec("keyed", key_space=8)
    with pytest.raises(ValueError, match="key_space"):
        KeyedStore(spec, table=np.zeros(9))
