"""Per-architecture smoke tests: reduced config, one train step + one decode
step on CPU, asserting output shapes and finiteness (no NaNs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_archs, get
from repro.models import frontends, model_api
from repro.models.config import ModelConfig

B, S = 2, 32


def make_batch(cfg: ModelConfig, key):
    ks = jax.random.split(key, 3)
    labels = jax.random.randint(ks[0], (B, S), 0, cfg.vocab)
    if cfg.family == "vlm":
        patches = frontends.image_patches(ks[1], cfg, B)
        text = jax.random.randint(ks[2], (B, S - cfg.img_tokens), 0,
                                  cfg.vocab)
        # fused embeds are produced inside the train step in launch/train;
        # for the smoke test we pre-fuse with a dummy embedding table
        emb = jax.random.normal(ks[2], (cfg.vocab, cfg.d_model)) * 0.02
        embeds = jnp.concatenate([patches, emb[text]], axis=1)
        return {"embeds": embeds, "labels": labels}
    if cfg.family == "audio":
        frames = frontends.audio_frames(ks[1], cfg, B)
        inputs = jax.random.randint(ks[2], (B, S), 0, cfg.vocab)
        return {"frames": frames, "inputs": inputs, "labels": labels}
    inputs = jax.random.randint(ks[1], (B, S), 0, cfg.vocab)
    return {"inputs": inputs, "labels": labels}


@pytest.mark.parametrize("arch", all_archs())
def test_smoke_train_step(arch):
    cfg = get(arch, smoke=True)
    api = model_api(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, jax.random.PRNGKey(1))

    def loss_fn(p):
        loss, metrics = api.loss(p, batch, cfg)
        return loss

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss)), arch
    # a correctly-wired model starts near ln(vocab)
    assert 0.2 * np.log(cfg.vocab) < float(loss) < 3.0 * np.log(cfg.vocab)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, arch


@pytest.mark.parametrize("arch", all_archs())
def test_smoke_decode_step(arch):
    cfg = get(arch, smoke=True)
    api = model_api(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    if cfg.family == "audio":
        from repro.models import encdec
        frames = frontends.audio_frames(jax.random.PRNGKey(1), cfg, B)
        enc = encdec.encode(params, frames, cfg)
        cache = encdec.init_cache(cfg, B, max_len=16, enc_states=enc,
                                  params=params)
    else:
        cache = api.init_cache(cfg, B, max_len=16)
    tokens = jnp.zeros((B,), jnp.int32)
    step = jax.jit(lambda p, c, t, pos: api.decode_step(p, c, t, pos, cfg))
    logits, cache = step(params, cache, tokens, jnp.int32(0))
    assert logits.shape == (B, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits))), arch
    logits2, cache = step(params, cache, jnp.argmax(logits, -1).astype(
        jnp.int32), jnp.int32(1))
    assert np.all(np.isfinite(np.asarray(logits2))), arch


def test_decode_matches_teacher_forcing_dense():
    """Greedy decode logits == teacher-forced logits (danube, window arch)."""
    cfg = get("h2o_danube_1_8b", smoke=True)
    api = model_api(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, 8), 0, cfg.vocab)
    # teacher-forced full pass
    from repro.models import transformer
    x = transformer.embed_tokens(params, toks, cfg)
    h, _ = transformer.forward(params, x, cfg, jnp.arange(8))
    tf_logits = transformer.logits_fn(params, h, cfg)       # (B, 8, V)
    # token-by-token decode
    cache = api.init_cache(cfg, B, max_len=8)
    outs = []
    for t in range(8):
        lg, cache = api.decode_step(params, cache, toks[:, t], jnp.int32(t),
                                    cfg)
        outs.append(lg)
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits), np.asarray(tf_logits),
                               atol=2e-2, rtol=2e-2)


def test_decode_matches_teacher_forcing_hybrid():
    """Same equivalence for the jamba hybrid (mamba + attn + moe).

    MoE capacity depends on batch size (T=B*S), so routing can differ
    between the full pass and step-wise decode when experts overflow; the
    smoke config uses ample capacity to keep them identical."""
    cfg = get("jamba_1_5_large_398b", smoke=True)
    import dataclasses
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    api = model_api(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, 8), 0, cfg.vocab)
    from repro.models import transformer
    x = transformer.embed_tokens(params, toks, cfg)
    h, _ = transformer.forward(params, x, cfg, jnp.arange(8))
    tf_logits = transformer.logits_fn(params, h, cfg)
    cache = api.init_cache(cfg, B, max_len=8)
    outs = []
    for t in range(8):
        lg, cache = api.decode_step(params, cache, toks[:, t], jnp.int32(t),
                                    cfg)
        outs.append(lg)
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits), np.asarray(tf_logits),
                               atol=5e-2, rtol=5e-2)


def test_mla_absorbed_matches_naive():
    cfg = get("deepseek_v3_671b", smoke=True)
    from repro.models import layers
    p = layers.mla_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, cfg.d_model)) * 0.1
    cache = layers.mla_make_cache(cfg, B, 8, jnp.float32)
    # warm the cache with a few positions
    for t in range(3):
        _, cache = layers.mla_decode(p, x, cache, t, cfg, absorbed=True)
    o1, _ = layers.mla_decode(p, x, cache, 3, cfg, absorbed=True)
    o2, _ = layers.mla_decode(p, x, cache, 3, cfg, absorbed=False)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-4,
                               rtol=1e-4)


def test_param_count_formula_tracks_actual():
    for arch in ["smollm_360m", "qwen3_moe_235b_a22b", "xlstm_125m"]:
        cfg = get(arch, smoke=True)
        api = model_api(cfg)
        params = api.init(jax.random.PRNGKey(0), cfg)
        from repro.models.module import param_count
        actual = param_count(params)
        est, _ = cfg.param_count()
        assert abs(actual - est) / actual < 0.35, (arch, actual, est)
