"""Unit tests for the §3.1 rate-based performance model."""
import math

import numpy as np
import pytest

from repro.core import (ExecutionGraph, LogicalGraph, OperatorSpec, evaluate,
                        server_a, server_b, subset)
from repro.core.perfmodel import UNPLACED


def two_op_graph(te_spout=100.0, te_sink=200.0, sel=1.0, nbytes=64.0):
    ops = {
        "spout": OperatorSpec("spout", te_spout, nbytes, nbytes, sel,
                              is_spout=True),
        "sink": OperatorSpec("sink", te_sink, nbytes, nbytes, 1.0),
    }
    return LogicalGraph(ops, [("spout", "sink")])


def test_collocated_rates_match_service_times():
    lg = two_op_graph()
    g = ExecutionGraph(lg, {"spout": 1, "sink": 1})
    ev = evaluate(g, server_a(), [0, 0], input_rate=None)
    # spout saturates at 1/100ns = 1e7 t/s; sink capacity 1/200ns = 5e6 t/s
    assert ev.processed[0] == pytest.approx(1e7)
    assert ev.processed[1] == pytest.approx(5e6)
    assert ev.R == pytest.approx(5e6)
    assert "sink" in ev.bottlenecks          # over-supplied
    assert ev.bottlenecks["sink"] == pytest.approx(2.0)


def test_under_supplied_passthrough():
    lg = two_op_graph(te_spout=1000.0, te_sink=100.0)
    g = ExecutionGraph(lg, {"spout": 1, "sink": 1})
    ev = evaluate(g, server_a(), [0, 0], input_rate=None)
    # sink can do 1e7, gets only 1e6 -> under-supplied, rate passes through
    assert ev.processed[1] == pytest.approx(1e6)
    assert "sink" not in ev.bottlenecks


def test_remote_placement_pays_formula2():
    m = server_a()
    lg = two_op_graph(te_spout=1000.0, te_sink=100.0, nbytes=128.0)
    g = ExecutionGraph(lg, {"spout": 1, "sink": 1})
    local = evaluate(g, m, [0, 0], input_rate=None)
    remote = evaluate(g, m, [0, 4], input_rate=None)   # cross-tray
    # T^f = ceil(128/64) * 548ns = 1096ns -> service 100+1096 ns
    cap = 1.0 / (1196e-9)
    assert remote.processed[1] == pytest.approx(min(1e6, cap))
    # same-tray remote is cheaper but still slower than local
    near = evaluate(g, m, [0, 1], input_rate=None)
    assert near.processed[1] <= local.processed[1] + 1e-6
    assert remote.processed[1] <= near.processed[1] + 1e-6


def test_external_rate_bounds_spout():
    lg = two_op_graph(te_spout=100.0, te_sink=100.0)
    g = ExecutionGraph(lg, {"spout": 1, "sink": 1})
    ev = evaluate(g, server_a(), [0, 0], input_rate=1e5)
    assert ev.processed[0] == pytest.approx(1e5)
    assert ev.R == pytest.approx(1e5)
    assert not ev.bottlenecks


def test_selectivity_multiplies_stream():
    ops = {
        "spout": OperatorSpec("spout", 100.0, is_spout=True),
        "split": OperatorSpec("split", 100.0, selectivity=10.0),
        "sink": OperatorSpec("sink", 10.0),
    }
    lg = LogicalGraph(ops, [("spout", "split"), ("split", "sink")])
    g = ExecutionGraph(lg, {"spout": 1, "split": 1, "sink": 1})
    ev = evaluate(g, server_a(), [0, 0, 0], input_rate=None)
    # split saturates at 1e7 processed -> emits 1e8; sink cap 1e8 exactly
    assert ev.r_in[2] == pytest.approx(1e8)
    assert ev.R == pytest.approx(1e8)


def test_replication_splits_and_scales():
    lg = two_op_graph(te_spout=100.0, te_sink=400.0)
    g = ExecutionGraph(lg, {"spout": 1, "sink": 4})
    ev = evaluate(g, server_a(), [0, 0, 0, 0, 0], input_rate=None)
    # 4 sink replicas x 2.5e6 = 1e7 -> exactly balanced with spout
    assert ev.R == pytest.approx(1e7)


def test_compression_groups_capacity():
    lg = two_op_graph(te_spout=100.0, te_sink=400.0)
    g = ExecutionGraph(lg, {"spout": 1, "sink": 4}, compress_ratio=4)
    assert g.n_units == 2
    assert g.replicas[1].group == 4
    ev = evaluate(g, server_a(), [0, 0], input_rate=None)
    assert ev.R == pytest.approx(1e7)
    assert ev.utilization[1] == pytest.approx(4.0)


def test_cpu_constraint_detected():
    m = subset(server_a(), 1)
    ops = {"spout": OperatorSpec("spout", 10.0, is_spout=True)}
    ops.update({f"op{i}": OperatorSpec(f"op{i}", 10.0) for i in range(19)})
    edges = [("spout", "op0")] + [(f"op{i}", f"op{i+1}") for i in range(18)]
    lg = LogicalGraph(ops, edges)
    g = ExecutionGraph(lg, {n: 1 for n in ops})
    ev = evaluate(g, m, [0] * 20, input_rate=None)
    assert not ev.feasible                       # 20 busy threads > 18 cores
    assert any(v.startswith("cpu@") for v in ev.violations)


def test_channel_constraint_detected():
    m = server_a()
    # huge tuples at high rate across the slowest link
    ops = {
        "spout": OperatorSpec("spout", 100.0, is_spout=True),
        "sink": OperatorSpec("sink", 10.0, tuple_bytes=1e6, mem_bytes=64.0),
    }
    lg = LogicalGraph(ops, [("spout", "sink")])
    g = ExecutionGraph(lg, {"spout": 1, "sink": 1})
    ev = evaluate(g, m, [0, 4], input_rate=None)
    # fetched bytes/s = processed * 1MB; service dominated by T^f
    assert ev.chan_usage[0, 4] > 0
    # cross-tray Q = 5.8 GB/s; processed approx 1/ (10ns + 15625*548ns) ~ 116/s
    # -> 116 MB/s < Q, so this one is feasible; now crank the rate
    ops2 = dict(ops)
    ops2["sink"] = OperatorSpec("sink", 10.0, tuple_bytes=1e6, mem_bytes=64.0)
    g2 = ExecutionGraph(lg, {"spout": 1, "sink": 64}, compress_ratio=64)
    ev2 = evaluate(g2, m, [0, 4], input_rate=None)
    assert ev2.chan_usage[0, 4] > ev.chan_usage[0, 4]


def test_unplaced_units_are_optimistic():
    m = server_a()
    lg = two_op_graph(te_spout=1000.0, te_sink=100.0, nbytes=512.0)
    g = ExecutionGraph(lg, {"spout": 1, "sink": 1})
    part = evaluate(g, m, [0, UNPLACED], input_rate=None)
    full_far = evaluate(g, m, [0, 4], input_rate=None)
    assert part.R >= full_far.R


def test_server_b_flat_remote_bandwidth():
    b = server_b()
    assert b.Q[0, 1] == pytest.approx(10.6e9)
    assert b.Q[0, 7] == pytest.approx(10.8e9)
    a = server_a()
    assert a.Q[0, 1] / a.Q[0, 7] > 2.0          # steep dropoff on Server A
