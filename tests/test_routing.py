"""The routing substrate contract: Route.split semantics, vectorized vs
per-mask parity, and the runtime / DES / rate-model agreement on per-edge
tuple conservation under key/shuffle/broadcast and selectivity — the
kernel-level contract check the ROADMAP asked for."""
import numpy as np
import pytest

from repro.core import ExecutionGraph, evaluate, server_a
from repro.streaming.api import Topology, TopologyError
from repro.streaming.apps import ALL_APPS
from repro.streaming.routing import (PARTITION_STRATEGIES, RouteSpec,
                                     compile_routes, edge_strategy,
                                     extract_keys, split_by_key,
                                     split_by_key_masks, unit_delivery)
from repro.streaming.runtime import run_app
from repro.streaming.simulator import des_simulate

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _batch(rng, rows, width):
    if width == 0:
        return rng.integers(0, 97, size=rows).astype(np.int64)
    return rng.integers(0, 97, size=(rows, width)).astype(np.float64)


# ---------------------------------------------------------------------------
# Route.split semantics
# ---------------------------------------------------------------------------

def test_shuffle_round_robins_whole_batches():
    route = RouteSpec("a", "b", 0, "shuffle").bind(3)
    targets = [route.split(np.arange(4))[0][0] for _ in range(7)]
    assert targets == [0, 1, 2, 0, 1, 2, 0]
    # the whole batch lands on one replica per emit
    assert all(len(route.split(np.arange(4))) == 1 for _ in range(3))


@pytest.mark.parametrize("rows,width,k", [(1, 0, 2), (64, 0, 3), (256, 2, 4),
                                          (1000, 3, 7), (17, 1, 5)])
def test_key_split_conserves_and_separates(rows, width, k):
    rng = np.random.default_rng(rows * 31 + k)
    arr = _batch(rng, rows, width)
    route = RouteSpec("a", "b", 0, "key").bind(k)
    parts = route.split(arr)
    # conservation: every tuple appears exactly once across replicas
    assert sum(len(p) for _, p in parts) == rows
    rebuilt = np.concatenate([p.reshape(len(p), -1) for _, p in parts])
    orig = np.sort(arr.reshape(rows, -1), axis=0)
    assert np.array_equal(np.sort(rebuilt, axis=0), orig)
    # separation: each replica sees only its own key residues
    for j, p in parts:
        assert np.all(extract_keys(p, None) % k == j)


@pytest.mark.parametrize("rows,width,k", [(64, 0, 2), (256, 2, 4),
                                          (999, 1, 6), (8, 4, 8)])
def test_key_split_vectorized_matches_masks_exactly(rows, width, k):
    """The argsort/bincount path must be row-for-row identical (same
    replicas, same within-replica order) to the seed's per-mask path."""
    rng = np.random.default_rng(rows + k)
    arr = _batch(rng, rows, width)
    keys = extract_keys(arr, None)
    vec = split_by_key(arr, keys, k)
    masks = split_by_key_masks(arr, keys, k)
    assert [j for j, _ in vec] == [j for j, _ in masks]
    for (_, a), (_, b) in zip(vec, masks):
        assert np.array_equal(a, b)


def test_broadcast_duplicates_to_every_replica():
    arr = np.arange(10)
    route = RouteSpec("a", "b", 0, "broadcast").bind(4)
    parts = route.split(arr)
    assert [j for j, _ in parts] == [0, 1, 2, 3]
    for _, p in parts:
        assert np.array_equal(p, arr)


def test_key_by_column_and_callable():
    rng = np.random.default_rng(0)
    arr = rng.integers(0, 50, size=(128, 3)).astype(np.float64)
    by_col = RouteSpec("a", "b", 0, "key", key_by=2).bind(4)
    for j, p in by_col.split(arr):
        assert np.all(p[:, 2].astype(np.int64) % 4 == j)
    by_fn = RouteSpec("a", "b", 0, "key",
                      key_by=lambda b: b[:, 0] + b[:, 1]).bind(3)
    for j, p in by_fn.split(arr):
        assert np.all((p[:, 0] + p[:, 1]).astype(np.int64) % 3 == j)


def test_key_by_validation():
    with pytest.raises(ValueError, match="1-D batch"):
        extract_keys(np.arange(5), key_by=2)
    with pytest.raises(ValueError, match="key extractor returned"):
        extract_keys(np.arange(5), key_by=lambda b: np.arange(3))


def test_fanout_one_short_circuits_every_strategy():
    arr = np.arange(6)
    for strategy in PARTITION_STRATEGIES:
        parts = RouteSpec("a", "b", 0, strategy).bind(1).split(arr)
        assert len(parts) == 1 and parts[0][0] == 0
        assert parts[0][1] is arr          # zero-copy


if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(rows=st.integers(1, 400), width=st.integers(0, 4),
           k=st.integers(1, 9),
           strategy=st.sampled_from(PARTITION_STRATEGIES),
           seed=st.integers(0, 2**16))
    def test_split_conservation_property(rows, width, k, strategy, seed):
        rng = np.random.default_rng(seed)
        arr = _batch(rng, rows, width)
        parts = RouteSpec("a", "b", 0, strategy).bind(k).split(arr)
        total = sum(len(p) for _, p in parts)
        if strategy == "broadcast" and k > 1:
            assert total == rows * k       # fan-out duplicates
        else:
            assert total == rows           # partitioning conserves
        assert len({j for j, _ in parts}) == len(parts)


# ---------------------------------------------------------------------------
# one source of truth: table vs declaration vs planner vs DES
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", list(ALL_APPS))
def test_routing_table_matches_declaration(name):
    app = ALL_APPS[name]()
    routes = compile_routes(app)
    assert len(routes) == len(app.graph.edges)
    for (u, v), spec in routes.items():
        assert spec.selectivity == pytest.approx(app.graph.sel(u, v))
        assert spec.strategy == edge_strategy(app.partition, u, v)
        if spec.strategy == "key":
            assert spec.key_by == app.key_by.get(v)
        else:
            assert spec.key_by is None
    # output-stream order == consumer declaration order (kernel contract)
    for u in app.graph.operators:
        assert [r.consumer for r in routes.out_routes(u)] == \
            app.graph.consumers(u)


@pytest.mark.parametrize("name", list(ALL_APPS))
def test_planner_weights_and_des_delivery_agree(name):
    """The ExecutionGraph edge weights (rate model) and the DES delivery
    tables must be the same numbers, both derived from the compiled routes —
    and per logical edge they must sum to the declared selectivity."""
    app = ALL_APPS[name]()
    routes = compile_routes(app)
    par = {op: 1 + (i % 3) for i, op in enumerate(app.graph.operators)}
    g = ExecutionGraph(app.graph, par, compress_ratio=2, routes=routes)
    delivery = unit_delivery(g)
    for u in range(g.n_units):
        assert sorted(delivery[u]) == sorted(g.out_edges[u])
    for (pu, cv), spec in routes.items():
        for ui in g.units_of(pu):
            out = sum(w for vi, w in g.out_edges[ui]
                      if g.replicas[vi].op == cv)
            assert out == pytest.approx(spec.selectivity), (pu, cv)


def test_broadcast_multiplies_planner_weight():
    app = (Topology("bc")
           .spout("s", lambda b, s: np.arange(b), exec_ns=100.0)
           .op("fan", lambda b, s: [b], exec_ns=100.0,
               partition="broadcast")
           .sink("sink", lambda b, s: [], exec_ns=50.0)
           .build())
    routes = compile_routes(app)
    g = ExecutionGraph(app.graph, {"s": 1, "fan": 3, "sink": 1},
                       routes=routes)
    (ui,) = g.units_of("s")
    # each fan replica receives the FULL stream: total inflow = 3x
    weights = [w for vi, w in g.out_edges[ui] if g.replicas[vi].op == "fan"]
    assert weights == pytest.approx([1.0, 1.0, 1.0])
    ev = evaluate(g, server_a(), [0] * g.n_units, input_rate=1e5)
    assert sum(ev.r_in[v] for v in g.units_of("fan")) == pytest.approx(3e5)


def test_broadcast_end_to_end_runtime():
    def k_seen(batch, state):
        state["n"] = state.get("n", 0) + len(batch)
        return []

    app = (Topology("bc")
           .spout("s", lambda b, s: np.arange(b), exec_ns=100.0)
           .op("fan", k_seen, exec_ns=100.0, partition="broadcast")
           .build())
    res = run_app(app, {"fan": 3}, batch=64, duration=0.25)
    assert res.spout_tuples > 0
    # every replica saw the whole stream (a lane can lose at most its
    # in-flight jumbos when stop interrupts the shutdown drain)
    for st_ in res.states["fan"]:
        assert res.spout_tuples - 2 * 64 <= st_.get("n", 0) \
            <= res.spout_tuples


# ---------------------------------------------------------------------------
# the three execution layers agree on per-edge tuple conservation
# ---------------------------------------------------------------------------

def _contract_app(sel=3, partition="key"):
    """spout -> expand (selectivity `sel`) -> counter (keyed) -> sink."""
    def k_expand(batch, state):
        return [np.repeat(batch, sel)]

    def k_count(batch, state):
        counts = state.setdefault("counts", np.zeros(97, np.int64))
        np.add.at(counts, batch % 97, 1)
        return [batch]

    def k_sink(batch, state):
        state["seen"] = state.get("seen", 0) + len(batch)
        return []

    return (Topology("contract")
            .spout("spout", lambda b, s: np.random.default_rng(s)
                   .integers(0, 97, size=b), exec_ns=300.0)
            .op("expand", k_expand, exec_ns=400.0, selectivity=float(sel))
            .op("counter", k_count, exec_ns=300.0, partition=partition)
            .sink("sink", k_sink, exec_ns=100.0)
            .build())


@pytest.mark.parametrize("partition", ["shuffle", "key"])
def test_runtime_des_model_tuple_conservation(partition):
    sel = 3
    app = _contract_app(sel, partition)
    routes = compile_routes(app)
    par = {"spout": 1, "expand": 1, "counter": 2, "sink": 1}

    # (1) threaded runtime: counted == sel x spout, sink == counted
    res = run_app(app, par, batch=64, duration=0.3)
    counted = sum(int(st_["counts"].sum()) for st_ in res.states["counter"])
    assert counted == sel * res.spout_tuples
    assert res.sink_tuples == sum(st_.get("seen", 0)
                                  for st_ in res.states["sink"])

    # (2) rate model: processed rates scale by the same selectivity
    g = ExecutionGraph(app.graph, par, routes=routes)
    ev = evaluate(g, server_a(), [0] * g.n_units, input_rate=1e5)
    spout_rate = sum(ev.processed[v] for v in g.units_of("spout"))
    counter_rate = sum(ev.processed[v] for v in g.units_of("counter"))
    assert counter_rate == pytest.approx(sel * spout_rate)

    # (3) DES: under-fed, the sink rate is sel x ingress
    des = des_simulate(g, server_a(), [0] * g.n_units, input_rate=1e5,
                       batch=64, horizon=0.05)
    assert des.R == pytest.approx(sel * 1e5, rel=0.2)


def test_non_first_stream_selectivity_reaches_all_layers():
    """The ROADMAP contract hole: an edge_selectivity override on a
    producer's SECOND output stream must shape planner weights and DES
    delivery exactly like the first one."""
    t = (Topology("two-streams")
         .spout("s", lambda b, sd: np.arange(b, dtype=np.int64),
                exec_ns=200.0)
         .op("split", lambda b, st_: [b, np.repeat(b, 2)], exec_ns=200.0)
         .op("a", lambda b, st_: [b], inputs={"split": 1.0}, exec_ns=200.0)
         .op("b", lambda b, st_: [b], inputs={"split": 2.0}, exec_ns=200.0))
    app = t.build()
    routes = compile_routes(app)
    assert routes.sel("split", "a") == 1.0
    assert routes.sel("split", "b") == 2.0
    g = ExecutionGraph(app.graph, {"s": 1, "split": 1, "a": 2, "b": 2},
                       routes=routes)
    delivery = unit_delivery(g)
    (ui,) = g.units_of("split")
    to_b = sum(w for vi, w in delivery[ui] if g.replicas[vi].op == "b")
    assert to_b == pytest.approx(2.0)
    ev = evaluate(g, server_a(), [0] * g.n_units, input_rate=1e4)
    assert sum(ev.r_in[v] for v in g.units_of("b")) == pytest.approx(2e4)


# ---------------------------------------------------------------------------
# runtime parity + declaration plumbing
# ---------------------------------------------------------------------------

def test_run_app_per_mask_mode_conserves_like_vectorized():
    app = _contract_app(3, "key")
    res = run_app(app, {"counter": 3}, batch=64, duration=0.25,
                  vectorized=False)
    counted = sum(int(st_["counts"].sum()) for st_ in res.states["counter"])
    assert counted == 3 * res.spout_tuples


def test_key_by_round_trips_through_runtime():
    def k_count(batch, state):
        counts = state.setdefault("counts", np.zeros(64, np.int64))
        np.add.at(counts, batch[:, 1].astype(np.int64) % 64, 1)
        return [batch]

    def src(b, sd):
        rng = np.random.default_rng(sd)
        return rng.integers(0, 64, size=(b, 2)).astype(np.float64)

    app = (Topology("kb")
           .spout("s", src, exec_ns=200.0)
           .op("count", k_count, exec_ns=200.0, partition="key", key_by=1)
           .sink("sink", lambda b, st_: [], exec_ns=100.0)
           .build())
    assert app.key_by == {"count": 1}
    res = run_app(app, {"count": 2}, batch=64, duration=0.25)
    c0 = res.states["count"][0].get("counts", np.zeros(64))
    c1 = res.states["count"][1].get("counts", np.zeros(64))
    assert int(c0.sum() + c1.sum()) == res.spout_tuples
    assert np.logical_and(c0 > 0, c1 > 0).sum() == 0   # keyed on column 1


def test_topology_rejects_key_by_without_key_partition():
    t = Topology("t").spout("s", lambda b, sd: np.arange(b), exec_ns=100.0)
    with pytest.raises(TopologyError, match="key extractors require"):
        t.op("a", lambda b, st_: [b], exec_ns=100.0, key_by=0)


def test_compile_routes_rejects_unknown_names():
    app = ALL_APPS["wc"]()
    with pytest.raises(ValueError, match="unknown operator"):
        compile_routes(app, partition={"ghost": "key"})
    with pytest.raises(ValueError, match="unknown partition strategy"):
        compile_routes(app, partition={"counter": "range"})


def test_partition_override_away_from_key_drops_declared_extractor():
    """Regression: run_app(partition=...) must be able to switch a keyed-by
    operator to shuffle — the declared extractor is disabled, not an error."""
    app = ALL_APPS["lr"]()                  # toll_history: key, key_by=0
    routes = compile_routes(app, partition={"toll_history": "shuffle"})
    spec = routes.route("hist_spout", "toll_history")
    assert spec.strategy == "shuffle" and spec.key_by is None
    res = run_app(app, {"toll_history": 2}, batch=128, duration=0.2,
                  partition={"toll_history": "shuffle"})
    assert res.sink_tuples > 0
    # an extractor passed EXPLICITLY with a non-key strategy stays an error
    with pytest.raises(ValueError, match="key extractors require"):
        compile_routes(app, partition={"toll_history": "shuffle"},
                       key_by={"toll_history": 0})


def test_planning_only_topology_keeps_routing_semantics():
    """Regression: a kernel-less Topology (planning-only Job) must still
    hand its declared partition strategies to the planner."""
    from repro.streaming.api import Job

    def topo(with_kernels):
        t = Topology("plan-only").spout(
            "s", (lambda b, sd: np.arange(b)) if with_kernels else None,
            exec_ns=500.0)
        t.op("b", (lambda b, st_: [b]) if with_kernels else None,
             exec_ns=1000.0, partition="broadcast")
        return t

    job = Job(topo(False))
    assert job.app is None
    assert job.routes.strategy("s", "b") == "broadcast"
    r_logical = job.plan(server_a(), optimizer="ff",
                         parallelism={"b": 4}).R
    r_executable = Job(topo(True)).plan(server_a(), optimizer="ff",
                                        parallelism={"b": 4}).R
    assert r_logical == pytest.approx(r_executable)


def test_measure_capacity_forwards_des_kwargs():
    from repro.streaming.api import Job
    plan = Job(ALL_APPS["wc"]()).plan(server_a(), optimizer="ff")
    m = plan.simulate(input_rate=None, horizon=0.005, queue_cap=128,
                      warmup_frac=0.2)
    assert m.throughput > 0


def test_executor_rejects_kernel_stream_count_mismatch():
    import queue as queue_mod
    from repro.streaming.runtime import Executor, _OutPort
    route = RouteSpec("u", "v", 0, "shuffle").bind(1)
    port = _OutPort(route, [queue_mod.Queue()], batch=8)
    ex = Executor("u#0", [port, ], 8, True, {},
                  kernel=lambda b, st_: [b, b], in_q=queue_mod.Queue(),
                  expected_poisons=1)
    with pytest.raises(ValueError, match="output streams"):
        ex._dispatch(ex.kernel(np.arange(4), {}), 0.0)


def test_des_rejects_rate_dict_with_unknown_spout():
    app = ALL_APPS["lr"]()
    g = ExecutionGraph(app.graph, {n: 1 for n in app.graph.operators},
                       routes=app.routes())
    with pytest.raises(ValueError, match="non-spout operators"):
        des_simulate(g, server_a(), [0] * g.n_units,
                     input_rate={"hist": 2e4}, horizon=0.01)
