"""Unified Topology/Job/Plan API: builder validation, partition round-trip,
estimate-vs-simulate agreement (Table 4 protocol), app migration parity."""
import numpy as np
import pytest

from repro.core import LogicalGraph, server_a
from repro.streaming.api import (Job, Metrics, Plan, StreamingApp, Topology,
                                 TopologyError)
from repro.streaming.apps import ALL_APPS, word_count
from repro.streaming.runtime import run_app


def _src(batch, seed):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 64, size=batch)


def _ident(batch, state):
    return [batch]


def _sink(batch, state):
    state["seen"] = state.get("seen", 0) + len(batch)
    return []


# ---------------------------------------------------------------------------
# builder validation
# ---------------------------------------------------------------------------

def test_duplicate_operator_rejected_at_declaration():
    t = Topology("t").spout("s", _src, exec_ns=100.0)
    with pytest.raises(TopologyError, match="duplicate operator 's'"):
        t.op("s", _ident, exec_ns=100.0)


def test_unknown_input_endpoint_rejected_at_build():
    t = (Topology("t").spout("s", _src, exec_ns=100.0)
         .op("a", _ident, inputs="ghost", exec_ns=100.0))
    with pytest.raises(TopologyError, match="unknown operator 'ghost'"):
        t.build()


def test_first_op_without_spout_rejected():
    with pytest.raises(TopologyError, match="no inputs and no upstream"):
        Topology("t").op("a", _ident, exec_ns=100.0)


def test_empty_topology_rejected():
    with pytest.raises(TopologyError, match="declares no operators"):
        Topology("t").build_logical()


def test_no_spout_rejected():
    t = (Topology("t").op("a", _ident, inputs="b", exec_ns=100.0)
         .op("b", _ident, inputs="a", exec_ns=100.0))
    with pytest.raises(TopologyError, match="has no spout"):
        t.build_logical()


def test_cycle_rejected():
    t = (Topology("t").spout("s", _src, exec_ns=100.0)
         .op("a", _ident, inputs=["s", "b"], exec_ns=100.0)
         .op("b", _ident, inputs="a", exec_ns=100.0))
    with pytest.raises(TopologyError, match="cycle"):
        t.build_logical()


def test_unreachable_island_rejected_as_cycle():
    t = (Topology("t").spout("s", _src, exec_ns=100.0)
         .op("a", _ident, inputs="s", exec_ns=100.0)
         .op("island", _ident, inputs="island2", exec_ns=100.0)
         .op("island2", _ident, inputs="island", exec_ns=100.0))
    with pytest.raises(TopologyError, match="cycle"):
        t.build_logical()


def test_bad_partition_strategy_rejected():
    t = Topology("t").spout("s", _src, exec_ns=100.0)
    with pytest.raises(TopologyError, match="unknown partition strategy"):
        t.op("a", _ident, exec_ns=100.0, partition="range")


def test_missing_kernel_rejected_for_build_but_ok_for_logical():
    t = (Topology("t").spout("s", _src, exec_ns=100.0)
         .op("a", exec_ns=100.0))
    graph = t.build_logical()                # planning-only is fine
    assert isinstance(graph, LogicalGraph)
    with pytest.raises(TopologyError, match="without kernels"):
        t.build()


def test_missing_source_rejected_for_build():
    t = (Topology("t").spout("s", exec_ns=100.0)
         .op("a", _ident, exec_ns=100.0))
    with pytest.raises(TopologyError, match="without source"):
        t.build()


def test_edge_selectivity_mapping_round_trips():
    t = (Topology("t").spout("s", _src, exec_ns=100.0)
         .op("a", _ident, inputs={"s": 0.25}, exec_ns=100.0))
    g = t.build_logical()
    assert g.sel("s", "a") == pytest.approx(0.25)


def test_builder_matches_hand_assembled_graph():
    """The migrated WC app must equal the seed's hand-assembled topology."""
    app = word_count()
    g = app.graph
    assert g.topo_order() == ["spout", "parser", "splitter", "counter",
                              "sink"]
    assert g.operators["splitter"].exec_ns == pytest.approx(1612.8)
    assert g.operators["splitter"].selectivity == 10.0
    assert g.operators["counter"].exec_ns == pytest.approx(612.3)
    assert app.partition == {"counter": "key"}
    assert set(g.edges) == {("spout", "parser"), ("parser", "splitter"),
                            ("splitter", "counter"), ("counter", "sink")}


# ---------------------------------------------------------------------------
# partition declarations flow into the runtime
# ---------------------------------------------------------------------------

def test_key_partition_round_trips_through_run_app():
    def k_count(batch, state):
        counts = state.setdefault("counts", np.zeros(64, np.int64))
        np.add.at(counts, batch, 1)
        return [counts[batch]]

    app = (Topology("keyed")
           .spout("s", _src, exec_ns=200.0)
           .op("count", k_count, exec_ns=200.0, partition="key")
           .sink("sink", _sink)
           .build())
    res = run_app(app, {"count": 2}, batch=64, duration=0.3)
    c0 = res.states["count"][0].get("counts", np.zeros(64))
    c1 = res.states["count"][1].get("counts", np.zeros(64))
    assert res.spout_tuples > 0
    # exact conservation: every tuple the spout delivered was counted, even
    # when stop interrupts a keyed delivery between key partitions
    assert int(c0.sum() + c1.sum()) == res.spout_tuples
    assert np.logical_and(c0 > 0, c1 > 0).sum() == 0   # disjoint key ranges
    assert c0.sum() > 0 and c1.sum() > 0


def test_spout_round_robin_independent_per_consumer():
    """Regression: the spout kept ONE rr counter advanced once per batch and
    indexed every consumer op with it; replicas must be fed independently
    per consumer op (multi-consumer fan-out, e.g. LR's dispatcher)."""
    def k_count_batches(batch, state):
        state["n"] = state.get("n", 0) + len(batch)
        return []

    app = (Topology("fanout")
           .spout("s", _src, exec_ns=100.0)
           .op("a", k_count_batches, inputs="s", exec_ns=100.0)
           .op("b", k_count_batches, inputs="s", exec_ns=100.0)
           .build())
    res = run_app(app, {"a": 2, "b": 3}, batch=64, duration=0.3)
    assert res.spout_tuples > 0
    for opname in ("a", "b"):
        counts = [st.get("n", 0) for st in res.states[opname]]
        assert all(c > 0 for c in counts), (opname, counts)
        # round-robin keeps replicas of each consumer near-evenly fed
        assert max(counts) <= 2.5 * min(counts), (opname, counts)


def test_run_app_rejects_unknown_partition_override():
    with pytest.raises(ValueError, match="unknown partition strategy"):
        run_app(word_count(), duration=0.05,
                partition={"counter": "bogus"})


def test_run_app_partition_arg_overrides_declaration():
    app = word_count()                       # declares counter: key
    res = run_app(app, {"counter": 2}, batch=64, duration=0.25,
                  partition={"counter": "shuffle"})
    c0 = res.states["counter"][0].managed.table
    c1 = res.states["counter"][1].managed.table
    # shuffle spreads every key over both replicas -> overlap appears
    assert np.logical_and(c0 > 0, c1 > 0).sum() > 0


# ---------------------------------------------------------------------------
# Job / Plan: one object through estimate -> simulate -> execute
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def wc_plan():
    return Job(word_count()).plan(server_a(), optimizer="rlas",
                                  compress_ratio=5, bestfit=True,
                                  max_nodes=5000)


def test_plan_estimate_and_simulate_agree_table4(wc_plan):
    est = wc_plan.estimate()
    des = wc_plan.simulate(backend="des", horizon=0.008)
    assert est.feasible
    assert est.throughput == pytest.approx(wc_plan.R)
    # Table 4 tolerance band (paper rel. errors 0.02-0.14; DES adds
    # batching/queueing noise)
    assert des.throughput == pytest.approx(est.throughput, rel=0.25)
    assert des.latency_p99 >= des.latency_p50 >= 0.0


def test_plan_fluid_backend(wc_plan):
    fl = wc_plan.simulate(backend="fluid")
    assert fl.source == "fluid"
    assert fl.throughput == pytest.approx(wc_plan.R, rel=0.1)


def test_plan_execute_scales_to_host(wc_plan):
    rt = wc_plan.execute(duration=0.25, batch=128, max_threads=6)
    assert rt.source == "runtime"
    assert rt.throughput > 0
    total = sum(int(st.managed.table.sum())
                for st in rt.raw.states["counter"])
    assert total == 10 * rt.raw.spout_tuples


def test_plan_optimizer_variants_produce_valid_plans():
    job = Job(word_count())
    m = server_a()
    for opt in ["ff", "rr", "bnb", "random"]:
        plan = job.plan(m, optimizer=opt, max_nodes=500) if opt == "bnb" \
            else job.plan(m, optimizer=opt)
        assert len(plan.placement) == plan.graph.n_units, opt
        assert plan.R >= 0.0, opt
        assert isinstance(plan.estimate(), Metrics), opt


def test_manual_plan_requires_full_placement():
    job = Job(word_count())
    with pytest.raises(TypeError, match="requires a placement"):
        job.plan(server_a(), optimizer="manual")
    with pytest.raises(ValueError, match="manual placement"):
        job.plan(server_a(), optimizer="manual", placement=[0, 0])
    plan = job.plan(server_a(), optimizer="manual",
                    placement=[0] * len(word_count().graph.operators))
    assert plan.optimizer == "manual"
    assert plan.feasible


def test_unknown_optimizer_rejected():
    with pytest.raises(ValueError, match="unknown optimizer"):
        Job(word_count()).plan(server_a(), optimizer="simulated-annealing")


def test_ff_rr_reject_stray_kwargs():
    """ff/rr take no search options — silently dropping them would let a
    benchmark believe e.g. tf_mode applied when it did not."""
    for opt in ("ff", "rr"):
        with pytest.raises(TypeError, match="unexpected arguments"):
            Job(word_count()).plan(server_a(), optimizer=opt,
                                   tf_mode="worst")
    # 'random' draws its own replication; a fixed-parallelism request must
    # be rejected, not silently discarded
    with pytest.raises(TypeError, match="random"):
        Job(word_count()).plan(server_a(), optimizer="random",
                               parallelism={"splitter": 4})


def test_planning_only_job_cannot_execute():
    topo = (Topology("plan-only").spout("s", exec_ns=100.0)
            .op("a", exec_ns=100.0))
    job = Job(topo)
    plan = job.plan(server_a(), optimizer="ff")
    assert plan.estimate().throughput >= 0.0
    with pytest.raises(TopologyError, match="planning-only"):
        plan.execute(duration=0.05)


# ---------------------------------------------------------------------------
# plan caching + elastic replan
# ---------------------------------------------------------------------------

def test_plan_cache_returns_same_object():
    from repro.core import subset
    job = Job(word_count())
    m = server_a()
    p1 = job.plan(m, optimizer="ff")
    p2 = job.plan(m, optimizer="ff")
    assert p1 is p2                            # cache hit
    assert job.plan(m, optimizer="ff", cache=False) is not p1
    assert job.plan(m, optimizer="rr") is not p1
    assert job.plan(subset(m, 4), optimizer="ff") is not p1


def test_plan_cache_keeps_settings_apart():
    job = Job(word_count())
    m = server_a()
    a = job.plan(m, optimizer="bnb", parallelism={"splitter": 2},
                 max_nodes=500)
    b = job.plan(m, optimizer="bnb", parallelism={"splitter": 3},
                 max_nodes=500)
    assert a is not b
    assert a is job.plan(m, optimizer="bnb", parallelism={"splitter": 2},
                         max_nodes=500)


def test_random_plans_never_cached():
    job = Job(word_count())
    m = server_a()
    assert job.plan(m, optimizer="random", seed=3) is not \
        job.plan(m, optimizer="random", seed=3)


def test_plan_replan_mirrors_elastic_path():
    """Pod-loss analogue: replan the same optimizer+settings on the
    surviving (smaller) machine; replication is re-derived, not copied."""
    from repro.core import subset
    job = Job(word_count())
    plan = job.plan(server_a(), optimizer="rlas", compress_ratio=5,
                    bestfit=True, max_nodes=5000)
    small = job.plan(subset(server_a(), 2), optimizer="rlas",
                     compress_ratio=5, bestfit=True, max_nodes=5000,
                     cache=False)
    replanned = plan.replan(subset(server_a(), 2))
    assert replanned.machine.n_sockets == 2
    assert replanned.optimizer == "rlas"
    assert replanned.R == pytest.approx(small.R)
    assert replanned.R < plan.R                 # degraded, gracefully
    # replan lands in the job's cache
    assert plan.replan(subset(server_a(), 2)) is replanned


def test_replan_manual_requires_fresh_placement():
    from repro.core import subset
    job = Job(word_count())
    n = len(word_count().graph.operators)
    plan = job.plan(server_a(), optimizer="manual", placement=[7] * n)
    with pytest.raises(ValueError, match="machine-specific placement"):
        plan.replan(subset(server_a(), 2))
    ok = plan.replan(subset(server_a(), 2), placement=[1] * n)
    assert ok.machine.n_sockets == 2


def test_manual_placement_socket_range_checked():
    job = Job(word_count())
    n = len(word_count().graph.operators)
    with pytest.raises(ValueError, match="names sockets"):
        job.plan(server_a(), optimizer="manual", placement=[11] * n)


def test_plan_rejects_unknown_parallelism_names():
    with pytest.raises(ValueError, match="unknown operators"):
        Job(word_count()).plan(server_a(), optimizer="ff",
                               parallelism={"spliter": 4})
    with pytest.raises(ValueError, match="unknown operators"):
        run_app(word_count(), {"spliter": 4}, duration=0.05)


def test_fluid_rejects_des_only_parameters(wc_plan):
    with pytest.raises(TypeError, match="DES-only"):
        wc_plan.simulate(backend="fluid", horizon=0.5)
    assert wc_plan.simulate(backend="fluid").throughput > 0


# ---------------------------------------------------------------------------
# all four migrated apps still behave exactly
# ---------------------------------------------------------------------------

def test_all_apps_build_through_topology():
    for name, make in ALL_APPS.items():
        app = make()
        assert isinstance(app, StreamingApp)
        assert app.graph.spouts() and app.graph.sinks(), name
        for op in app.graph.operators:
            if not app.graph.operators[op].is_spout:
                assert op in app.kernels, (name, op)


@pytest.mark.parametrize("name", list(ALL_APPS))
def test_migrated_apps_execute_and_conserve_counts(name):
    plan = Job(ALL_APPS[name]()).plan(server_a(), optimizer="ff")
    rt = plan.execute(duration=0.3, batch=128)
    assert rt.throughput > 0, name
    rt_res = rt.raw
    seen = sum(st.get("seen", 0) for st in rt_res.states["sink"])
    assert seen == rt_res.sink_tuples
    if name == "wc":
        counted = sum(int(st.managed.table.sum())
                      for st in rt_res.states["counter"])
        assert counted == 10 * rt_res.spout_tuples      # exact word counts
    if name == "fd":
        st = rt_res.states["sink"][0]
        assert 0 <= st.get("flagged", 0) <= st.get("seen", 1)
