"""Paper Tables 3, 4 and 7 analogues, driven by the unified Job/Plan API.

Table 3 — per-tuple processing time T under varying NUMA distance
          (measured = DES round-trip; estimated = Formula 2 model).
Table 4 — model accuracy: estimated vs measured throughput for the RLAS
          plan of each application (paper rel. errors: 0.08/0.14/0.02/0.06).
Table 7 — compression ratio r: throughput vs optimization runtime.
"""
from __future__ import annotations

import time

from repro.core import ExecutionGraph, server_a
from repro.streaming.api import Job
from repro.streaming.apps import ALL_APPS, word_count

from .common import des_measure, emit, optimized_plan


def table3_rma():
    """Measured vs estimated T for WC splitter/counter at socket distances."""
    m = server_a()
    app = word_count()
    job = Job(app)
    pairs = [("splitter", "parser"), ("counter", "splitter")]
    dists = [("S0-S0", 0, 0), ("S0-S1", 0, 1), ("S0-S3", 0, 3),
             ("S0-S4", 0, 4), ("S0-S7", 0, 7)]
    # unit index per operator: parallelism is fixed at 1, so the replica
    # ordering is invariant across all (op, distance) cells
    units = ExecutionGraph(app.graph, {n: 1 for n in app.graph.operators})
    idx = {r.op: i for i, r in enumerate(units.replicas)}
    n_ops = len(app.graph.operators)
    for op, producer in pairs:
        spec = app.graph.operators[op]
        for label, si, sj in dists:
            tf = m.fetch_time(si, sj, spec.tuple_bytes)
            est_ns = spec.exec_ns + tf * 1e9
            # measured: run the whole app on the DES with `op` placed at
            # distance (si, sj) from its producer; derive ns/tuple from the
            # unit's observed busy time
            placement = [si] * n_ops
            placement[idx[op]] = sj
            plan = job.plan(m, optimizer="manual", placement=placement)
            t0 = time.time()
            des = plan.simulate(backend="des", input_rate=3e5,
                                batch=64, horizon=0.004)
            wall = (time.time() - t0) * 1e6
            i = idx[op]
            meas_ns = (des.raw.busy_s[i] /
                       max(des.raw.unit_tuples[i], 1)) * 1e9
            rel = abs(meas_ns - est_ns) / max(meas_ns, 1e-9)
            emit(f"table3/{op}/{label}", wall,
                 f"meas_ns={meas_ns:.1f};est_ns={est_ns:.1f};"
                 f"rel={rel:.3f}")


def table4_accuracy():
    for name in ALL_APPS:
        app, machine, plan, wall = optimized_plan(name, "server_a")
        est = plan.R
        t0 = time.time()
        des = des_measure(plan)
        wall_m = (time.time() - t0) * 1e6
        rel = abs(des.throughput - est) / max(des.throughput, 1e-9)
        emit(f"table4/{name}", wall_m,
             f"meas={des.throughput:.3e};est={est:.3e};rel_err={rel:.3f}")


def table7_compress():
    for r in [1, 3, 5, 10, 15]:
        t0 = time.time()
        app, machine, plan, _ = optimized_plan("wc", "server_a", compress=r)
        wall = (time.time() - t0) * 1e6
        emit(f"table7/r={r}", wall, f"R={plan.R:.3e};opt_s={wall/1e6:.2f}")


def main():
    table3_rma()
    table4_accuracy()
    table7_compress()


if __name__ == "__main__":
    main()
