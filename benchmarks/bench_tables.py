"""Paper Tables 3, 4 and 7 analogues.

Table 3 — per-tuple processing time T under varying NUMA distance
          (measured = DES round-trip; estimated = Formula 2 model).
Table 4 — model accuracy: estimated vs measured throughput for the RLAS
          plan of each application (paper rel. errors: 0.08/0.14/0.02/0.06).
Table 7 — compression ratio r: throughput vs optimization runtime.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import ExecutionGraph, evaluate, rlas_optimize, server_a
from repro.streaming.apps import ALL_APPS, word_count
from repro.streaming.simulator import des_simulate, fluid_solve

from .common import des_measure, emit, optimized_plan


def table3_rma():
    """Measured vs estimated T for WC splitter/counter at socket distances."""
    m = server_a()
    app = word_count()
    pairs = [("splitter", "parser"), ("counter", "splitter")]
    dists = [("S0-S0", 0, 0), ("S0-S1", 0, 1), ("S0-S3", 0, 3),
             ("S0-S4", 0, 4), ("S0-S7", 0, 7)]
    for op, producer in pairs:
        spec = app.graph.operators[op]
        for label, si, sj in dists:
            tf = m.fetch_time(si, sj, spec.tuple_bytes)
            est_ns = spec.exec_ns + tf * 1e9
            # measured: run the whole app on the DES with `op` placed at
            # distance (si, sj) from its producer; derive ns/tuple from the
            # unit's observed busy time
            sub = ExecutionGraph(app.graph, {n: 1 for n in
                                             app.graph.operators})
            placement = [si] * sub.n_units
            idx = {r.op: i for i, r in enumerate(sub.replicas)}
            placement[idx[op]] = sj
            t0 = time.time()
            des = des_simulate(sub, m, placement, input_rate=3e5,
                               batch=64, horizon=0.004)
            wall = (time.time() - t0) * 1e6
            i = idx[op]
            meas_ns = (des.busy_s[i] / max(des.unit_tuples[i], 1)) * 1e9
            rel = abs(meas_ns - est_ns) / max(meas_ns, 1e-9)
            emit(f"table3/{op}/{label}", wall,
                 f"meas_ns={meas_ns:.1f};est_ns={est_ns:.1f};"
                 f"rel={rel:.3f}")


def table4_accuracy():
    for name in ALL_APPS:
        app, machine, res, wall = optimized_plan(name, "server_a")
        est = res.R
        t0 = time.time()
        des = des_measure(app, machine, res)
        wall_m = (time.time() - t0) * 1e6
        rel = abs(des.R - est) / max(des.R, 1e-9)
        emit(f"table4/{name}", wall_m,
             f"meas={des.R:.3e};est={est:.3e};rel_err={rel:.3f}")


def table7_compress():
    for r in [1, 3, 5, 10, 15]:
        t0 = time.time()
        app, machine, res, _ = optimized_plan("wc", "server_a", compress=r)
        wall = (time.time() - t0) * 1e6
        emit(f"table7/r={r}", wall, f"R={res.R:.3e};opt_s={wall/1e6:.2f}")


def main():
    table3_rma()
    table4_accuracy()
    table7_compress()


if __name__ == "__main__":
    main()
