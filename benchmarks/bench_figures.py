"""Paper Figures 7, 9, 10, 12, 13, 14, 16 analogues.

Fig 6's cross-system comparison (Storm/Flink/StreamBox) cannot run here —
those systems aren't reproducible in this container; the execution-efficiency
claims are covered by the Fig 16 factor analysis on the real runtime instead
(jumbo-tuple on/off) plus the DES comparisons.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (ExecutionGraph, evaluate, rlas_optimize, server_a,
                        server_b, subset)
from repro.core.baselines import ff_place, random_plan, rr_place
from repro.streaming.apps import ALL_APPS, word_count
from repro.streaming.simulator import des_simulate, fluid_solve

from .common import des_measure, emit, optimized_plan


def fig7_latency():
    """End-to-end latency percentiles (DES, WC optimized plan)."""
    app, machine, res, _ = optimized_plan("wc", "server_a")
    t0 = time.time()
    des = des_measure(app, machine, res)
    wall = (time.time() - t0) * 1e6
    emit("fig7/wc_latency", wall,
         f"p50_us={des.latency_p50*1e6:.1f};p99_us={des.latency_p99*1e6:.1f}")


def fig9_scalability():
    """RLAS throughput vs number of sockets, per app."""
    for name in ALL_APPS:
        base = None
        for ns in [1, 2, 4, 8]:
            t0 = time.time()
            app, machine, res, _ = optimized_plan(name, "server_a",
                                                  n_sockets=ns)
            wall = (time.time() - t0) * 1e6
            if ns == 1:
                base = max(res.R, 1e-9)
            emit(f"fig9/{name}/sockets={ns}", wall,
                 f"R={res.R:.3e};speedup={res.R/base:.2f}")


def fig10_gap_to_ideal():
    """W/o RMA bound vs ideal linear scaling (paper: 89-95%)."""
    for name in ALL_APPS:
        app, machine, res, _ = optimized_plan(name, "server_a", n_sockets=8)
        app1, m1, res1, _ = optimized_plan(name, "server_a", n_sockets=1)
        ideal = res1.R * 8
        t0 = time.time()
        no_rma = evaluate(res.graph, machine, res.placement.placement,
                          None, tf_mode="zero")
        wall = (time.time() - t0) * 1e6
        emit(f"fig10/{name}", wall,
             f"R={res.R:.3e};wo_rma={no_rma.R:.3e};ideal={ideal:.3e};"
             f"wo_rma_frac={no_rma.R/max(ideal,1e-9):.2f}")


def fig12_fixed_capability():
    """RLAS vs RLAS_fix(L)/(U) (paper: 19-39% / 119-455% improvements).

    Fixed-capability plans are *optimized* under the wrong model, then
    *measured* under the true relative-location DES."""
    for name in ALL_APPS:
        rows = {}
        for mode, label in [("relative", "rlas"), ("worst", "fixL"),
                            ("zero", "fixU")]:
            t0 = time.time()
            app, machine, res, _ = optimized_plan(name, "server_a",
                                                  tf_mode=mode)
            des = des_measure(app, machine, res)
            wall = (time.time() - t0) * 1e6
            rows[label] = des.R
            emit(f"fig12/{name}/{label}", wall, f"R_meas={des.R:.3e}")
        emit(f"fig12/{name}/improvement", 0.0,
             f"vs_fixL={rows['rlas']/max(rows['fixL'],1e-9):.2f}x;"
             f"vs_fixU={rows['rlas']/max(rows['fixU'],1e-9):.2f}x")


def fig13_placement_strategies():
    """Same replication, placement by RLAS/FF/RR on both servers."""
    for server in ["server_a", "server_b"]:
        for name in ALL_APPS:
            app, machine, res, _ = optimized_plan(name, server)
            graph = res.graph
            for strat, place_fn in [
                    ("rlas", None), ("ff", ff_place), ("rr", rr_place)]:
                t0 = time.time()
                if place_fn is None:
                    placement = res.placement.placement
                else:
                    placement = place_fn(graph, machine, None).placement
                des = des_simulate(graph, machine, placement,
                                   input_rate=_sat_rate(graph, machine,
                                                        placement),
                                   horizon=0.006)
                wall = (time.time() - t0) * 1e6
                emit(f"fig13/{server}/{name}/{strat}", wall,
                     f"R_meas={des.R:.3e}")


def _sat_rate(graph, machine, placement):
    sat = fluid_solve(graph, machine, placement, input_rate=None)
    spout = sum(sat.processed[v] for v in graph.spout_units())
    return max(spout, 1.0) * 1.05


def fig14_monte_carlo(n_samples: int = 1000):
    """Random replication+placement plans vs RLAS (paper: none beat RLAS)."""
    rng = np.random.default_rng(0)
    for name in ["wc", "lr"]:
        app, machine, res, _ = optimized_plan(name, "server_a")
        t0 = time.time()
        better = 0
        rs = []
        for _ in range(n_samples):
            _, _, r = random_plan(app.graph, machine, rng)
            rs.append(r)
            if r > res.R:
                better += 1
        wall = (time.time() - t0) * 1e6 / n_samples
        rs = np.array(rs)
        emit(f"fig14/{name}", wall,
             f"rlas={res.R:.3e};best_random={rs.max():.3e};"
             f"median_random={np.median(rs):.3e};frac_better={better/n_samples:.4f}")


def fig16_factor_analysis():
    """Cumulative factors, measured on the DES + the real threaded runtime.

    simple       = fix(L)-optimized plan, per-tuple queues (batch=1)
    +jumbo       = same plan, jumbo tuples (batch=64)
    +RLAS        = relative-location-aware plan, jumbo tuples
    runtime rows = real thread runtime, jumbo off/on (Fig 16's execution-
                   efficiency factor on actual hardware).
    """
    for name in ALL_APPS:
        app, machine, res_fix, _ = optimized_plan(name, "server_a",
                                                  tf_mode="worst")
        app, machine, res_rlas, _ = optimized_plan(name, "server_a")
        t0 = time.time()
        simple = des_simulate(
            res_fix.graph, machine, res_fix.placement.placement,
            input_rate=_sat_rate(res_fix.graph, machine,
                                 res_fix.placement.placement),
            batch=1, horizon=0.002)
        jumbo = des_simulate(
            res_fix.graph, machine, res_fix.placement.placement,
            input_rate=_sat_rate(res_fix.graph, machine,
                                 res_fix.placement.placement),
            batch=64, horizon=0.006)
        rlas = des_measure(app, machine, res_rlas)
        wall = (time.time() - t0) * 1e6
        emit(f"fig16/{name}", wall,
             f"simple={simple.R:.3e};jumbo={jumbo.R:.3e};"
             f"rlas={rlas.R:.3e}")
    # real-runtime factor (WC): jumbo tuples on/off
    from repro.streaming.runtime import run_app
    t0 = time.time()
    off = run_app(word_count(), batch=256, duration=0.4, jumbo=False)
    on = run_app(word_count(), batch=256, duration=0.4, jumbo=True)
    wall = (time.time() - t0) * 1e6
    emit("fig16/runtime_wc_jumbo", wall,
         f"off={off.throughput:.3e};on={on.throughput:.3e};"
         f"speedup={on.throughput/max(off.throughput,1e-9):.2f}x")


def main():
    fig7_latency()
    fig9_scalability()
    fig10_gap_to_ideal()
    fig12_fixed_capability()
    fig13_placement_strategies()
    fig14_monte_carlo()
    fig16_factor_analysis()


if __name__ == "__main__":
    main()
