"""Paper Figures 7, 9, 10, 12, 13, 14, 16 analogues, driven by the unified
Job/Plan API.

Fig 6's cross-system comparison (Storm/Flink/StreamBox) cannot run here —
those systems aren't reproducible in this container; the execution-efficiency
claims are covered by the Fig 16 factor analysis on the real runtime instead
(jumbo-tuple on/off) plus the DES comparisons.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import server_a
from repro.streaming.api import Job
from repro.streaming.apps import ALL_APPS, word_count

from .common import des_measure, emit, optimized_plan


def fig7_latency():
    """End-to-end latency percentiles (DES, WC optimized plan)."""
    app, machine, plan, _ = optimized_plan("wc", "server_a")
    t0 = time.time()
    des = des_measure(plan)
    wall = (time.time() - t0) * 1e6
    emit("fig7/wc_latency", wall,
         f"p50_us={des.latency_p50*1e6:.1f};p99_us={des.latency_p99*1e6:.1f}")


def fig9_scalability():
    """RLAS throughput vs number of sockets, per app."""
    for name in ALL_APPS:
        base = None
        for ns in [1, 2, 4, 8]:
            t0 = time.time()
            app, machine, plan, _ = optimized_plan(name, "server_a",
                                                   n_sockets=ns)
            wall = (time.time() - t0) * 1e6
            if ns == 1:
                base = max(plan.R, 1e-9)
            emit(f"fig9/{name}/sockets={ns}", wall,
                 f"R={plan.R:.3e};speedup={plan.R/base:.2f}")


def fig10_gap_to_ideal():
    """W/o RMA bound vs ideal linear scaling (paper: 89-95%)."""
    for name in ALL_APPS:
        app, machine, plan, _ = optimized_plan(name, "server_a", n_sockets=8)
        _, _, plan1, _ = optimized_plan(name, "server_a", n_sockets=1)
        ideal = plan1.R * 8
        t0 = time.time()
        no_rma = plan.estimate(tf_mode="zero")
        wall = (time.time() - t0) * 1e6
        emit(f"fig10/{name}", wall,
             f"R={plan.R:.3e};wo_rma={no_rma.throughput:.3e};"
             f"ideal={ideal:.3e};"
             f"wo_rma_frac={no_rma.throughput/max(ideal,1e-9):.2f}")


def fig12_fixed_capability():
    """RLAS vs RLAS_fix(L)/(U) (paper: 19-39% / 119-455% improvements).

    Fixed-capability plans are *optimized* under the wrong model, then
    *measured* under the true relative-location DES."""
    for name in ALL_APPS:
        rows = {}
        for mode, label in [("relative", "rlas"), ("worst", "fixL"),
                            ("zero", "fixU")]:
            t0 = time.time()
            app, machine, plan, _ = optimized_plan(name, "server_a",
                                                   tf_mode=mode)
            des = des_measure(plan)
            wall = (time.time() - t0) * 1e6
            rows[label] = des.throughput
            emit(f"fig12/{name}/{label}", wall,
                 f"R_meas={des.throughput:.3e}")
        emit(f"fig12/{name}/improvement", 0.0,
             f"vs_fixL={rows['rlas']/max(rows['fixL'],1e-9):.2f}x;"
             f"vs_fixU={rows['rlas']/max(rows['fixU'],1e-9):.2f}x")


def fig13_placement_strategies():
    """Same replication, placement by RLAS/FF/RR on both servers."""
    for server in ["server_a", "server_b"]:
        for name in ALL_APPS:
            app, machine, rlas_plan, _ = optimized_plan(name, server)
            job = Job(app)
            for strat in ["rlas", "ff", "rr"]:
                t0 = time.time()
                if strat == "rlas":
                    plan = rlas_plan
                else:
                    plan = job.plan(
                        machine, optimizer=strat,
                        parallelism=rlas_plan.parallelism,
                        compress_ratio=rlas_plan.graph.compress_ratio)
                des = plan.simulate(backend="des", input_rate=None,
                                    horizon=0.006)
                wall = (time.time() - t0) * 1e6
                emit(f"fig13/{server}/{name}/{strat}", wall,
                     f"R_meas={des.throughput:.3e}")


def fig14_monte_carlo(n_samples: int = 1000):
    """Random replication+placement plans vs RLAS (paper: none beat RLAS)."""
    rng = np.random.default_rng(0)
    for name in ["wc", "lr"]:
        app, machine, plan, _ = optimized_plan(name, "server_a")
        job = Job(app)
        t0 = time.time()
        better = 0
        rs = []
        for _ in range(n_samples):
            sample = job.plan(machine, optimizer="random", rng=rng)
            rs.append(sample.R)
            if sample.R > plan.R:
                better += 1
        wall = (time.time() - t0) * 1e6 / n_samples
        rs = np.array(rs)
        emit(f"fig14/{name}", wall,
             f"rlas={plan.R:.3e};best_random={rs.max():.3e};"
             f"median_random={np.median(rs):.3e};frac_better={better/n_samples:.4f}")


def fig16_factor_analysis():
    """Cumulative factors, measured on the DES + the real threaded runtime.

    simple       = fix(L)-optimized plan, per-tuple queues (batch=1)
    +jumbo       = same plan, jumbo tuples (batch=64)
    +RLAS        = relative-location-aware plan, jumbo tuples
    runtime rows = real thread runtime, jumbo off/on (Fig 16's execution-
                   efficiency factor on actual hardware).
    """
    for name in ALL_APPS:
        _, machine, plan_fix, _ = optimized_plan(name, "server_a",
                                                 tf_mode="worst")
        _, _, plan_rlas, _ = optimized_plan(name, "server_a")
        t0 = time.time()
        simple = plan_fix.simulate(backend="des", input_rate=None,
                                   batch=1, horizon=0.002)
        jumbo = plan_fix.simulate(backend="des", input_rate=None,
                                  batch=64, horizon=0.006)
        rlas = des_measure(plan_rlas)
        wall = (time.time() - t0) * 1e6
        emit(f"fig16/{name}", wall,
             f"simple={simple.throughput:.3e};jumbo={jumbo.throughput:.3e};"
             f"rlas={rlas.throughput:.3e}")
    # real-runtime factor (WC): jumbo tuples on/off
    t0 = time.time()
    base = Job(word_count()).plan(server_a(), optimizer="ff")
    off = base.execute(batch=256, duration=0.4, jumbo=False)
    on = base.execute(batch=256, duration=0.4, jumbo=True)
    wall = (time.time() - t0) * 1e6
    emit("fig16/runtime_wc_jumbo", wall,
         f"off={off.throughput:.3e};on={on.throughput:.3e};"
         f"speedup={on.throughput/max(off.throughput,1e-9):.2f}x")


def main():
    fig7_latency()
    fig9_scalability()
    fig10_gap_to_ideal()
    fig12_fixed_capability()
    fig13_placement_strategies()
    fig14_monte_carlo()
    fig16_factor_analysis()


if __name__ == "__main__":
    main()
