"""Roofline derivation from the dry-run sweep (deliverable g).

Three terms per (arch x shape x mesh) cell, all in seconds per step:

  compute    = per-device HLO FLOPs / (197 TFLOP/s bf16)
  memory     = per-device HLO bytes accessed / (819 GB/s HBM)
  collective = per-device collective payload bytes / (50 GB/s ICI link)

FLOPs/bytes are scan-corrected (launch/dryrun.py docstring); sLSTM's analytic
extra is global, so it is divided by the device count here.  MODEL_FLOPS is
6·N_active·tokens (train), 2·N_active·tokens (prefill) or 2·N_active·batch
(decode); the ratio MODEL_FLOPS / (HLO FLOPs x devices) exposes
remat/dispatch/replication waste.  The "roofline fraction" score is
T_ideal / max(term): the fraction of the compute roofline this lowering
would attain if the dominant term were perfectly overlapped with nothing.
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, List, Optional

PEAK = 197e12
HBM = 819e9
LINK = 50e9

SHAPE_TOKENS = {
    "train_4k": (4096 * 256, "train"),
    "prefill_32k": (32768 * 32, "prefill"),
    "decode_32k": (128, "decode"),
    "long_500k": (1, "decode"),
}


def _analytic_hbm_bytes(rec: Dict, n_dev: int) -> float:
    """Per-device HBM traffic model (the CPU backend's "bytes accessed"
    counts every unfused intermediate — useless as a TPU memory term).

    train:   params read fwd+bwd + grad write + opt state r/w
             + activation traffic (read+write per layer, x2 for remat)
    prefill: params read + activation traffic
    decode:  params read + full KV-cache/state read + cache write
    """
    from repro.configs import get
    cfg = get(rec["arch"])
    tokens, kind = SHAPE_TOKENS[rec["shape"]]
    p = rec["param_bytes_per_device"]
    o = rec.get("opt_bytes_per_device", 0.0)
    act_rw = 4                                # read+write, fwd + remat-bwd
    acts = tokens / n_dev * cfg.d_model * 2 * cfg.n_layers * act_rw
    if kind == "train":
        return 3 * p + 2 * o + acts
    if kind == "prefill":
        return p + acts / 2
    cache = rec.get("cache_bytes_per_device", 0.0)
    return p + cache * 1.05


def terms(rec: Dict) -> Optional[Dict]:
    if rec["status"] != "ok":
        return None
    n_dev = 512 if rec["mesh"] == "2x16x16" else 256
    flops_dev = rec["flops"] + rec.get("extra_flops", 0.0) / n_dev
    t_compute = flops_dev / PEAK
    t_memory = _analytic_hbm_bytes(rec, n_dev) / HBM
    t_memory_hlo = rec["bytes_accessed"] / HBM      # unfused upper bound
    coll = rec.get("coll") or {}
    coll_bytes = sum(v for k, v in coll.items() if k != "count")
    t_coll = coll_bytes / LINK
    tokens, kind = SHAPE_TOKENS[rec["shape"]]
    mult = {"train": 6, "prefill": 2, "decode": 2}[kind]
    model_flops = mult * rec["n_active"] * tokens
    t_ideal = model_flops / (n_dev * PEAK)
    tmax = max(t_compute, t_memory, t_coll, 1e-30)
    dom = {t_compute: "compute", t_memory: "memory",
           t_coll: "collective"}[tmax]
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute": t_compute, "t_memory": t_memory, "t_collective": t_coll,
        "t_memory_hlo": t_memory_hlo,
        "dominant": dom, "model_flops": model_flops,
        "useful_ratio": model_flops / max(flops_dev * n_dev, 1e-30),
        "roofline_frac": t_ideal / tmax,
        "peak_gib": rec["peak_bytes_per_device"] / 2**30,
        "param_gib": rec["param_bytes_per_device"] / 2**30,
        "opt_gib": rec.get("opt_bytes_per_device", 0.0) / 2**30,
        "cache_gib": rec.get("cache_bytes_per_device", 0.0) / 2**30,
        "coll_count": coll.get("count", 0),
    }


def load(path: str) -> List[Dict]:
    recs = {}
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            recs[(r["arch"], r["shape"], r["mesh"])] = r
    return list(recs.values())


def main(path: str = "results/dryrun_baseline.jsonl",
         out_csv: str = "results/roofline.csv"):
    if not os.path.exists(path):
        print(f"roofline,0.0,skipped_no_dryrun_results({path})")
        return
    rows = []
    skips = []
    for rec in sorted(load(path), key=lambda r: (r["arch"], r["shape"],
                                                 r["mesh"])):
        if rec["status"] == "skipped":
            skips.append(rec)
            continue
        t = terms(rec)
        if t is None:
            continue
        rows.append(t)
        frac = t["roofline_frac"]
        print(f"roofline/{t['arch']}/{t['shape']}/{t['mesh']},0.0,"
              f"dom={t['dominant']};frac={frac:.3f};"
              f"useful={t['useful_ratio']:.3f};"
              f"tc={t['t_compute']:.3e};tm={t['t_memory']:.3e};"
              f"tx={t['t_collective']:.3e}")
    for rec in skips:
        print(f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']},0.0,"
              f"skipped:{rec['reason'][:60]}")
    if rows and out_csv:
        os.makedirs(os.path.dirname(out_csv), exist_ok=True)
        keys = list(rows[0].keys())
        with open(out_csv, "w") as f:
            f.write(",".join(keys) + "\n")
            for t in rows:
                f.write(",".join(str(t[k]) for k in keys) + "\n")


if __name__ == "__main__":
    main(*sys.argv[1:])
