"""Runtime routing benchmark: seed per-mask keyed split vs the vectorized
argsort/bincount path (ISSUE 2 tentpole), micro and end-to-end.

Micro rows time ``Route.split`` alone (us/call) over batch-size x fan-out
grids; end-to-end rows run WC and LR on the real threaded runtime in both
modes and report sink throughput and p99 latency.  Results append to the
CSV row protocol (``name,us_per_call,derived``) and are recorded in
``BENCH_streaming.json`` for the perf trajectory.

Usage::

    PYTHONPATH=src python benchmarks/bench_runtime.py [--smoke] [--out F]
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import sys
import time

import numpy as np

try:                                       # python -m benchmarks.bench_runtime
    from .common import emit
except ImportError:                        # python benchmarks/bench_runtime.py
    sys.path.insert(0, os.path.dirname(__file__))
    from common import emit

from repro.streaming.apps import linear_road, word_count  # noqa: E402
from repro.streaming.routing import (RouteSpec, split_by_key,  # noqa: E402
                                     split_by_key_masks)
from repro.streaming.runtime import run_app  # noqa: E402


def bench_split(rows: int, k: int, iters: int) -> dict:
    """us/call for one keyed split of ``rows`` tuples over ``k`` replicas."""
    rng = np.random.default_rng(rows + k)
    arr = rng.integers(0, 4096, size=rows).astype(np.int64)
    spec = RouteSpec("u", "v", 0, "key")
    out = {}
    for label, fn in [("masks", split_by_key_masks),
                      ("vectorized", split_by_key)]:
        keys = spec.keys(arr)
        fn(arr, keys, k)                       # warm
        t0 = time.perf_counter()
        for _ in range(iters):
            fn(arr, spec.keys(arr), k)
        out[label] = (time.perf_counter() - t0) / iters * 1e6
    out["speedup"] = out["masks"] / out["vectorized"]
    emit(f"split_rows{rows}_k{k}", out["vectorized"],
         f"{out['speedup']:.2f}x_vs_masks")
    return {"rows": rows, "k": k, **{m: round(v, 3)
                                     for m, v in out.items()}}


def bench_app(name: str, make, parallelism: dict, batch: int,
              duration: float, repeat: int) -> dict:
    """Median end-to-end throughput/p99 in both routing modes."""
    out = {"batch": batch, "parallelism": parallelism}
    run_app(make(), parallelism, batch=batch, duration=min(duration, 0.2))
    for mode, vectorized in [("masks", False), ("vectorized", True)]:
        # a throwaway warm run above stabilises thread startup; repeat
        # medians absorb scheduler noise
        thr, p99 = [], []
        for r in range(repeat):
            res = run_app(make(), parallelism, batch=batch,
                          duration=duration, seed=100 + r,
                          vectorized=vectorized)
            thr.append(res.throughput)
            p99.append(res.latency_p99)
        out[mode] = {"throughput": round(statistics.median(thr), 1),
                     "latency_p99": round(statistics.median(p99), 6)}
        emit(f"runtime_{name}_{mode}_b{batch}",
             duration * 1e6, f"{out[mode]['throughput']:.0f}tps")
    out["speedup"] = round(out["vectorized"]["throughput"] /
                           max(out["masks"]["throughput"], 1e-9), 3)
    emit(f"runtime_{name}_speedup_b{batch}", 0.0, f"{out['speedup']:.3f}x")
    return out


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny durations for CI")
    ap.add_argument("--duration", type=float, default=None)
    ap.add_argument("--repeat", type=int, default=None)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_streaming.json"))
    args = ap.parse_args(argv)
    duration = args.duration or (0.1 if args.smoke else 0.8)
    repeat = args.repeat or (1 if args.smoke else 7)
    iters = 50 if args.smoke else 400

    micro = [bench_split(rows, k, iters)
             for rows in (256, 2560, 10240) for k in (2, 4, 8)]
    apps = {
        # WC's keyed edge carries batch x selectivity-10 words; batch 256
        # is the acceptance configuration (jumbo batches of 2560 words)
        "wc": bench_app("wc", word_count,
                        {"splitter": 2, "counter": 4}, 256,
                        duration, repeat),
        "lr": bench_app("lr", linear_road,
                        {"dispatcher": 2, "toll_history": 4}, 1024,
                        duration, repeat),
    }
    report = {
        "meta": {"cpus": os.cpu_count(), "duration_s": duration,
                 "repeat": repeat, "smoke": bool(args.smoke)},
        "micro": micro,
        "apps": apps,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {os.path.abspath(args.out)}")
    return report


if __name__ == "__main__":
    main()
