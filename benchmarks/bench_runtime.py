"""Runtime routing + state benchmark: seed per-mask keyed split vs the
vectorized argsort/bincount path (ISSUE 2 tentpole), and the managed
keyed-state path vs seed dict-kernel state (ISSUE 3), micro and end-to-end.

Micro rows time ``Route.split`` alone (us/call) over batch-size x fan-out
grids; end-to-end rows run WC and LR on the real threaded runtime in both
modes and report sink throughput and p99 latency.  The state A/B runs WC
with its declared ``StateSpec`` KeyedStore against a seed-style variant
whose counter mutates a bare dict-held array, at identical profile.
Results append to the CSV row protocol (``name,us_per_call,derived``) and
are recorded in ``BENCH_streaming.json`` for the perf trajectory.

The ``fusion`` section (ISSUE 10) A/Bs operator fusion on the chain-heavy
1:1 pipeline (``chain_pipeline``): every-hop-a-queue vs ``fuse="auto"``
compiling the whole segment into one executor per replica, on the threaded
backend (plus the process backend with ``--backend processes``), with a
byte-parity replay asserted and the ``fused_vs_unfused >= 1.0`` floor
gated on exit.

The ``inference`` section (ISSUE 8) A/Bs the async device-dispatch
pipeline: ``streaming_inference`` ingest at ``dispatch_depth`` 1 vs 2 vs 4,
every data point in a fresh interpreter (jax-clean parents for the process
backend; cold JIT caches for fair rows), with a depth-1-vs-2 replay-parity
gate asserted on exit.  ``--backend processes`` adds the same A/B through
the process backend as ``inference_processes``.

``--backend processes`` adds the process-parallel sections (ISSUE 6): a
threads-vs-processes A/B on WC, the serialization A/B (ISSUE 7 — raw
zero-copy ring slots vs the pickled baseline, micro us/slot +
bytes-copied-per-tuple and cross-group WC throughput, replay parity
asserted across formats) plus the placement-sensitivity sweep — the
same WC replay executed under the RLAS plan's worker grouping, a seeded
random grouping, and a worst-case grouping that alternates sockets along
the chain so every edge pays a shared-memory ring copy.  The spread
(worst wall / RLAS wall) is the measurable cost of bad placement the
threaded runtime could never show.  Under ``--smoke --backend processes``
only these sections run (the CI procexec smoke row); a cadence A/B on
sd_et (auto-derived vs pinned watermark cadence) rides along in every
full run.

Usage::

    PYTHONPATH=src python benchmarks/bench_runtime.py [--smoke] [--out F]
        [--backend threads|processes]
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import subprocess
import sys
import time

import numpy as np

try:                                       # python -m benchmarks.bench_runtime
    from .common import emit
except ImportError:                        # python benchmarks/bench_runtime.py
    sys.path.insert(0, os.path.dirname(__file__))
    from common import emit

from repro.streaming.api import Topology  # noqa: E402
from repro.streaming.apps import (WC_VOCAB,  # noqa: E402
                                  WC_WORDS_PER_SENTENCE, linear_road,
                                  spike_detection, spike_detection_eventtime,
                                  spike_detection_keyed, word_count)
from repro.streaming.routing import (RouteSpec, split_by_key,  # noqa: E402
                                     split_by_key_masks)
from repro.streaming.runtime import run_app  # noqa: E402


def bench_split(rows: int, k: int, iters: int) -> dict:
    """us/call for one keyed split of ``rows`` tuples over ``k`` replicas."""
    rng = np.random.default_rng(rows + k)
    arr = rng.integers(0, 4096, size=rows).astype(np.int64)
    spec = RouteSpec("u", "v", 0, "key")
    out = {}
    for label, fn in [("masks", split_by_key_masks),
                      ("vectorized", split_by_key)]:
        keys = spec.keys(arr)
        fn(arr, keys, k)                       # warm
        t0 = time.perf_counter()
        for _ in range(iters):
            fn(arr, spec.keys(arr), k)
        out[label] = (time.perf_counter() - t0) / iters * 1e6
    out["speedup"] = out["masks"] / out["vectorized"]
    emit(f"split_rows{rows}_k{k}", out["vectorized"],
         f"{out['speedup']:.2f}x_vs_masks")
    return {"rows": rows, "k": k, **{m: round(v, 3)
                                     for m, v in out.items()}}


def bench_app(name: str, make, parallelism: dict, batch: int,
              duration: float, repeat: int) -> dict:
    """Median end-to-end throughput/p99 in both forced routing modes plus
    the per-edge auto selection (``vectorized=None``, the default)."""
    out = {"batch": batch, "parallelism": parallelism}
    run_app(make(), parallelism, batch=batch, duration=min(duration, 0.2))
    modes = [("masks", False), ("vectorized", True), ("auto", None)]
    # a throwaway warm run above stabilises thread startup; repeats are
    # interleaved round-robin across modes (not sequential per-mode
    # blocks) so slow host drift lands on every mode equally — sequential
    # blocks once mis-read a healthy auto selection as 0.836x of best
    thr = {m: [] for m, _ in modes}
    p99 = {m: [] for m, _ in modes}
    for r in range(repeat):
        for mode, vectorized in modes:
            res = run_app(make(), parallelism, batch=batch,
                          duration=duration, seed=100 + r,
                          vectorized=vectorized)
            thr[mode].append(res.throughput)
            p99[mode].append(res.latency_p99)
    for mode, _ in modes:
        out[mode] = {"throughput": round(statistics.median(thr[mode]), 1),
                     "latency_p99": round(statistics.median(p99[mode]), 6)}
        emit(f"runtime_{name}_{mode}_b{batch}",
             duration * 1e6, f"{out[mode]['throughput']:.0f}tps")
    out["speedup"] = round(out["vectorized"]["throughput"] /
                           max(out["masks"]["throughput"], 1e-9), 3)
    out["auto_vs_best"] = round(
        out["auto"]["throughput"] /
        max(out["masks"]["throughput"],
            out["vectorized"]["throughput"], 1e-9), 3)
    emit(f"runtime_{name}_speedup_b{batch}", 0.0, f"{out['speedup']:.3f}x")
    return out


def _dict_word_count():
    """The seed's WC: counter state is a bare dict-held array, mem_bytes a
    hand-tuned constant — the baseline for the managed-state A/B."""
    def source(batch, seed):
        rng = np.random.default_rng(seed)
        return rng.integers(0, WC_VOCAB,
                            size=(batch, WC_WORDS_PER_SENTENCE))

    def k_counter(batch, state):
        counts = state.setdefault("counts", np.zeros(WC_VOCAB, np.int64))
        np.add.at(counts, batch, 1)
        return [counts[batch].astype(np.int64)]

    def k_sink(batch, state):
        state["seen"] = state.get("seen", 0) + len(batch)
        return []

    return (
        Topology("wc-dict")
        .spout("spout", source, exec_ns=500.0, tuple_bytes=120.0)
        .op("parser", lambda b, st: [b], exec_ns=350.0, tuple_bytes=120.0)
        .op("splitter", lambda b, st: [b.reshape(-1)], exec_ns=1612.8,
            tuple_bytes=120.0, mem_bytes=240.0, selectivity=10.0)
        .op("counter", k_counter, exec_ns=612.3, tuple_bytes=32.0,
            mem_bytes=96.0, partition="key")
        .sink("sink", k_sink, exec_ns=100.0, tuple_bytes=32.0)
        .build())


def bench_state(batch: int, duration: float, repeat: int) -> dict:
    """End-to-end WC throughput: declared KeyedStore vs seed dict state."""
    out = {"batch": batch, "parallelism": {"splitter": 2, "counter": 4}}
    run_app(word_count(), out["parallelism"], batch=batch,
            duration=min(duration, 0.2))              # warm threads
    for label, make in [("dict", _dict_word_count), ("managed", word_count)]:
        thr = []
        for r in range(repeat):
            res = run_app(make(), out["parallelism"], batch=batch,
                          duration=duration, seed=300 + r)
            thr.append(res.throughput)
        out[label] = {"throughput": round(statistics.median(thr), 1)}
        emit(f"state_wc_{label}_b{batch}", duration * 1e6,
             f"{out[label]['throughput']:.0f}tps")
    out["speedup"] = round(out["managed"]["throughput"] /
                           max(out["dict"]["throughput"], 1e-9), 3)
    emit(f"state_wc_speedup_b{batch}", 0.0, f"{out['speedup']:.3f}x")
    return out


def bench_eventtime(batch: int, duration: float, repeat: int) -> dict:
    """SD A/B: event-time sliding panes (watermark-fired, out-of-order
    input, segmented kernel — one stacked call per watermark) vs the
    seed's count-based sliding window, end to end on the threaded
    runtime, plus the keyed-pane variant (sd_key, per-device sessions).
    The ratio prices what watermarking costs (per-batch jumbo flushes +
    pane buffering) against the count path that cannot tolerate disorder
    at all; late/pane tallies confirm the event-time run actually
    exercised the substrate."""
    out = {"batch": batch, "parallelism": {"parser": 2}}
    run_app(spike_detection_eventtime(), out["parallelism"], batch=batch,
            duration=min(duration, 0.2))               # warm threads
    for label, make in [("count", spike_detection),
                        ("eventtime", spike_detection_eventtime),
                        ("keyed", spike_detection_keyed)]:
        ingest, thr, panes, late = [], [], 0, 0
        for r in range(repeat):
            res = run_app(make(), out["parallelism"], batch=batch,
                          duration=duration, seed=500 + r)
            ingest.append(res.spout_tuples / res.duration)
            thr.append(res.throughput)
            panes += res.panes_fired
            late += res.late_drops
        out[label] = {"ingest": round(statistics.median(ingest), 1),
                      "throughput": round(statistics.median(thr), 1)}
        if label != "count":
            out[label]["panes_fired"] = panes
            out[label]["late_drops"] = late
        emit(f"eventtime_sd_{label}_b{batch}", duration * 1e6,
             f"{out[label]['ingest']:.0f}tps_in")
    # capacity ratio on the spout side: the count window emits one running
    # aggregate per reading while panes fire once per slide, so sink rates
    # differ by selectivity even at equal cost
    out["ingest_ratio"] = round(out["eventtime"]["ingest"] /
                                max(out["count"]["ingest"], 1e-9), 3)
    emit(f"eventtime_sd_ingest_ratio_b{batch}", 0.0,
         f"{out['ingest_ratio']:.3f}x")
    return out


def bench_backends(batch: int, duration: float, repeat: int,
                   batches: int) -> dict:
    """Threads vs processes on WC: duration-mode throughput for the solo
    grouping (every edge a shared-memory ring) and the colocated grouping
    (one worker, every edge in-process), plus the replay parity check the
    backend contract demands (identical counters and keyed state)."""
    from repro.streaming.procexec import run_app_processes
    from repro.streaming.state import KeyedStore, merge_keyed

    par = {"splitter": 2, "counter": 4}
    out = {"batch": batch, "parallelism": par}
    colocated = {op: 0 for op in word_count().graph.operators}
    modes = [("threads", run_app, {}),
             ("processes_solo", run_app_processes, {}),
             ("processes_grouped", run_app_processes,
              {"groups": colocated})]
    for label, runner, extra in modes:
        thr = []
        for r in range(repeat):
            res = runner(word_count(), par, batch=batch, duration=duration,
                         seed=700 + r, **extra)
            thr.append(res.throughput)
        out[label] = {"throughput": round(statistics.median(thr), 1)}
        emit(f"backend_wc_{label}_b{batch}", duration * 1e6,
             f"{out[label]['throughput']:.0f}tps")

    def fingerprint(res):
        keyed = merge_keyed([s.managed for s in res.states["counter"]
                             if isinstance(s.managed, KeyedStore)])
        return (res.spout_tuples, res.sink_tuples, keyed.tobytes())

    rt = run_app(word_count(), par, batch=batch, max_batches=batches,
                 seed=900)
    rp = run_app_processes(word_count(), par, batch=batch,
                           max_batches=batches, seed=900)
    out["replay_parity"] = fingerprint(rt) == fingerprint(rp)
    emit(f"backend_wc_parity_b{batch}", 0.0, str(out["replay_parity"]))
    return out


def bench_serialization(batch: int, duration: float, repeat: int,
                        batches: int) -> dict:
    """The zero-copy slot format A/B (ISSUE 7): raw-header slots vs the
    pickled baseline, micro and end to end.

    Micro: one producer/consumer pair hammering a single ``ShmRing`` with
    the WC splitter jumbo (batch x 10 int64 words) — us/slot plus the
    ring's own bytes-copied-per-tuple counters (raw pays exactly one copy
    in and one copy out; pickle adds the serialize + deserialize + staging
    ``bytes``).  End to end: WC under a two-worker grouping that cuts the
    pipeline at the heavy splitter->counter edge, so the selectivity-10
    word stream crosses a ring in both formats; replay parity across
    formats is asserted on the same fingerprint the backend A/B uses."""
    from repro.streaming.procexec import ShmRing, run_app_processes
    from repro.streaming.state import KeyedStore, merge_keyed

    jumbo = np.arange(batch * 10, dtype=np.int64)      # WC splitter flush
    slots = 200 if batch <= 256 else 50
    out = {"batch": batch, "jumbo_rows": len(jumbo)}
    for label, raw in [("pickle", False), ("raw", True)]:
        ring = ShmRing(capacity=4, slot_bytes=1 << 20, raw=raw)
        try:
            ring.put((jumbo, 0.0))                     # warm
            ring.get()
            t0 = time.perf_counter()
            for _ in range(slots):
                ring.put((jumbo, 0.0))
                ring.get()
            us = (time.perf_counter() - t0) / slots * 1e6
            copied = (ring.put_bytes + ring.get_bytes) / \
                max(ring.put_tuples, 1)
        finally:
            ring.close()
            ring.unlink()
        out[f"ring_{label}"] = {"us_per_slot": round(us, 3),
                                "bytes_copied_per_tuple": round(copied, 2)}
        emit(f"serialization_ring_{label}_b{batch}", us,
             f"{copied:.0f}B_per_tuple")
    out["ring_speedup"] = round(out["ring_pickle"]["us_per_slot"] /
                                max(out["ring_raw"]["us_per_slot"], 1e-9), 3)

    # end to end: cut the pipeline mid-chain so the word stream pays a ring
    par = {"splitter": 2, "counter": 4}
    groups = {"spout": 0, "parser": 0, "splitter": 0, "counter": 1,
              "sink": 1}
    out["parallelism"], out["groups"] = par, "spout..splitter|counter..sink"
    for label in ("pickle", "raw"):
        thr = []
        for r in range(repeat):
            res = run_app_processes(word_count(), par, batch=batch,
                                    duration=duration, seed=750 + r,
                                    groups=groups, ring_format=label)
            thr.append(res.throughput)
        out[f"wc_{label}"] = {"throughput": round(statistics.median(thr), 1)}
        emit(f"serialization_wc_{label}_b{batch}", duration * 1e6,
             f"{out[f'wc_{label}']['throughput']:.0f}tps")
    out["wc_speedup"] = round(out["wc_raw"]["throughput"] /
                              max(out["wc_pickle"]["throughput"], 1e-9), 3)
    emit(f"serialization_wc_speedup_b{batch}", 0.0,
         f"{out['wc_speedup']:.3f}x")

    def fingerprint(res):
        keyed = merge_keyed([s.managed for s in res.states["counter"]
                             if isinstance(s.managed, KeyedStore)])
        return (res.spout_tuples, res.sink_tuples, keyed.tobytes())

    fps = [fingerprint(run_app_processes(word_count(), par, batch=batch,
                                         max_batches=batches, seed=910,
                                         groups=groups, ring_format=label))
           for label in ("pickle", "raw")]
    out["replay_parity"] = fps[0] == fps[1]
    emit(f"serialization_wc_parity_b{batch}", 0.0, str(out["replay_parity"]))
    return out


def bench_placement(repeat: int, batches: int, batch: int = 256) -> dict:
    """Placement sensitivity under the process backend: the same WC replay
    under (a) the RLAS plan's socket grouping, (b) a seeded random
    grouping, (c) the worst case — sockets alternating along the chain so
    *every* edge, including the selectivity-10 splitter->counter word
    stream, crosses workers and pays the ring serialize+copy.

    The protocol holds the worker count fixed: RLAS plans the bench
    parallelism onto a two-socket machine, and the random/worst groupings
    reassign the same replicas over the same two workers — so the only
    variable is *which* edges cross the boundary, exactly the paper's
    placement question.  Replay wall time over a fixed batch budget is the
    cost metric; ``spread`` is worst/RLAS — the margin a placement-blind
    single-process runtime can never show."""
    from repro.core import server_a, subset
    from repro.streaming.api import Job
    from repro.streaming.procexec import plan_placement, run_app_processes

    par = {"spout": 1, "parser": 1, "splitter": 2, "counter": 4, "sink": 1}
    replicas = [(op, i) for op, k in par.items() for i in range(k)]
    plan = Job(word_count()).plan(subset(server_a(), 2), optimizer="rlas",
                                  parallelism=par, compress_ratio=5,
                                  bestfit=True, max_nodes=5000)
    rlas_groups, pins = plan_placement(plan, par)
    sockets = sorted(set(rlas_groups.values())) or [0]
    depth = {"spout": 0, "parser": 1, "splitter": 2, "counter": 3, "sink": 4}
    worst = {(op, i): sockets[(depth[op] + i) % len(sockets)]
             for op, i in replicas}
    rng = np.random.default_rng(0)
    random_g = {rep: sockets[int(rng.integers(0, len(sockets)))]
                for rep in replicas}

    lg = word_count().graph

    def cut(groups):
        """(cross-group replica edges, modeled tuple weight crossing)."""
        edges = [(u, i, v, j) for v in lg.operators
                 if not lg.operators[v].is_spout for j in range(par[v])
                 for u in lg.producers(v) for i in range(par[u])
                 if groups[(u, i)] != groups[(v, j)]]
        w = sum(lg.edge_selectivity.get((u, v), 1.0) / par[v]
                for u, i, v, j in edges)
        return len(edges), round(w, 2)

    out = {"batch": batch, "batches": batches, "parallelism": par,
           "plan_sockets": sockets}
    for label, groups, pin in [("rlas", rlas_groups, pins),
                               ("random", random_g, None),
                               ("worst", worst, None)]:
        wall = []
        for r in range(repeat):
            res = run_app_processes(word_count(), par, batch=batch,
                                    max_batches=batches, seed=800,
                                    groups=groups, pin=pin)
            wall.append(res.duration)
        rings, weight = cut(groups)
        out[label] = {"wall_s": round(statistics.median(wall), 4),
                      "workers": len(set(groups.values())),
                      "rings": rings, "cut_weight": weight}
        emit(f"placement_wc_{label}", statistics.median(wall) * 1e6,
             f"{rings}rings_w{weight}")
    out["spread_worst_over_rlas"] = round(
        out["worst"]["wall_s"] / max(out["rlas"]["wall_s"], 1e-9), 3)
    emit("placement_wc_spread", 0.0,
         f"{out['spread_worst_over_rlas']:.3f}x")
    return out


def bench_cadence(batch: int, duration: float, repeat: int) -> dict:
    """Watermark cadence A/B on sd_et: the auto-derived cadence (window-
    grid targeted, ISSUE 6 satellite) vs pinned 8 (the old hand calibration
    — identical at batch 256 by construction) and pinned 16/1 as the
    too-coarse / too-fine endpoints."""
    from repro.streaming.runtime import prepare_app

    out = {"batch": batch,
           "auto_resolves_to": prepare_app(spike_detection_eventtime(),
                                           batch=batch).wm_every["spout"]}
    for label, cadence in [("auto", "auto"), ("fixed8", 8),
                           ("fixed16", 16), ("fixed1", 1)]:
        ingest = []
        for r in range(repeat):
            res = run_app(spike_detection_eventtime(watermark_every=cadence),
                          {"parser": 2}, batch=batch, duration=duration,
                          seed=600 + r)
            ingest.append(res.spout_tuples / res.duration)
        out[label] = {"ingest": round(statistics.median(ingest), 1)}
        emit(f"cadence_sd_et_{label}_b{batch}", duration * 1e6,
             f"{out[label]['ingest']:.0f}tps_in")
    out["auto_vs_fixed8"] = round(out["auto"]["ingest"] /
                                  max(out["fixed8"]["ingest"], 1e-9), 3)
    emit(f"cadence_sd_et_auto_vs_fixed8_b{batch}", 0.0,
         f"{out['auto_vs_fixed8']:.3f}x")
    return out


def bench_checkpoint(batch: int, duration: float, repeat: int) -> dict:
    """Aligned-barrier checkpointing (ISSUE 9): what barrier injection,
    alignment and per-round state snapshots cost the WC ingest path.

    A/B: checkpointing off vs barrier cadences 16/64/256 batches, same
    duration-mode runs as the apps section.  The 64-batch cadence is the
    acceptance configuration — ``overhead_ratio`` (off/on ingest at 64)
    gates at <= 1.10, i.e. the snapshot path may cost at most 10% ingest.
    ``recovery_parity`` replays a deterministic budget, resumes from a
    mid-stream checkpoint and demands byte-identical sink counters and
    keyed state — recovery must be exact, not just fast."""
    from repro.streaming.state import merge_keyed

    par = {"splitter": 2, "counter": 4}

    def ingest(**kw):
        vals = []
        for r in range(repeat):
            res = run_app(word_count(), dict(par), batch=batch,
                          duration=duration, seed=900 + r, **kw)
            vals.append(res.spout_tuples / res.duration)
        return statistics.median(vals)

    out = {"batch": batch, "default_every": 64}
    off = ingest()
    out["off"] = {"ingest": round(off, 1)}
    emit(f"checkpoint_wc_off_b{batch}", duration * 1e6, f"{off:.0f}tps_in")
    for every in (16, 64, 256):
        on = ingest(checkpoint_every=every)
        out[f"every{every}"] = {"ingest": round(on, 1),
                                "vs_off": round(on / max(off, 1e-9), 3)}
        emit(f"checkpoint_wc_every{every}_b{batch}", duration * 1e6,
             f"{on:.0f}tps_in_{out[f'every{every}']['vs_off']:.3f}x")
    out["overhead_ratio"] = round(
        off / max(out["every64"]["ingest"], 1e-9), 3)
    emit(f"checkpoint_wc_overhead_b{batch}", 0.0,
         f"{out['overhead_ratio']:.3f}x_off_vs_every64")

    def fp(res):
        seen = sum(st.get("seen", 0) for st in res.states["sink"])
        keyed = merge_keyed([st.managed for st in res.states["counter"]])
        return seen, keyed.tobytes()

    base = run_app(word_count(), dict(par), batch=batch, max_batches=12,
                   seed=77, checkpoint_every=4)
    ck = base.checkpoints[1]
    resumed = run_app(word_count(), batch=batch, seed=77,
                      max_batches=12 - ck.spout_offsets["spout#0"],
                      from_checkpoint=ck)
    out["recovery_parity"] = fp(base) == fp(resumed)
    emit(f"checkpoint_wc_recovery_parity_b{batch}", 0.0,
         str(out["recovery_parity"]))
    return out


def bench_fusion(batch: int, duration: float, repeat: int, batches: int,
                 with_processes: bool) -> dict:
    """Operator fusion A/B (ISSUE 10): the chain-heavy 1:1 pipeline where
    every hop is a queue crossing vs ``fuse="auto"`` compiling the whole
    f1..f4+sink segment into one ``FusedExecutor`` per replica.

    The stage kernels are light affine arithmetic, so the unfused run is
    dominated by exactly what fusion deletes: per-hop enqueue/dequeue,
    fan-in polling, watermark min-merge and an arena lease per stage.
    ``replay_parity`` replays a deterministic budget fused and unfused
    (and through the process backend when enabled) and byte-compares
    every replica's state — the speedup must not buy a single changed
    byte."""
    from repro.streaming.apps import chain_pipeline
    from repro.streaming.state import state_payload

    runners = [("threads", run_app)]
    if with_processes:
        from repro.streaming.procexec import run_app_processes
        runners.append(("processes", run_app_processes))

    out = {"batch": batch, "stages": 4}
    for bname, runner in runners:
        row = {}
        for label, fuse in [("unfused", None), ("fused", "auto")]:
            ingest = []
            for r in range(repeat):
                res = runner(chain_pipeline(), {}, batch=batch,
                             duration=duration, seed=300 + r, fuse=fuse)
                ingest.append(res.spout_tuples / res.duration)
            row[label] = {"ingest": round(statistics.median(ingest), 1)}
            emit(f"fusion_chain_{bname}_{label}_b{batch}", duration * 1e6,
                 f"{row[label]['ingest']:.0f}tps_in")
        row["fused_vs_unfused"] = round(
            row["fused"]["ingest"] / max(row["unfused"]["ingest"], 1e-9), 3)
        emit(f"fusion_chain_{bname}_speedup_b{batch}", 0.0,
             f"{row['fused_vs_unfused']:.3f}x")
        out[bname] = row

    def fp(res):
        return {op: [repr(state_payload(s)) for s in sts]
                for op, sts in sorted(res.states.items())}

    base = run_app(chain_pipeline(), {}, batch=batch, max_batches=batches,
                   seed=11)
    fused = run_app(chain_pipeline(), {}, batch=batch, max_batches=batches,
                    seed=11, fuse="auto")
    parity = fp(fused) == fp(base)
    if with_processes:
        proc = run_app_processes(chain_pipeline(), {}, batch=batch,
                                 max_batches=batches, seed=11, fuse="auto")
        parity = parity and fp(proc) == fp(base)
    out["replay_parity"] = parity
    out["fused_vs_unfused"] = out["threads"]["fused_vs_unfused"]
    emit(f"fusion_chain_replay_parity_b{batch}", 0.0, str(parity))
    return out


#: run one streaming_inference measurement in a *fresh* interpreter: the
#: process backend demands a JAX-clean parent (jax's fork-unsafe runtime
#: deadlocks a forked child's jit call once the parent touched XLA), and a
#: cold process per data point also keeps the sync/async rows free of
#: cross-run JIT-cache and allocator state.  Prints one JSON line.
_INF_CHILD = r"""
import json, sys
backend, depth, batch, nbatches, duration, seed = sys.argv[1:7]
from repro.streaming.apps import streaming_inference
app = streaming_inference(model_versions=1)
kw = dict(batch=int(batch), seed=int(seed), dispatch_depth=int(depth))
if float(duration) > 0:
    kw["duration"] = float(duration)
else:
    kw["max_batches"] = int(nbatches)
if backend == "threads":
    from repro.streaming.runtime import run_app as runner
    # warm run: jit trace+compile (~0.6s, dwarfs the window) happens here,
    # not inside the measured run; states are rebuilt per run so this
    # leaves no trace in results
    runner(app, {}, batch=int(batch), max_batches=6, seed=7,
           dispatch_depth=int(depth))
else:
    from repro.streaming.procexec import run_app_processes as runner
res = runner(app, {}, **kw)
sink = res.states["sink"][0]
print(json.dumps({
    "throughput": res.throughput,
    "spout_tuples": res.spout_tuples,
    "sink_tuples": res.sink_tuples,
    "seen": int(sink.get("seen", 0)),
    "score": float(sink.get("score", 0.0)).hex(),
}))
"""


def _inf_child(backend: str, depth: int, batch: int, *, duration: float = 0.0,
               batches: int = 0, seed: int = 0,
               timeout: float = 240.0) -> dict:
    env = dict(os.environ)
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    cp = subprocess.run(
        [sys.executable, "-c", _INF_CHILD, backend, str(depth), str(batch),
         str(batches), str(duration), str(seed)],
        capture_output=True, text=True, env=env, timeout=timeout)
    if cp.returncode != 0:
        raise RuntimeError(
            f"inference child failed (backend={backend}, depth={depth}):\n"
            f"{cp.stderr[-2000:]}")
    return json.loads(cp.stdout.strip().splitlines()[-1])


def bench_inference(batch: int, duration: float, repeat: int, batches: int,
                    backend: str) -> dict:
    """The async device-dispatch A/B (ISSUE 8 tentpole): streaming_inference
    ingest at dispatch_depth 1 (synchronous materialization) vs 2 and 4.

    On a small host the win is not device/host overlap but the per-call
    dispatch bubble — with depth>1 the executor enqueues the next jitted
    call before blocking on the oldest, so XLA's queue never drains between
    batches; the bubble is fixed per call, hence the small batch.  Each
    data point runs in a fresh interpreter (see ``_INF_CHILD``) with a
    timeout guard; rounds interleave across depths and the row keeps
    best-of-N — sink throughput swings ~20% run to run and medians of
    interleaved bests are the stable readout on a noisy 1-2 core box.
    ``replay_parity`` replays a fixed batch budget at depth 1 vs 2 and
    demands byte-identical sink state (count + float64 score hex) — the
    async window must be invisible to results, not just faster.

    Threads children warm the jit before the window; process-backend
    workers fork fresh per run and compile *inside* it, so that section
    stretches the window to keep the compile from drowning the signal —
    its rows still understate the async win and the acceptance ratio is
    read from the threads section."""
    if backend == "processes":
        duration = max(duration, 1.6)
    depths = (1, 2, 4)
    thr = {d: [] for d in depths}
    for r in range(repeat):
        for d in depths:
            thr[d].append(_inf_child(backend, d, batch, duration=duration,
                                     seed=100 + r)["throughput"])
    out = {"batch": batch, "backend": backend}
    for d in depths:
        out[f"depth{d}"] = {"throughput": round(max(thr[d]), 1)}
        emit(f"inference_{backend}_depth{d}_b{batch}", duration * 1e6,
             f"{out[f'depth{d}']['throughput']:.0f}tps")
    sync = max(out["depth1"]["throughput"], 1e-9)
    out["async2_vs_sync"] = round(out["depth2"]["throughput"] / sync, 3)
    out["async4_vs_sync"] = round(out["depth4"]["throughput"] / sync, 3)
    out["async_speedup"] = max(out["async2_vs_sync"], out["async4_vs_sync"])
    emit(f"inference_{backend}_async_speedup_b{batch}", 0.0,
         f"{out['async_speedup']:.3f}x")

    fps = [(p["spout_tuples"], p["sink_tuples"], p["seen"], p["score"])
           for p in (_inf_child(backend, d, batch, batches=batches, seed=42)
                     for d in (1, 2))]
    out["replay_parity"] = fps[0] == fps[1]
    emit(f"inference_{backend}_parity_b{batch}", 0.0,
         str(out["replay_parity"]))
    return out


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny durations for CI")
    ap.add_argument("--duration", type=float, default=None)
    ap.add_argument("--repeat", type=int, default=None)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_streaming.json"))
    ap.add_argument("--floor-eventtime", type=float, default=None,
                    metavar="RATIO",
                    help="exit nonzero unless eventtime.ingest_ratio >= "
                         "RATIO (the CI guard against the pane-at-a-time "
                         "regression sneaking back)")
    ap.add_argument("--backend", choices=("threads", "processes"),
                    default="threads",
                    help="'processes' adds the backend A/B + placement-"
                         "sensitivity sections; with --smoke, only those "
                         "sections run (the CI procexec smoke row)")
    args = ap.parse_args(argv)
    duration = args.duration or (0.1 if args.smoke else 0.8)
    repeat = args.repeat or (1 if args.smoke else 7)
    iters = 50 if args.smoke else 400
    procexec_only = args.backend == "processes" and args.smoke
    single_cpu = len(os.sched_getaffinity(0)) < 2

    report = {
        "meta": {"cpus": os.cpu_count(), "duration_s": duration,
                 "repeat": repeat, "smoke": bool(args.smoke),
                 "backend": args.backend, "single_cpu": single_cpu},
    }
    failures = []
    if not procexec_only:
        report["micro"] = [bench_split(rows, k, iters)
                          for rows in (256, 2560, 10240) for k in (2, 4, 8)]
        report["apps"] = {
            # WC's keyed edge carries batch x selectivity-10 words; batch
            # 256 is the acceptance configuration (jumbo batches of 2560
            # words)
            "wc": bench_app("wc", word_count,
                            {"splitter": 2, "counter": 4}, 256,
                            duration, repeat),
            "lr": bench_app("lr", linear_road,
                            {"dispatcher": 2, "toll_history": 4}, 1024,
                            duration, repeat),
        }
        report["state"] = bench_state(256, duration, repeat)
        # the floor gate needs a window long enough to amortize thread
        # startup and the first pane-firing ramp: smoke durations
        # systematically under-report the event-time path (~0.35x at 0.1s
        # vs ~0.55x at 0.8s), so the gated section runs at bench-grade
        # settings even under --smoke (medians over 5 runs keep the
        # scheduler-noise tail off the gate)
        et_duration = max(duration, 0.8) if args.floor_eventtime \
            else duration
        et_repeat = max(repeat, 5) if args.floor_eventtime else repeat
        report["eventtime"] = bench_eventtime(256, et_duration, et_repeat)
        report["cadence"] = bench_cadence(256, duration, repeat)
        # the 10% overhead gate needs windows long enough that per-run
        # thread startup doesn't drown the barrier cost it prices
        report["checkpoint"] = bench_checkpoint(256, max(duration, 0.4),
                                                max(repeat, 3))
        # small batches put the per-hop overhead fusion deletes in the
        # numerator; medians over >=3 runs keep the gate off the noise
        report["fusion"] = bench_fusion(
            64, max(duration, 0.4), max(repeat, 3), batches=20,
            with_processes=args.backend == "processes")
    inf_repeat = 1 if args.smoke else max(3, min(repeat, 5))
    inf_batches = 20 if args.smoke else 60
    if not procexec_only:
        report["inference"] = bench_inference(16, duration, inf_repeat,
                                              inf_batches, "threads")
    if args.backend == "processes":
        bb = 8 if args.smoke else 20
        report["backends"] = bench_backends(256, duration, repeat, bb)
        report["serialization"] = bench_serialization(256, duration, repeat,
                                                      bb)
        report["placement"] = bench_placement(max(1, repeat // 2), bb)
        report["inference_processes"] = bench_inference(
            16, duration, inf_repeat, inf_batches, "processes")

    # gates — evaluated before the dump so skips leave a marker in meta
    # rather than only a line on stdout
    skipped = report["meta"].setdefault("skipped_floor", [])
    for sec in ("inference", "inference_processes"):
        if sec in report and not report[sec]["replay_parity"]:
            failures.append(f"{sec} replay_parity is False (async dispatch "
                            "window changed results)")
    if "checkpoint" in report:
        if not report["checkpoint"]["recovery_parity"]:
            failures.append("checkpoint recovery_parity is False (restore "
                            "from a mid-stream checkpoint diverged)")
        ratio = report["checkpoint"]["overhead_ratio"]
        # on a single-CPU host the snapshot deep-copies contend with
        # ingest on the same core, so the 10% bound is not comparable
        if single_cpu and ratio > 1.10:
            skipped.append({"gate": "checkpoint_overhead", "ratio": ratio,
                            "reason": "single-CPU host; snapshots and "
                                      "ingest share one core"})
            print(f"# checkpoint overhead_ratio {ratio:.3f} — 1.10 gate "
                  "skipped (single-CPU host)")
        elif ratio > 1.10:
            failures.append(f"checkpoint overhead_ratio {ratio:.3f} > 1.10 "
                            "(barrier/snapshot path costs more than 10% "
                            "ingest at the default 64-batch cadence)")
        # every cadence row carries an explicit floor.  every16 aligns 4x
        # as many barriers as the acceptance cadence and measured 0.797x
        # on the reference host — its 0.75 floor is a documented waiver
        # that holds the line against FURTHER regression rather than
        # asserting the 64-cadence bound at 4x the barrier frequency.
        for row, floor in (("every16", 0.75), ("every64", 0.85),
                           ("every256", 0.90)):
            vs = report["checkpoint"][row]["vs_off"]
            if single_cpu and vs < floor:
                skipped.append({"gate": f"checkpoint_{row}", "ratio": vs,
                                "reason": "single-CPU host; snapshots and "
                                          "ingest share one core"})
                print(f"# checkpoint {row} vs_off {vs:.3f} — {floor:.2f} "
                      "floor skipped (single-CPU host)")
            elif vs < floor:
                failures.append(
                    f"checkpoint {row} vs_off {vs:.3f} < {floor:.2f} "
                    "(cadence row regressed past its documented floor)")
    if "fusion" in report:
        if not report["fusion"]["replay_parity"]:
            failures.append("fusion replay_parity is False (the fused "
                            "chain changed replayed results)")
        fr = report["fusion"]["fused_vs_unfused"]
        # deleting queue hops must never cost throughput: the fused
        # executor is gated at >= 1.0x the unfused pipeline
        if fr < 1.0:
            failures.append(f"fusion fused_vs_unfused {fr:.3f} < 1.00 "
                            "(FusedExecutor slower than the queue-hop "
                            "pipeline it replaces)")
    if "apps" in report:
        worst_auto = min(s["auto_vs_best"] for s in report["apps"].values())
        report["meta"]["auto_vs_best_worst"] = worst_auto
        # the per-edge auto selection contract: within ~4% of the best
        # forced mode; 0.90 leaves margin for residual scheduler noise on
        # top of the interleaved-repeat protocol
        if worst_auto < 0.90:
            failures.append(f"auto_vs_best {worst_auto:.3f} < 0.90 "
                            "(per-edge keyed-split selection regressed)")
    if args.floor_eventtime is not None and "eventtime" in report:
        ratio = report["eventtime"]["ingest_ratio"]
        # the ratio compares two *threaded* pipelines whose scaling differs
        # with core count: on a single-CPU host the count-window denominator
        # runs ~4x faster relative to the event-time path, so a healthy
        # engine measures ~0.25 there and the floor cannot separate it from
        # the pane-at-a-time regression (0.217) it guards against
        if single_cpu:
            skipped.append({"gate": "floor_eventtime",
                            "floor": args.floor_eventtime, "ratio": ratio,
                            "reason": "single-CPU host; ratio only "
                                      "comparable on >=2 cores"})
            print(f"# eventtime ingest_ratio {ratio:.3f} — floor "
                  f"{args.floor_eventtime:.3f} skipped (single-CPU host; "
                  "ratio only comparable on >=2 cores)")
        elif ratio < args.floor_eventtime:
            failures.append(f"eventtime ingest_ratio {ratio:.3f} < floor "
                            f"{args.floor_eventtime:.3f} (segmented pane "
                            "engine regressed toward pane-at-a-time cost)")
        else:
            print(f"# eventtime ingest_ratio {ratio:.3f} >= floor "
                  f"{args.floor_eventtime:.3f}")
    if not skipped:
        del report["meta"]["skipped_floor"]

    with open(args.out, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {os.path.abspath(args.out)}")
    if failures:
        for msg in failures:
            print(f"# FAIL {msg}")
        sys.exit(1)
    return report


if __name__ == "__main__":
    main()
