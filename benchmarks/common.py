"""Shared benchmark helpers: CSV row protocol + cached RLAS plans.

Every benchmark prints ``name,us_per_call,derived`` rows; ``us_per_call`` is
the optimizer/simulator wall time per invocation, ``derived`` the
benchmark-specific metric (throughput, relative error, speedup...).
"""
from __future__ import annotations

import functools
import time
from typing import Dict, Optional

from repro.core import (ExecutionGraph, MachineSpec, evaluate, rlas_optimize,
                        server_a, server_b, subset)
from repro.streaming.apps import ALL_APPS
from repro.streaming.simulator import fluid_solve, measure_capacity

ROWS = []


def emit(name: str, us_per_call: float, derived) -> None:
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


@functools.lru_cache(maxsize=64)
def optimized_plan(app_name: str, machine_name: str, n_sockets: int = 8,
                   compress: int = 5, tf_mode: str = "relative"):
    """RLAS plan for (app, machine) with the paper's settings (r=5)."""
    app = ALL_APPS[app_name]()
    machine = {"server_a": server_a, "server_b": server_b}[machine_name]()
    if n_sockets < machine.n_sockets:
        machine = subset(machine, n_sockets)
    t0 = time.time()
    res = rlas_optimize(app.graph, machine, input_rate=None,
                        compress_ratio=compress, bestfit=True,
                        max_nodes=5000, tf_mode=tf_mode)
    wall = time.time() - t0
    return app, machine, res, wall


def des_measure(app, machine, res, horizon: float = 0.008, seed: int = 0):
    """Measured throughput of an optimized plan on the DES."""
    return measure_capacity(res.graph, machine, res.placement.placement,
                            horizon=horizon, seed=seed)
