"""Shared benchmark helpers: CSV row protocol + cached RLAS plans.

Every benchmark prints ``name,us_per_call,derived`` rows; ``us_per_call`` is
the optimizer/simulator wall time per invocation, ``derived`` the
benchmark-specific metric (throughput, relative error, speedup...).

Plans come from the unified Job/Plan API: ``optimized_plan`` returns an
RLAS :class:`repro.streaming.api.Plan` whose ``estimate()`` / ``simulate()``
/ ``execute()`` produce the benchmark measurements.
"""
from __future__ import annotations

import functools
import time
from typing import Optional

from repro.core import server_a, server_b, subset
from repro.streaming.api import Job, Metrics, Plan
from repro.streaming.apps import ALL_APPS

ROWS = []


def emit(name: str, us_per_call: float, derived) -> None:
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


@functools.lru_cache(maxsize=64)
def optimized_plan(app_name: str, machine_name: str, n_sockets: int = 8,
                   compress: int = 5, tf_mode: str = "relative"):
    """RLAS plan for (app, machine) with the paper's settings (r=5)."""
    app = ALL_APPS[app_name]()
    machine = {"server_a": server_a, "server_b": server_b}[machine_name]()
    if n_sockets < machine.n_sockets:
        machine = subset(machine, n_sockets)
    t0 = time.time()
    plan = Job(app).plan(machine, optimizer="rlas", compress_ratio=compress,
                         bestfit=True, max_nodes=5000, tf_mode=tf_mode)
    wall = time.time() - t0
    return app, machine, plan, wall


def des_measure(plan: Plan, horizon: float = 0.008,
                seed: int = 0) -> Metrics:
    """Measured saturation throughput of a plan on the DES (§6.1 protocol)."""
    return plan.simulate(backend="des", input_rate=None, horizon=horizon,
                         seed=seed)
