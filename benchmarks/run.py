"""Benchmark harness entry point: one section per paper table/figure plus
the TPU roofline table derived from the dry-run sweep.

Prints ``name,us_per_call,derived`` CSV rows.
  PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="skip the slowest sections (monte-carlo, runtime)")
    ap.add_argument("--dryrun-jsonl", default="results/dryrun_baseline.jsonl")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    t0 = time.time()
    from . import bench_tables, bench_figures, roofline
    bench_tables.table3_rma()
    bench_tables.table4_accuracy()
    bench_tables.table7_compress()
    bench_figures.fig7_latency()
    bench_figures.fig9_scalability()
    bench_figures.fig10_gap_to_ideal()
    bench_figures.fig12_fixed_capability()
    bench_figures.fig13_placement_strategies()
    if not args.quick:
        bench_figures.fig14_monte_carlo()
        bench_figures.fig16_factor_analysis()
        from . import bench_runtime
        bench_runtime.main([])
    roofline.main(args.dryrun_jsonl)
    print(f"total,{(time.time() - t0) * 1e6:.0f},done")


if __name__ == "__main__":
    main()
