"""Render the roofline table + hillclimb comparisons into EXPERIMENTS.md.

Replaces the <!-- ROOFLINE_TABLE --> marker with a markdown table built from
the dry-run JSONLs.  Idempotent: re-running regenerates the table between
the marker and the following blank-line-delimited fence.

  PYTHONPATH=src python -m benchmarks.report
"""
from __future__ import annotations

import json
import os
import re
import sys

from .roofline import load, terms


def roofline_markdown(paths) -> str:
    by_key = {}
    skips = []
    for path in paths:                      # later files override earlier
        if not os.path.exists(path):
            continue
        for rec in load(path):
            if rec["status"] != "ok":
                skips.append(rec)
                continue
            t = terms(rec)
            if t:
                by_key[(t["arch"], t["shape"], t["mesh"])] = t
    rows = sorted(by_key.values(),
                  key=lambda t: (t["arch"], t["shape"], t["mesh"]))
    lines = [
        "| arch | shape | mesh | t_compute | t_memory | t_collective | "
        "dominant | useful | roofline frac | param GiB/dev | opt GiB/dev |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for t in rows:
        lines.append(
            f"| {t['arch']} | {t['shape']} | {t['mesh']} "
            f"| {t['t_compute']:.3e} | {t['t_memory']:.3e} "
            f"| {t['t_collective']:.3e} | {t['dominant']} "
            f"| {t['useful_ratio']:.2f} | **{t['roofline_frac']:.3f}** "
            f"| {t['param_gib']:.2f} | {t['opt_gib']:.2f} |")
    seen = set()
    for rec in skips:
        key = (rec["arch"], rec["shape"], rec["mesh"])
        if key in seen:
            continue
        seen.add(key)
        reason = rec["reason"].splitlines()[0][:70]
        lines.append(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} "
                     f"| — | — | — | {rec['status']} | — | — | — | — |")
    return "\n".join(lines)


def inject(md_path: str = "EXPERIMENTS.md",
           marker: str = "<!-- ROOFLINE_TABLE -->",
           paths=("results/dryrun_baseline.jsonl",
                  "results/dryrun_hillclimb.jsonl",
                  "results/dryrun_hillclimb2.jsonl",
                  "results/dryrun_hillclimb3.jsonl",
                  "results/dryrun_hillclimb4.jsonl",
                  "results/dryrun_hillclimb5.jsonl")):
    table = roofline_markdown(paths)
    text = open(md_path).read()
    begin = f"{marker}\n<!-- BEGIN GENERATED -->"
    end = "<!-- END GENERATED -->"
    block = f"{begin}\n{table}\n{end}"
    if begin in text:
        text = re.sub(re.escape(begin) + r".*?" + re.escape(end), block,
                      text, flags=re.S)
    else:
        text = text.replace(marker, block)
    open(md_path, "w").write(text)
    print(f"injected {table.count(chr(10)) + 1} lines into {md_path}")


if __name__ == "__main__":
    inject(*sys.argv[1:])
