"""Batched decode serving demo: jumbo-batched requests through the decode
step with KV caches (the danube config exercises the sliding-window ring
buffer).

  PYTHONPATH=src python examples/serve_decode.py
"""
import jax
import numpy as np

from repro.configs import get
from repro.launch.serve import Request, serve_batch
from repro.models import model_api

cfg = get("h2o_danube_1_8b", smoke=True)
api = model_api(cfg)
params = api.init(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)
reqs = [Request(i, rng.integers(0, cfg.vocab, 8, dtype=np.int32),
                max_new=16) for i in range(8)]
reqs, dt = serve_batch(cfg, params, reqs, max_len=32)
toks = sum(r.max_new for r in reqs)
print(f"served {len(reqs)} requests / {toks} tokens in {dt:.2f}s "
      f"({toks/dt:.1f} tok/s batched on this host)")
print("sample output:", reqs[0].out)
