"""End-to-end training driver: a small llama-family LM on synthetic data
with checkpoint/resume. Scale --width-mult/--steps up on real hardware
(width_mult=4 is ~100M params).

  PYTHONPATH=src python examples/train_tiny_lm.py [--steps 200]
"""
import argparse

import numpy as np

from repro.launch.train import train

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=120)
ap.add_argument("--width-mult", type=int, default=1)
ap.add_argument("--ckpt-dir", default="/tmp/repro_tiny_lm")
args = ap.parse_args()

out = train("smollm_360m", smoke=True, steps=args.steps, batch=8, seq=128,
            ckpt_dir=args.ckpt_dir, ckpt_every=50,
            width_mult=args.width_mult)
first, last = np.mean(out["losses"][:10]), np.mean(out["losses"][-10:])
print(f"loss {first:.3f} -> {last:.3f} over {args.steps} steps "
      f"({'OK' if last < first else 'NOT LEARNING'})")
