"""RLAS as a multi-pod auto-planner (DESIGN.md §2): the LM layer stack is
declared as a planning-only streaming Topology (stages have profiled specs
but no kernels), and the same ``Job``/``Plan`` surface that drives the
streaming apps decides DP-vs-PP across pods; then simulate losing a pod and
re-plan (elastic scaling, paper §5.3).

  PYTHONPATH=src python examples/multipod_plan.py [--arch granite_3_2b]
"""
import argparse

from repro.configs import get
from repro.core.autoshard import plan_stages
from repro.launch.elastic import simulate_pod_failure

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="granite_3_2b")
args = ap.parse_args()
cfg = get(args.arch)

# plan_stages builds the stage Topology and runs one Job(...).plan(...);
# the underlying api.Plan rides along for the unified estimate surface
plan = plan_stages(cfg, n_pods=2, chips_per_pod=256)
est = plan.plan.estimate()
print(f"== {cfg.name} on 2 pods x 256 chips ==")
print(f"{est.summary()}  ({plan.plan.total_threads} chips engaged)")
print(f"stage -> pod: {plan.assignment}")
print(f"replication (chips per stage): {plan.parallelism}")
print(f"pipeline crosses pods: {plan.crosses_pods} "
      f"(False = RLAS chose DP-across-pods, collocating the pipeline)")
print(f"modeled throughput: {plan.throughput:.2f} microbatches/s")

before, after = simulate_pod_failure(cfg, 2, 1)
print(f"\n== pod failure: 2 pods -> 1 pod ==")
print(f"throughput {before.est_throughput:.2f} -> {after.est_throughput:.2f}"
      f" microbatches/s ({after.est_throughput/before.est_throughput:.0%})")
print("restore path: ckpt.restore(..., shardings=<new mesh>) reshards the "
      "last committed checkpoint onto the surviving pods.")
