"""Quickstart: the paper's pipeline end-to-end on Word Count.

1. Profile-backed WC topology (paper Fig. 2).
2. RLAS: jointly optimize replication + placement on Server A (Table 2).
3. Compare the analytical estimate against the discrete-event measurement.
4. Execute the real threaded runtime (jumbo tuples) and verify exact counts.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import rlas_optimize, server_a
from repro.streaming.apps import word_count
from repro.streaming.runtime import run_app
from repro.streaming.simulator import measure_capacity

app = word_count()
machine = server_a()

print("== RLAS optimization (paper Alg. 1 + 2) ==")
res = rlas_optimize(app.graph, machine, input_rate=None, compress_ratio=5,
                    bestfit=True, max_nodes=5000)
print(f"replication: {res.parallelism}")
print(f"estimated throughput: {res.R:,.0f} tuples/s "
      f"({res.iterations} scaling iterations)")

des = measure_capacity(res.graph, machine, res.placement.placement,
                       horizon=0.008)
rel = abs(des.R - res.R) / des.R
print(f"measured (DES): {des.R:,.0f} tuples/s  -> rel. error {rel:.2%} "
      f"(paper Table 4: 0.02-0.14)")
print(f"latency p50/p99: {des.latency_p50*1e6:.0f}/{des.latency_p99*1e6:.0f} us")

print("\n== real threaded runtime (jumbo tuples) ==")
rt = run_app(app, {"splitter": 2, "counter": 2}, batch=256, duration=0.5)
counted = sum(int(st.get("counts", np.zeros(1)).sum())
              for st in rt.states["counter"])
print(f"sink throughput: {rt.throughput:,.0f} words/s on this host")
print(f"exact-count check: {counted} == 10 x {rt.spout_tuples} sentences -> "
      f"{counted == 10 * rt.spout_tuples}")
