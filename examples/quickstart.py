"""Quickstart: the paper's pipeline end-to-end through the unified API.

1. Declare the Word Count topology (paper Fig. 2) with the fluent
   ``Topology`` builder — profiled specs, kernels, sources and partition
   strategies in one declaration.
2. ``Job(...).plan(...)``: RLAS jointly optimizes replication + placement
   on Server A (Table 2).
3. One ``Plan`` object flows through the Table 4 protocol:
   ``estimate()`` (analytical model) -> ``simulate()`` (discrete-event
   measurement) -> ``execute()`` (real threaded runtime, jumbo tuples).

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import server_a
from repro.streaming import Job, Topology

VOCAB, WORDS = 4096, 10


def source(batch, seed):
    rng = np.random.default_rng(seed)
    return rng.integers(0, VOCAB, size=(batch, WORDS))


def k_parser(batch, state):
    return [batch]


def k_splitter(batch, state):
    return [batch.reshape(-1)]


def k_counter(batch, state):
    counts = state.setdefault("counts", np.zeros(VOCAB, np.int64))
    np.add.at(counts, batch, 1)
    return [counts[batch].astype(np.int64)]


def k_sink(batch, state):
    state["seen"] = state.get("seen", 0) + len(batch)
    return []


topology = (
    Topology("wc")
    .spout("spout", source, exec_ns=500.0, tuple_bytes=120.0)
    .op("parser", k_parser, exec_ns=350.0, tuple_bytes=120.0)
    .op("splitter", k_splitter, exec_ns=1612.8, tuple_bytes=120.0,
        mem_bytes=240.0, selectivity=10.0)
    .op("counter", k_counter, exec_ns=612.3, tuple_bytes=32.0,
        mem_bytes=96.0, partition="key")
    .sink("sink", k_sink, exec_ns=100.0, tuple_bytes=32.0))

print("== RLAS optimization (paper Alg. 1 + 2) ==")
plan = Job(topology).plan(server_a(), optimizer="rlas", compress_ratio=5,
                          bestfit=True, max_nodes=5000)
print(plan.describe())

est = plan.estimate()
print(f"\n{est.summary()}")

des = plan.simulate(backend="des", horizon=0.008)
rel = abs(des.throughput - est.throughput) / des.throughput
print(f"{des.summary()}")
print(f"estimate vs DES rel. error: {rel:.2%} (paper Table 4: 0.02-0.14)")

print("\n== real threaded runtime (jumbo tuples) ==")
rt = plan.execute(duration=0.5, batch=256,
                  parallelism={"splitter": 2, "counter": 2})
counted = sum(int(st.get("counts", np.zeros(1)).sum())
              for st in rt.raw.states["counter"])
print(rt.summary())
print(f"exact-count check: {counted} == 10 x {rt.raw.spout_tuples} "
      f"sentences -> {counted == 10 * rt.raw.spout_tuples}")
